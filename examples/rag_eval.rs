//! RAG evaluation pipeline (paper §4.1 RAG metrics, after RAGAS):
//! factual-QA workload with retrieved context chunks and a known gold
//! chunk, scored with faithfulness, context relevance/precision/recall,
//! and answer relevance (embedding path through the PJRT runtime).

use spark_llm_eval::config::{EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report;
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};

fn main() -> anyhow::Result<()> {
    let n = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(600usize);
    println!("== RAG evaluation: {n} factual-QA examples with retrieved context ==\n");

    // QA-only mix: every example carries context chunks + gold position.
    let df = synth::generate(
        n,
        11,
        synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
    )?;

    let mut task = EvalTask::default();
    task.task_id = "rag-eval".into();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("faithfulness", "rag"),
        MetricConfig::new("context_relevance", "rag"),
        MetricConfig::new("context_precision", "rag"),
        MetricConfig::new("context_recall", "rag"),
    ];

    let mut runner = EvalRunner::with_clock(VirtualClock::new());
    runner.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
    let artifacts = default_artifact_dir();
    if artifacts.join("manifest.json").exists() {
        runner.runtime = Some(SemanticRuntime::load(&artifacts)?);
        task.metrics.push(MetricConfig::new("answer_relevance", "rag"));
    } else {
        eprintln!("(artifacts not built — skipping answer_relevance)");
    }

    let result = runner.evaluate(&df, &task)?;
    println!("{}", report::eval_summary(&result));

    // Ground truth is known by construction; check the metric semantics.
    let recall = result.metric("context_recall").unwrap();
    assert!(
        recall.value > 0.99,
        "gold chunk always contains the answer -> recall ≈ 1, got {}",
        recall.value
    );
    let precision = result.metric("context_precision").unwrap();
    assert!(
        (0.3..0.9).contains(&precision.value),
        "gold position uniform over 4 ranks -> MRR-style precision ≈ 0.52, got {}",
        precision.value
    );
    let faith = result.metric("faithfulness").unwrap();
    println!(
        "faithfulness {:.3}: correct answers are grounded in the gold chunk; \
         wrong answers (model quality misses) are not",
        faith.value
    );
    println!("\nrag_eval OK");
    Ok(())
}
