//! Adaptive early stopping — wave-based evaluation with certifiable CIs
//! (ISSUE 9 / Cer-Eval-style certified evaluation).
//!
//! Adds a `stopping` block to an otherwise ordinary task: the runner
//! issues inference in waves, recomputes each metric's CI after every
//! wave under a geometric alpha-spending correction, and stops spending
//! inference the moment every metric's half-width certifies at the
//! target. The saved suffix is accounted (`rows_saved`), never billed.
//!
//! Run with `cargo run --release --example stopping [n] [backend]`
//! (backend: "thread" default, "process", or "remote" — same contract
//! as the quickstart example).

use spark_llm_eval::config::{CachePolicy, CiMethod, EvalTask, MetricConfig, StoppingConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report;

fn main() -> anyhow::Result<()> {
    let n = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(3_000usize);
    let backend = match std::env::args().nth(2).as_deref() {
        Some(b) => spark_llm_eval::config::BackendKind::from_str(b)?,
        None => spark_llm_eval::config::BackendKind::Thread,
    };

    let mut task = EvalTask::default();
    task.task_id = "adaptive-stopping-eval".into();
    // Cache off so api_calls counts exactly the inference that stopping
    // is there to save.
    task.inference.cache_policy = CachePolicy::Disabled;
    task.inference.batch_size = 25;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    task.statistics.ci_method = CiMethod::Analytic;
    // Certify every metric to ±0.075 with a total error budget of 5%
    // spent geometrically over looks of 200 rows.
    task.stopping = Some(StoppingConfig {
        ci_half_width: 0.075,
        alpha: 0.05,
        wave_size: 200,
        min_rows: 200,
        spend_alpha: true,
    });
    task.backend = backend;
    if backend == spark_llm_eval::config::BackendKind::Remote {
        task.hosts = std::env::var("SLLEVAL_REMOTE_HOSTS")
            .map(|hosts| {
                hosts
                    .split(',')
                    .map(str::trim)
                    .filter(|h| !h.is_empty())
                    .map(String::from)
                    .collect()
            })
            .unwrap_or_default();
    }

    println!(
        "== Spark-LLM-Eval adaptive stopping: {} examples, {} backend ==\n",
        n,
        backend.as_str()
    );
    let df = synth::generate_default(n, 42);

    let mut runner = EvalRunner::with_clock(VirtualClock::new());
    runner.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };

    let result = runner.evaluate(&df, &task)?;
    println!("{}", report::eval_summary(&result));

    let s = &result.inference.sched;
    println!(
        "certified with {} of {} rows — {} rows ({:.1}%) of inference never issued",
        s.rows_evaluated,
        n,
        s.rows_saved,
        100.0 * s.rows_saved as f64 / n as f64,
    );

    // Contract checks (CI smoke runs this example across backends).
    assert_eq!(s.rows_evaluated + s.rows_saved, n, "every row evaluated or saved");
    assert!(s.rows_saved > 0, "the loose target must stop before the frame ends");
    assert_eq!(result.inference.api_calls, s.rows_evaluated as u64);
    let target = task.stopping.as_ref().unwrap().ci_half_width;
    for m in &result.metrics {
        assert_eq!(m.certified, Some(true), "{} must certify", m.name);
        let half_width = (m.ci.hi - m.ci.lo) / 2.0;
        assert!(
            half_width <= target,
            "{}: half-width {half_width:.4} exceeds the certified target ±{target}",
            m.name
        );
        println!(
            "{}: {:.4} ±{:.4} (certified at wave {:?})",
            m.name, m.value, half_width, m.stopped_at_wave
        );
    }

    // Machine-readable result for cross-backend checks (CI).
    if let Ok(out) = std::env::var("STOPPING_OUT") {
        std::fs::write(&out, result.to_json().to_pretty())?;
        println!("result JSON written to {out}");
    }
    println!("\nstopping OK");
    Ok(())
}
