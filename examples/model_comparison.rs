//! Model comparison with full statistical reporting (paper §4.3–§4.4):
//! evaluate two models on the same examples, pick the right significance
//! test per metric (Table 2), and report p-values + effect sizes.

use spark_llm_eval::config::{EvalTask, MetricConfig};
use spark_llm_eval::coordinator::{compare_results, EvalRunner};
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report;
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};

fn main() -> anyhow::Result<()> {
    let n = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(1_500usize);
    println!("== model comparison: gpt-4o vs gpt-4o-mini on {n} examples ==\n");

    let df = synth::generate_default(n, 7);

    let mut task_a = EvalTask::default();
    task_a.task_id = "model-comparison".into();
    task_a.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
        MetricConfig::new("rouge_l", "lexical"),
        MetricConfig::new("embedding_similarity", "semantic"),
    ];
    let mut task_b = task_a.clone();
    task_a.model.model_name = "gpt-4o".into();
    task_b.model.model_name = "gpt-4o-mini".into();

    let mut runner = EvalRunner::with_clock(VirtualClock::new());
    runner.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
    let artifacts = default_artifact_dir();
    if artifacts.join("manifest.json").exists() {
        runner.runtime = Some(SemanticRuntime::load(&artifacts)?);
    } else {
        // Semantic metric needs artifacts; drop it gracefully.
        task_a.metrics.retain(|m| m.metric_type != "semantic");
        task_b.metrics.retain(|m| m.metric_type != "semantic");
        eprintln!("(artifacts not built — skipping embedding_similarity)");
    }

    let ra = runner.evaluate(&df, &task_a)?;
    let rb = runner.evaluate(&df, &task_b)?;
    println!("{}", report::eval_summary(&ra));
    println!("{}", report::eval_summary(&rb));

    let cmp = compare_results(&ra, &rb, &task_a)?;
    println!("{}", report::comparison_summary(&cmp));

    for c in &cmp.comparisons {
        println!(
            "{}: {} selected (scale-driven, Table 2); p={:.4}, d={:+.3} ({}), {}",
            c.metric,
            c.test_choice.as_str(),
            c.test.p_value,
            c.cohens_d.value,
            c.cohens_d.magnitude(),
            c.odds_ratio
                .map(|o| format!("odds ratio {:.2}", o.value))
                .unwrap_or_else(|| "no odds ratio (non-binary)".into()),
        );
    }

    // The strong model must win significantly on exact match at this n.
    let em = cmp.comparisons.iter().find(|c| c.metric == "exact_match").unwrap();
    assert!(em.value_a > em.value_b, "gpt-4o should beat mini");
    assert!(em.test.significant(0.05), "difference should be significant at n={n}");
    println!("\nmodel_comparison OK");
    Ok(())
}
