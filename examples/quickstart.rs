//! End-to-end quickstart — the paper's §5.6 / Listing 2 workflow.
//!
//! Generates a synthetic instruction-following dataset (§5.1), runs the
//! full 4-stage pipeline (prompt prep → distributed inference with
//! per-executor rate limiting and caching → lexical + semantic (PJRT /
//! Pallas) + LLM-judge metrics → BCa bootstrap aggregation), logs to the
//! MLflow-style tracker, and prints the paper-style `MetricValue` lines.
//!
//! Run with `cargo run --release --example quickstart`. This is the
//! system's end-to-end validation driver: all three layers compose here
//! (Rust coordinator, JAX-AOT SimLM encoder, Pallas BERTScore kernel),
//! and the run is recorded in EXPERIMENTS.md.

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report;
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};
use spark_llm_eval::tracking::TrackingStore;
use spark_llm_eval::util::json::Json;

fn main() -> anyhow::Result<()> {
    let n = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(2_000usize);
    // Optional second argument: the executor backend ("thread" default,
    // "process" = crash-isolated `slleval worker` children; point
    // SLLEVAL_WORKER_EXE at the slleval binary when running the example
    // directly, since the example executable has no worker mode;
    // "remote" = TCP executors on the `slleval serve-worker` daemons
    // listed in SLLEVAL_REMOTE_HOSTS, comma-separated host:port).
    let backend = match std::env::args().nth(2).as_deref() {
        Some(b) => spark_llm_eval::config::BackendKind::from_str(b)?,
        None => spark_llm_eval::config::BackendKind::Thread,
    };

    // The Listing-2 task: instruction following with exact match,
    // BERTScore, and an LLM-judge helpfulness rubric; BCa CIs, B=1000.
    let mut task = EvalTask::default();
    task.task_id = "instruction-following-eval".into();
    task.model.provider = "openai".into();
    task.model.model_name = "gpt-4o".into();
    task.inference.batch_size = 50;
    task.inference.cache_policy = CachePolicy::Enabled;
    task.inference.rate_limit_rpm = 10_000.0;
    task.executors = 8;
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
        MetricConfig::new("bertscore", "semantic"),
        MetricConfig::new("helpfulness", "llm_judge")
            .with_param("rubric", Json::str("Rate helpfulness 1-5")),
    ];
    task.statistics.ci_method = spark_llm_eval::config::CiMethod::Bca;
    task.statistics.bootstrap_iterations = 1000;
    task.backend = backend;
    if backend == spark_llm_eval::config::BackendKind::Remote {
        task.hosts = std::env::var("SLLEVAL_REMOTE_HOSTS")
            .map(|hosts| {
                hosts
                    .split(',')
                    .map(str::trim)
                    .filter(|h| !h.is_empty())
                    .map(String::from)
                    .collect()
            })
            .unwrap_or_default();
    }

    println!(
        "== Spark-LLM-Eval quickstart: {} examples, {} backend ==\n",
        n,
        backend.as_str()
    );
    let df = synth::generate_default(n, 42);

    // Virtual clock + no latency sleeps: the example finishes in seconds
    // while still exercising rate limiting in virtual time. Drop `--fast`
    // semantics here to watch real pacing.
    let mut runner = EvalRunner::with_clock(VirtualClock::new());
    runner.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };

    // Cache + tracking in a scratch workspace.
    let work = std::env::temp_dir().join(format!("slleval-quickstart-{}", std::process::id()));
    runner.open_cache(&work.join("cache"), task.inference.cache_policy)?;

    // PJRT runtime for the semantic metric (requires `make artifacts`).
    // Without artifacts (plain CI checkout) the semantic metric is
    // dropped so the rest of the pipeline still runs end to end.
    let artifacts = default_artifact_dir();
    if artifacts.join("manifest.json").exists() {
        runner.runtime = Some(SemanticRuntime::load(&artifacts)?);
    } else {
        eprintln!("note: PJRT artifacts missing — skipping bertscore (run `make artifacts`)");
        task.metrics.retain(|m| m.name != "bertscore");
    }

    let result = runner.evaluate(&df, &task)?;
    println!("{}", report::eval_summary(&result));

    // Paper-style MetricValue lines.
    for m in &result.metrics {
        println!("{m}");
    }
    let judge = result.metric("helpfulness").unwrap();
    println!(
        "\njudge: {} unparseable responses ({:.2}%) logged for review (paper §5.6: 0.12%)",
        judge.unparseable,
        100.0 * judge.unparseable as f64 / n as f64
    );

    // MLflow-style tracking (§A.5).
    let store = TrackingStore::open(&work.join("runs"))?;
    let mut run = store.start_run(&task.task_id)?;
    run.log_evaluation(&task, &result)?;
    let run_id = run.run_id.clone();
    run.finish()?;
    println!("tracked as {run_id} under {:?}", work.join("runs"));

    // Sanity: the strong simulated model must do well on instructions.
    let em = result.metric("exact_match").unwrap();
    assert!(em.n > 0 && em.value > 0.3, "unexpected exact-match {}", em.value);

    // Machine-readable result for cross-backend identity checks (CI).
    if let Ok(out) = std::env::var("QUICKSTART_OUT") {
        std::fs::write(&out, result.to_json().to_pretty())?;
        println!("result JSON written to {out}");
    }
    println!("\nquickstart OK");
    Ok(())
}
