//! Figure 2 sweep, two ways:
//!
//! 1. The discrete-event simulator over the full executor range (the
//!    protocol the benches use — seconds of wall time for the whole
//!    sweep).
//! 2. A *live* confirmation run with real executor threads and the real
//!    token-bucket/provider stack at a reduced scale, showing the same
//!    knee.

use spark_llm_eval::config::EvalTask;
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report::table;
use spark_llm_eval::sim::{simulate, simulate_sequential, SimParams};

fn main() -> anyhow::Result<()> {
    println!("== Figure 2 scaling sweep ==\n");

    // --- DES sweep (paper protocol) -------------------------------------
    let mut rows = Vec::new();
    for executors in [1usize, 2, 4, 6, 8, 12, 16] {
        let p = SimParams { executors, n_examples: 10_000, ..Default::default() };
        let out = simulate(&p, None);
        rows.push(vec![
            executors.to_string(),
            format!("{:.0}", out.throughput_per_min),
            format!("{:.0}%", out.rate_wait_frac * 100.0),
        ]);
    }
    let seq = simulate_sequential(&SimParams { n_examples: 2_000, ..Default::default() });
    println!("DES sweep (10k examples, global 10k RPM):");
    println!(
        "{}",
        table(&["executors", "examples/min", "time rate-limited"], &rows)
    );
    println!("sequential baseline: {:.0}/min (paper: ~450/min)\n", seq.throughput_per_min);

    // --- live confirmation at reduced scale ------------------------------
    // Real executor threads, real buckets, virtual clock so latency
    // sleeps advance simulated time without wall-clock cost.
    println!("live pipeline confirmation (1,200 examples, throughput in wall time):");
    let df = synth::generate_default(1_200, 3);
    let mut live_rows = Vec::new();
    for executors in [1usize, 2, 4, 8] {
        let mut task = EvalTask::default();
        task.executors = executors;
        task.metrics = vec![spark_llm_eval::config::MetricConfig::new("exact_match", "lexical")];
        let mut runner = EvalRunner::with_clock(VirtualClock::new());
        runner.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
        let t0 = std::time::Instant::now();
        let result = runner.evaluate(&df, &task)?;
        let wall = t0.elapsed().as_secs_f64();
        live_rows.push(vec![
            executors.to_string(),
            format!("{:.0}", df.len() as f64 / wall),
            format!("{:.0}", result.metric("exact_match").unwrap().value * 100.0) + "%",
        ]);
    }
    println!(
        "{}",
        table(&["executors", "examples/sec (wall)", "exact match"], &live_rows)
    );
    println!("scaling_sweep OK");
    Ok(())
}
