//! The Table 4 workflow, live (not simulated): an initial evaluation run
//! populates the deltalite-backed cache, then three metric-iteration
//! rounds run in **replay mode** — zero API calls, zero cost — exactly
//! the paper's "decouple inference from metric computation" claim.

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report::table;

fn main() -> anyhow::Result<()> {
    let n = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(3_000usize);
    println!("== cache replay workflow ({n} examples, live pipeline) ==\n");

    let df = synth::generate_default(n, 13);
    let cache_dir = std::env::temp_dir().join(format!("slleval-replay-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);

    let mk_runner = |policy: CachePolicy| -> anyhow::Result<EvalRunner> {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
        r.open_cache(&cache_dir, policy)?;
        Ok(r)
    };

    let mut rows = Vec::new();
    let mut record = |label: &str, result: &spark_llm_eval::coordinator::EvalResult, wall: f64| {
        rows.push(vec![
            label.to_string(),
            format!(
                "{:.0}%",
                100.0 * result.inference.cache_hits as f64
                    / (result.inference.cache_hits + result.inference.cache_misses).max(1) as f64
            ),
            result.inference.api_calls.to_string(),
            format!("${:.4}", result.inference.total_cost_usd),
            format!("{:.2}s", wall),
        ]);
    };

    // Initial run: exact match only.
    let mut task = EvalTask::default();
    task.task_id = "replay-workflow".into();
    task.inference.cache_policy = CachePolicy::Enabled;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    let runner = mk_runner(CachePolicy::Enabled)?;
    let t0 = std::time::Instant::now();
    let initial = runner.evaluate(&df, &task)?;
    record("Initial run", &initial, t0.elapsed().as_secs_f64());
    let initial_cost = initial.inference.total_cost_usd;

    // Three metric-iteration rounds in strict replay mode.
    let iterations: [Vec<MetricConfig>; 3] = [
        vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("token_f1", "lexical"),
        ],
        vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("token_f1", "lexical"),
            MetricConfig::new("bleu", "lexical"),
        ],
        vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("rouge_l", "lexical"),
            MetricConfig::new("contains", "lexical"),
        ],
    ];
    let mut em_values = vec![initial.metric("exact_match").unwrap().value];
    for (i, metrics) in iterations.into_iter().enumerate() {
        let mut t = task.clone();
        t.inference.cache_policy = CachePolicy::Replay;
        t.metrics = metrics;
        let runner = mk_runner(CachePolicy::Replay)?;
        let t0 = std::time::Instant::now();
        let result = runner.evaluate(&df, &t)?;
        assert_eq!(result.inference.api_calls, 0, "replay must not call the API");
        assert_eq!(result.inference.total_cost_usd, 0.0);
        em_values.push(result.metric("exact_match").unwrap().value);
        record(&format!("Metric change {}", i + 1), &result, t0.elapsed().as_secs_f64());
    }

    println!(
        "{}",
        table(&["Iteration", "Cache Hits", "API Calls", "Cost", "Wall Time"], &rows)
    );
    println!(
        "total cost with cache: ${initial_cost:.4} (vs ${:.4} without — 75% saved, as Table 4)",
        initial_cost * 4.0
    );

    // Replay determinism: the shared metric agrees bit-for-bit.
    assert!(em_values.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-15));
    println!("exact_match identical across all iterations: {:.4}", em_values[0]);

    let _ = std::fs::remove_dir_all(&cache_dir);
    println!("\nreplay_iteration OK");
    Ok(())
}
