//! Self-tests for the lint rules over known-bad fixtures in
//! `tests/fixtures/lint/`. Fixture files are excluded from the real repo
//! walk (any `fixtures` directory is skipped), so the deliberate
//! violations here never trip the tier-1 gate; each test lexes a fixture
//! and maps it to the repo-relative path that puts it in the right
//! rule's scope.

use spark_llm_eval::analysis::{lexer, lint_sources, LintOutcome, SourceFile};
use spark_llm_eval::util::json::Json;
use std::path::Path;

fn fixture(rel_as: &str, name: &str) -> SourceFile {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint").join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    SourceFile { rel: rel_as.to_string(), lexed: lexer::lex(&text) }
}

fn run_one(rel_as: &str, name: &str, docs: &str) -> LintOutcome {
    lint_sources(&[fixture(rel_as, name)], docs, &[])
}

/// `(subject, line)` of every violation of `rule`, in reported order.
fn subjects(out: &LintOutcome, rule: &str) -> Vec<(String, u32)> {
    out.violations
        .iter()
        .filter(|d| d.rule == rule)
        .map(|d| (d.subject.clone(), d.line))
        .collect()
}

fn pairs(list: &[(&str, u32)]) -> Vec<(String, u32)> {
    list.iter().map(|(s, l)| (s.to_string(), *l)).collect()
}

#[test]
fn determinism_flags_clock_hash_and_rng() {
    let out = run_one("rust/src/sched/fixture_determinism.rs", "determinism.rs", "");
    assert_eq!(
        subjects(&out, "determinism"),
        pairs(&[
            ("HashMap", 3),
            ("Instant::now", 7),
            ("SystemTime::now", 8),
            ("HashMap", 12),
            ("HashMap", 13),
            ("thread_rng", 17),
        ]),
        "{:?}",
        out.violations
    );
    assert_eq!(out.violations.len(), 6, "{:?}", out.violations);
    // The justified allow on line 21 silences exactly the line-22 read.
    assert_eq!(out.suppressed.len(), 1);
    assert_eq!(out.suppressed[0].0.line, 22);
}

#[test]
fn determinism_only_applies_under_src() {
    // The same bad code under rust/tests/ is out of scope for the rule.
    let out = run_one("rust/tests/fixture_determinism.rs", "determinism.rs", "");
    assert!(subjects(&out, "determinism").is_empty(), "{:?}", out.violations);
}

#[test]
fn lexer_sees_through_comments_strings_and_raw_fences() {
    let out = run_one("rust/src/sched/fixture_lexer.rs", "lexer_tricky.rs", "");
    assert_eq!(
        subjects(&out, "determinism"),
        pairs(&[("Instant::now", 17)]),
        "{:?}",
        out.violations
    );
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
}

#[test]
fn panic_safety_flags_executor_side_aborts() {
    let out = run_one("rust/src/providers/pipeline.rs", "panic.rs", "");
    assert_eq!(
        subjects(&out, "panic-safety"),
        pairs(&[(".unwrap()", 6), (".expect()", 7), ("panic!", 9), ("unreachable!", 11)]),
        "{:?}",
        out.violations
    );
    assert_eq!(out.violations.len(), 4, "{:?}", out.violations);
}

#[test]
fn panic_safety_only_applies_to_executor_side_files() {
    let out = run_one("rust/src/report/fixture_panic.rs", "panic.rs", "");
    assert!(subjects(&out, "panic-safety").is_empty(), "{:?}", out.violations);
}

#[test]
fn wire_drift_reports_all_three_disagreements() {
    let files = [
        fixture("rust/src/sched/backend.rs", "wire_backend.rs"),
        fixture("rust/src/coordinator/worker.rs", "wire_worker.rs"),
    ];
    let out = lint_sources(&files, "", &[]);
    let wire = subjects(&out, "wire-protocol");
    // cancel: emitted but never handled + missing from the doc;
    // ack: handled but never emitted + missing from the doc;
    // retired: documented but gone from code. hello is clean.
    assert_eq!(out.violations.len(), 5, "{:?}", out.violations);
    assert_eq!(wire.iter().filter(|(s, _)| s == "cancel").count(), 2, "{wire:?}");
    assert_eq!(wire.iter().filter(|(s, _)| s == "ack").count(), 2, "{wire:?}");
    assert_eq!(wire.iter().filter(|(s, _)| s == "retired").count(), 1, "{wire:?}");
    assert!(!wire.iter().any(|(s, _)| s == "hello"), "{wire:?}");
    let has = |subject: &str, needle: &str| {
        out.violations.iter().any(|d| d.subject == subject && d.message.contains(needle))
    };
    assert!(has("cancel", "no peer dispatches"), "{:?}", out.violations);
    assert!(has("cancel", "missing from the protocol doc"), "{:?}", out.violations);
    assert!(has("ack", "nothing emits it"), "{:?}", out.violations);
    assert!(has("retired", "never appears in code"), "{:?}", out.violations);
}

#[test]
fn config_doc_flags_undocumented_fields_only() {
    let docs = "DESIGN: the `seed` field seeds every sampler.";
    let out = run_one("rust/src/config/mod.rs", "config_drift.rs", docs);
    assert_eq!(
        subjects(&out, "config-doc"),
        pairs(&[("frobnication_level", 5)]),
        "{:?}",
        out.violations
    );
    assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
}

#[test]
fn allow_placement_covers_same_line_and_line_above_only() {
    let out = run_one("rust/src/sched/fixture_allow.rs", "allow_placement.rs", "");
    // Cases a and b suppress; c (two lines above) and d (wrong rule) do
    // not — and their allows are flagged as stale.
    assert_eq!(out.suppressed.len(), 2, "{:?}", out.violations);
    assert_eq!(
        subjects(&out, "determinism"),
        pairs(&[("Instant::now", 19), ("Instant::now", 24)]),
        "{:?}",
        out.violations
    );
    assert_eq!(
        subjects(&out, "unused-allow"),
        pairs(&[("determinism", 17), ("panic-safety", 23)]),
        "{:?}",
        out.violations
    );
    assert_eq!(out.violations.len(), 4, "{:?}", out.violations);
}

#[test]
fn outcome_json_round_trips() {
    let out = run_one("rust/src/sched/fixture_allow.rs", "allow_placement.rs", "");
    let v = Json::parse(&out.to_json().to_string()).expect("lint JSON parses back");
    assert_eq!(v.get("violations").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(v.get("suppressed").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.get("files_scanned").unwrap().as_usize().unwrap(), 1);
}
