//! Cache + storage integration over the live pipeline: legacy `_log/`
//! tables migrate transparently through `ResponseCache::open`, the
//! `inference.cache_skipping` toggle is bit-identical end to end, and
//! optimize → vacuum preserves replay (paper §3.2, §5.3).

use flate2::write::GzEncoder;
use flate2::Compression;
use spark_llm_eval::cache::{cache_key, CacheEntry, ResponseCache};
use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::util::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-skipping-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r
}

fn task_with(policy: CachePolicy) -> EvalTask {
    let mut t = EvalTask::default();
    t.inference.cache_policy = policy;
    t.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    t
}

fn legacy_entry(prompt: &str) -> CacheEntry {
    CacheEntry {
        prompt_hash: cache_key(prompt, "m", "prov", 0.0, 100),
        model_name: "m".into(),
        provider: "prov".into(),
        prompt_text: prompt.into(),
        response_text: format!("legacy:{prompt}"),
        input_tokens: 10,
        output_tokens: 5,
        latency_ms: 100.0,
        created_at: 1000.0,
        ttl_days: None,
    }
}

fn write_legacy_data_file(root: &Path, name: &str, entries: &[CacheEntry]) {
    let file = std::fs::File::create(root.join("data").join(name)).unwrap();
    let mut enc = GzEncoder::new(file, Compression::fast());
    for e in entries {
        writeln!(enc, "{}", e.to_json()).unwrap();
    }
    enc.finish().unwrap();
}

/// A cache dir in the pre-subsystem deltalite format: `_log/%08d.json`
/// commits holding flat add/remove filename arrays.
fn write_legacy_commit(root: &Path, version: u64, adds: &[&str], removes: &[&str]) {
    let entry = Json::obj(vec![
        ("version", Json::num(version as f64)),
        ("op", Json::str("append")),
        ("timestamp", Json::num(1.0)),
        ("add", Json::arr(adds.iter().map(|a| Json::str(*a)).collect())),
        ("remove", Json::arr(removes.iter().map(|r| Json::str(*r)).collect())),
    ]);
    std::fs::write(root.join("_log").join(format!("{version:08}.json")), entry.to_pretty())
        .unwrap();
}

/// Opening an old-format cache through `ResponseCache::open` migrates it
/// one-way to a `_delta_log` v0 commit: every legacy entry stays
/// retrievable, the new log carries stats (so skipping works immediately),
/// and the table keeps working as a writable Delta table.
#[test]
fn legacy_log_cache_migrates_through_open() {
    let dir = tmp("legacy-migrate");
    std::fs::create_dir_all(dir.join("_log")).unwrap();
    std::fs::create_dir_all(dir.join("data")).unwrap();
    let old = legacy_entry("stale-prompt");
    let kept: Vec<CacheEntry> = (0..5).map(|i| legacy_entry(&format!("prompt-{i}"))).collect();
    write_legacy_data_file(&dir, "00000000-0000.jsonl.gz", &[old.clone()]);
    write_legacy_data_file(&dir, "00000001-0000.jsonl.gz", &kept);
    write_legacy_commit(&dir, 0, &["00000000-0000.jsonl.gz"], &[]);
    // The legacy v1 superseded v0's file — only `kept` is live.
    write_legacy_commit(&dir, 1, &["00000001-0000.jsonl.gz"], &["00000000-0000.jsonl.gz"]);

    let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
    assert_eq!(cache.len().unwrap(), 5);
    assert_eq!(cache.current_version().unwrap(), Some(0), "migration is one v0 commit");
    assert!(dir.join("_log.migrated").is_dir(), "legacy log retired, kept for forensics");
    assert!(dir.join("_delta_log").join(format!("{:020}.json", 0)).exists());
    for e in &kept {
        let hit = cache.get(&e.prompt_text, "m", "prov", 0.0, 100).unwrap().unwrap();
        assert_eq!(hit.response_text, e.response_text);
    }
    assert!(
        cache.get(&old.prompt_text, "m", "prov", 0.0, 100).unwrap().is_none(),
        "entries dead in the legacy log stay dead"
    );

    // Migrated adds carry stats on the cache's columns, so skipping works
    // from the very first post-migration probe.
    let fresh = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    let state = fresh.table().state(None).unwrap().unwrap();
    assert_eq!(state.files.len(), 1);
    let stats = state.files[0].stats.as_ref().expect("migrated adds carry stats");
    assert_eq!(stats.num_records, 5);
    assert!(stats.min_values.contains_key("prompt_hash"));
    assert!(stats.max_values.contains_key("model_name"));

    // And the migrated table is a normal writable Delta table.
    let resp = spark_llm_eval::providers::InferenceResponse {
        text: "new".into(),
        input_tokens: 1,
        output_tokens: 1,
        latency_ms: 1.0,
        cost_usd: 0.0,
    };
    cache.put("post-migration", "m", "prov", 0.0, 100, &resp).unwrap();
    cache.flush().unwrap();
    assert_eq!(ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap().len().unwrap(), 6);
}

/// `inference.cache_skipping` rides the task through the full runner
/// path; on or off, a warmed replay is bit-identical (all hits, no API
/// calls, same metric values).
#[test]
fn task_skipping_toggle_is_bit_identical_end_to_end() {
    let dir = tmp("toggle");
    let df = synth::generate_default(80, 71);
    let mut warm = fast_runner();
    warm.open_cache(&dir, CachePolicy::Enabled).unwrap();
    let r0 = warm.evaluate(&df, &task_with(CachePolicy::Enabled)).unwrap();
    drop(warm); // flush

    let mut on = task_with(CachePolicy::Replay);
    on.inference.cache_skipping = true;
    let mut off = task_with(CachePolicy::Replay);
    off.inference.cache_skipping = false;
    let mut results = Vec::new();
    for task in [&on, &off] {
        let mut runner = fast_runner();
        runner.open_cache(&dir, CachePolicy::Replay).unwrap();
        let r = runner.evaluate(&df, task).unwrap();
        assert_eq!(r.inference.api_calls, 0);
        assert_eq!(r.inference.cache_hits as usize, df.len());
        results.push(r.metric("exact_match").unwrap().value);
    }
    assert_eq!(results[0], r0.metric("exact_match").unwrap().value);
    assert_eq!(results[0], results[1], "skipping must not change any metric");
}

/// Full maintenance cycle against a runner-warmed cache: optimize
/// range-clusters the flush files, vacuum reclaims the superseded ones,
/// and a replay run afterwards is still all-hits with identical metrics.
#[test]
fn optimize_vacuum_cycle_preserves_replay() {
    let dir = tmp("maintenance-cycle");
    // Two warm runs → at least two flush files, so optimize has real work.
    let df1 = synth::generate_default(60, 71);
    let df2 = synth::generate_default(60, 72);
    let mut w1 = fast_runner();
    w1.open_cache(&dir, CachePolicy::Enabled).unwrap();
    let r0 = w1.evaluate(&df1, &task_with(CachePolicy::Enabled)).unwrap();
    drop(w1);
    let mut w2 = fast_runner();
    w2.open_cache(&dir, CachePolicy::Enabled).unwrap();
    w2.evaluate(&df2, &task_with(CachePolicy::Enabled)).unwrap();
    drop(w2);

    let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
    assert!(cache.table().state(None).unwrap().unwrap().files.len() >= 2);
    let outcome = cache.optimize(u64::MAX).unwrap();
    assert!(outcome.version.is_some());
    assert!(outcome.metrics.removed_sizes.len() >= 2);
    let vacuumed = cache.vacuum(0, false).unwrap();
    assert!(vacuumed.deleted_files >= 2, "superseded flush files reclaimed");
    assert!(vacuumed.reclaimed_bytes > 0);
    drop(cache);

    let mut replay = fast_runner();
    replay.open_cache(&dir, CachePolicy::Replay).unwrap();
    let r1 = replay.evaluate(&df1, &task_with(CachePolicy::Replay)).unwrap();
    assert_eq!(r1.inference.api_calls, 0);
    assert_eq!(
        r1.metric("exact_match").unwrap().value,
        r0.metric("exact_match").unwrap().value,
        "maintenance must not change replayed metrics"
    );
    let r2 = replay.evaluate(&df2, &task_with(CachePolicy::Replay)).unwrap();
    assert_eq!(r2.inference.api_calls, 0);
}
