//! Remote-backend integration: real `slleval serve-worker` daemons over
//! loopback TCP (via `CARGO_BIN_EXE_slleval`), hard host kills, hung
//! connections, and checkpoint resume through spill upload.
//!
//! These are the acceptance tests for the remote executor transport:
//!
//! - thread and remote backends produce identical metric values, CIs,
//!   and cost accounting on the same task;
//! - a hard-killed host (deterministic, via the plan's fault hook →
//!   `std::process::abort`, which in serve mode takes the whole daemon
//!   down) costs only its in-flight tasks: *every* executor on the host
//!   is settled at once (one `host_death`), and the run completes
//!   through retry + blacklist on the surviving host;
//! - a connection that stalls without dying (accepts, then never sends
//!   another frame) hits the heartbeat read timeout instead of wedging
//!   the poll loop;
//! - when the only host dies, the run fails — but because remote workers
//!   upload completed-task spills to the driver as frames, a resume
//!   against a fresh daemon re-infers only the never-spilled rows (no
//!   shared filesystem required).

use std::io::BufRead;

use spark_llm_eval::config::{BackendKind, CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::sched::plan::WorkerFault;

fn worker_exe() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_slleval"))
}

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r.worker_exe = Some(worker_exe());
    r
}

/// Deterministic-count task: cache disabled (1 provider call per row),
/// no speculation (no duplicated work), small batches.
fn task(executors: usize, backend: BackendKind, hosts: Vec<String>) -> EvalTask {
    let mut task = EvalTask::default();
    task.executors = executors;
    task.backend = backend;
    task.hosts = hosts;
    task.inference.batch_size = 5;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    task
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-remotebackend-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One `slleval serve-worker` daemon on an OS-assigned loopback port.
/// The address is parsed from the daemon's `listening on <addr>` banner,
/// so by the time `spawn` returns the listener is accepting.
struct WorkerDaemon {
    child: std::process::Child,
    addr: String,
}

impl WorkerDaemon {
    fn spawn() -> WorkerDaemon {
        let mut child = std::process::Command::new(worker_exe())
            .args(["serve-worker", "--listen", "127.0.0.1:0"])
            .stdout(std::process::Stdio::piped())
            .spawn()
            .expect("spawning serve-worker daemon");
        let stdout = child.stdout.take().expect("daemon stdout is piped");
        let mut line = String::new();
        std::io::BufReader::new(stdout).read_line(&mut line).expect("reading daemon banner");
        let addr = line
            .trim()
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected serve-worker banner: {line:?}"))
            .to_string();
        WorkerDaemon { child, addr }
    }
}

impl Drop for WorkerDaemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn remote_backend_matches_thread_backend_exactly() {
    let n = 60;
    let df = synth::generate_default(n, 81);
    let (d0, d1) = (WorkerDaemon::spawn(), WorkerDaemon::spawn());

    let thread =
        fast_runner().evaluate(&df, &task(3, BackendKind::Thread, Vec::new())).unwrap();
    let remote = fast_runner()
        .evaluate(&df, &task(3, BackendKind::Remote, vec![d0.addr.clone(), d1.addr.clone()]))
        .unwrap();

    // Metric identity: values, CIs, per-row scores, n.
    for name in ["exact_match", "token_f1"] {
        let (a, b) = (thread.metric(name).unwrap(), remote.metric(name).unwrap());
        assert_eq!(a.value, b.value, "{name} value");
        assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi), "{name} CI");
        assert_eq!(a.n, b.n, "{name} n");
        assert_eq!(
            thread.report(name).unwrap().values,
            remote.report(name).unwrap().values,
            "{name} per-row values"
        );
    }
    // Cost accounting identity: one deterministic call per row on both
    // backends, same per-call pricing.
    assert_eq!(remote.inference.api_calls, n as u64);
    assert_eq!(thread.inference.api_calls, remote.inference.api_calls);
    assert!(
        (thread.inference.total_cost_usd - remote.inference.total_cost_usd).abs() < 1e-9,
        "cost: thread {} vs remote {}",
        thread.inference.total_cost_usd,
        remote.inference.total_cost_usd
    );
    assert_eq!(remote.inference.sched.executor_deaths, 0);
    assert_eq!(remote.inference.sched.host_deaths, 0);
    assert_eq!(remote.failed_examples, thread.failed_examples);
}

#[test]
fn dead_host_settles_all_its_executors_at_once() {
    let n = 75;
    let df = synth::generate_default(n, 82);

    // Reference values from the thread backend.
    let reference =
        fast_runner().evaluate(&df, &task(4, BackendKind::Thread, Vec::new())).unwrap();

    // 4 executors round-robin over 2 daemons: executors {0, 2} on d0,
    // {1, 3} on d1. The fault aborts d1's whole process while executor 1
    // runs its first task, so executor 3's connection dies with it.
    let (d0, d1) = (WorkerDaemon::spawn(), WorkerDaemon::spawn());
    let mut runner = fast_runner();
    runner.worker_fault = Some(WorkerFault { executor_id: 1, kill_after_tasks: 1 });
    let mut t = task(4, BackendKind::Remote, vec![d0.addr.clone(), d1.addr.clone()]);
    t.scheduler.tasks_per_executor = 3;
    let result = runner.evaluate(&df, &t).unwrap();

    let sched = &result.inference.sched;
    assert_eq!(sched.executor_deaths, 2, "both of the dead host's executors: {sched:?}");
    assert_eq!(sched.host_deaths, 1, "{sched:?}");
    for eid in [1, 3] {
        assert!(
            sched.blacklisted_executors.contains(&eid),
            "executor {eid} on the dead host must take no more work: {sched:?}"
        );
    }
    assert!(sched.retries >= 1, "in-flight work must be retried on survivors");
    // The host kill changes *where* rows ran, never what they evaluate to.
    assert_eq!(
        result.report("exact_match").unwrap().values,
        reference.report("exact_match").unwrap().values
    );
    assert_eq!(
        result.metric("exact_match").unwrap().value,
        reference.metric("exact_match").unwrap().value
    );
}

#[test]
fn stalled_connection_hits_the_heartbeat_timeout() {
    use spark_llm_eval::sched::backend::run_plan;
    use spark_llm_eval::sched::plan::{MetricPlan, PlanEnv, PlanWork, TaskPlan};
    use spark_llm_eval::sched::remote::RemoteBackend;
    use spark_llm_eval::sched::wire::{read_frame, write_frame};
    use spark_llm_eval::sched::SchedulerConfig;
    use spark_llm_eval::util::json::Json;

    // A host that accepts, handshakes, then goes silent — alive at the
    // TCP level (no EOF) but sending neither heartbeats nor results.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let silent_host = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().expect("accepting driver connection");
        let hello = read_frame(&mut stream).expect("reading hello").expect("hello frame");
        assert_eq!(hello.str_or("type", ""), "hello");
        write_frame(&mut stream, &Json::obj(vec![("type", Json::str("ready"))])).unwrap();
        // Swallow whatever the driver sends (task frames, the eventual
        // shutdown) without ever answering; exit on EOF.
        let mut buf = [0u8; 1024];
        use std::io::Read;
        while let Ok(nread) = stream.read(&mut buf) {
            if nread == 0 {
                break;
            }
        }
    });

    let plan = TaskPlan {
        work: PlanWork::MetricScore(MetricPlan {
            metric: MetricConfig::new("exact_match", "lexical"),
            examples: Vec::new(),
        }),
        env: PlanEnv::default(),
        stage: None,
        fault: None,
    };
    let mut backend = RemoteBackend::new(
        &plan,
        1,
        5,
        vec![addr],
        std::time::Duration::from_millis(300),
        None,
    )
    .unwrap();
    let err = run_plan(
        10,
        1,
        &SchedulerConfig::default(),
        &mut backend,
        None,
        Vec::new(),
        None,
        None,
    )
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("no live executors"),
        "a hung socket must become a death, not a wedge: {err:#}"
    );
    drop(backend); // shuts the socket down, unblocking the fake host
    silent_host.join().unwrap();
}

#[test]
fn killed_host_resumes_from_uploaded_spills_with_zero_reinference() {
    let n = 80;
    let df = synth::generate_default(n, 83);

    // Reference: uninterrupted thread-backend run (row-identity oracle).
    let reference =
        fast_runner().evaluate(&df, &task(1, BackendKind::Thread, Vec::new())).unwrap();
    assert_eq!(reference.inference.api_calls, n as u64);

    // Crashing run: a single remote executor on a single daemon, 4
    // tasks, the daemon hard-killed during task 2 — with every executor
    // (and every host) dead, the run must fail.
    let dir = tmp_dir("kill-resume");
    let daemon = WorkerDaemon::spawn();
    let mut t = task(1, BackendKind::Remote, vec![daemon.addr.clone()]);
    t.scheduler.tasks_per_executor = 4;
    let mut runner = fast_runner();
    runner.worker_fault = Some(WorkerFault { executor_id: 0, kill_after_tasks: 2 });
    runner.attach_checkpoint(&dir, false).unwrap();
    let err = runner.evaluate(&df, &t).unwrap_err();
    assert!(format!("{err:#}").contains("no live executors"), "{err:#}");
    drop(daemon);

    // Resume against a *fresh* daemon (the old one is gone — nothing of
    // the crashed run survives on the worker side): completed tasks
    // restore from the spills the worker uploaded to the driver before
    // dying; only the never-spilled rows are re-inferred.
    let daemon = WorkerDaemon::spawn();
    t.hosts = vec![daemon.addr.clone()];
    let mut runner = fast_runner();
    runner.attach_checkpoint(&dir, true).unwrap();
    let resumed = runner.evaluate(&df, &t).unwrap();

    let restored = resumed.inference.sched.restored_rows;
    assert!(restored > 0, "the killed run must have uploaded completed-task spills");
    assert!(restored < n, "the killed run must not have finished");
    assert_eq!(
        resumed.inference.api_calls,
        (n - restored) as u64,
        "zero re-inference of spill-uploaded rows"
    );
    assert_eq!(resumed.inference.examples, n);

    // Row-identical results versus the uninterrupted reference.
    assert_eq!(
        resumed.report("exact_match").unwrap().values,
        reference.report("exact_match").unwrap().values
    );
    let (a, b) =
        (reference.metric("exact_match").unwrap(), resumed.metric("exact_match").unwrap());
    assert_eq!(a.value, b.value);
    assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi));
}

#[test]
fn cli_remote_flags_run_end_to_end() {
    // The `--backend remote --hosts` CLI path: a real daemon, the real
    // binary as the driver, and a healthy run reported over loopback.
    let daemon = WorkerDaemon::spawn();
    let out_path = tmp_dir("cli-run").join("result.json");
    std::fs::create_dir_all(out_path.parent().unwrap()).unwrap();
    let output = std::process::Command::new(worker_exe())
        .args([
            "run",
            "--fast",
            "--n",
            "40",
            "--seed",
            "84",
            "--executors",
            "2",
            "--backend",
            "remote",
            "--hosts",
            &daemon.addr,
            "--out",
        ])
        .arg(&out_path)
        .output()
        .expect("running slleval");
    assert!(
        output.status.success(),
        "slleval run --backend remote failed:\n{}\n{}",
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
    let result = std::fs::read_to_string(&out_path).unwrap();
    let json = spark_llm_eval::util::json::Json::parse(&result).unwrap();
    assert_eq!(json.get("inference").unwrap().usize_or("examples", 0), 40);
    assert_eq!(
        json.get("scheduler").unwrap().usize_or("executor_deaths", 99),
        0,
        "healthy run reports zero deaths"
    );
}
