//! Eval-service integration: real loopback sockets against an
//! in-process [`ServeDaemon`] (`slleval serve` minus the process
//! wrapper — CI's serve smoke step covers the binary path).
//!
//! Acceptance coverage:
//!
//! - full HTTP lifecycle: submit → observe `running` with at least one
//!   `/partial` snapshot carrying a bootstrap CI → `done` with a result
//!   bit-identical to a one-shot `EvalRunner::evaluate` of the same
//!   task against the same cache directory;
//! - `POST /runs/{id}/cancel` mid-inference stops issuing new tasks
//!   and settles the run as `cancelled` (result stays 409);
//! - malformed submissions answer 400 and the daemon keeps serving;
//! - multi-tenant cache sharing: a resubmitted task reports
//!   `api_calls == 0` with bit-identical metric values and CIs.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig, ServeConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::serve::ServeDaemon;
use spark_llm_eval::util::json::Json;

// ---------------------------------------------------------------- helpers

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-serve-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn serve_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.listen = "127.0.0.1:0".into();
    cfg
}

fn fast_config() -> SimServiceConfig {
    SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    }
}

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = fast_config();
    r
}

/// Real-clock runner with fault-free, latency-scaled sleeps — slow
/// enough that a polling client reliably observes intermediate states.
fn live_config(latency_scale: f64) -> SimServiceConfig {
    SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        latency_scale,
        ..Default::default()
    }
}

fn live_runner(latency_scale: f64) -> EvalRunner {
    let mut r = EvalRunner::new();
    r.service_config = live_config(latency_scale);
    r
}

/// One raw HTTP/1.1 exchange over a fresh connection.
fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let raw = raw_request(
        addr,
        &format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.map(str::len).unwrap_or(0),
            body.unwrap_or("")
        ),
    );
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no status line in response: {raw:?}"));
    let body_text = raw.split("\r\n\r\n").nth(1).unwrap_or("");
    let body = Json::parse(body_text).unwrap_or(Json::Null);
    (status, body)
}

/// Ship raw bytes, read to server-side close.
fn raw_request(addr: SocketAddr, payload: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect to daemon");
    stream.write_all(payload.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = stream.read_to_string(&mut out);
    out
}

fn submit_body(task: &EvalTask, n: usize, seed: u64) -> String {
    format!("{{\"task\": {}, \"data\": {{\"n\": {n}, \"seed\": {seed}}}}}", task.to_json())
}

fn submit(addr: SocketAddr, task: &EvalTask, n: usize, seed: u64) -> String {
    let (status, body) = request(addr, "POST", "/runs", Some(&submit_body(task, n, seed)));
    assert_eq!(status, 201, "submit failed: {body:?}");
    body.get("id").unwrap().as_str().unwrap().to_string()
}

fn state_of(addr: SocketAddr, id: &str) -> (String, Json) {
    let (status, body) = request(addr, "GET", &format!("/runs/{id}"), None);
    assert_eq!(status, 200, "{body:?}");
    (body.get("state").unwrap().as_str().unwrap().to_string(), body)
}

fn wait_terminal(addr: SocketAddr, id: &str, timeout: Duration) -> (String, Json) {
    let t0 = Instant::now();
    loop {
        let (state, body) = state_of(addr, id);
        if matches!(state.as_str(), "done" | "failed" | "cancelled") {
            return (state, body);
        }
        assert!(t0.elapsed() < timeout, "run {id} stuck in state {state}: {body:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------- lifecycle

/// The tentpole acceptance path: submit over a real socket, observe the
/// run `running` with a partial snapshot whose first settled metric
/// already carries a bootstrap CI, then fetch the `done` result and pin
/// it bit-for-bit against a one-shot `evaluate` of the same task on the
/// same shared cache.
#[test]
fn lifecycle_running_partial_ci_then_done_bit_identical_to_oneshot() {
    let cache_dir = tmp_dir("lifecycle-cache");
    // exact_match settles quickly; the llm_judge metric then runs ~80
    // sequential driver-side judge calls (~10ms median at scale 0.03),
    // holding the run observably `running` with a partial available.
    let mut task = EvalTask::default();
    task.task_id = "serve-lifecycle".into();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("helpfulness", "llm_judge"),
    ];
    task.executors = 4;
    let (n, seed) = (80, 11);

    let mut runner = live_runner(0.03);
    runner.open_cache(&cache_dir, CachePolicy::Enabled).unwrap();
    let daemon = ServeDaemon::start_with_runner(&serve_cfg(), runner).unwrap();
    let addr = daemon.addr();

    let (status, health) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "{health:?}");

    let id = submit(addr, &task, n, seed);
    let mut saw_running_partial_with_ci = false;
    let t0 = Instant::now();
    let (final_state, final_status) = loop {
        let (state, status_body) = state_of(addr, &id);
        if state == "running" && !saw_running_partial_with_ci {
            let (code, partial) = request(addr, "GET", &format!("/runs/{id}/partial"), None);
            assert_eq!(code, 200, "{partial:?}");
            if partial.get("metrics_done").unwrap().as_f64().unwrap() >= 1.0 {
                let metrics = match partial.get("metrics").unwrap() {
                    Json::Arr(items) => items.clone(),
                    other => panic!("partial metrics not an array: {other:?}"),
                };
                let first = &metrics[0];
                assert_eq!(first.get("name").unwrap().as_str().unwrap(), "exact_match");
                // The incremental estimate must carry its bootstrap CI,
                // not a bare point value.
                let lo = first.get("ci_lower").unwrap().as_f64().unwrap();
                let hi = first.get("ci_upper").unwrap().as_f64().unwrap();
                let value = first.get("value").unwrap().as_f64().unwrap();
                assert!(lo <= value && value <= hi, "CI {lo}..{hi} vs {value}");
                saw_running_partial_with_ci = true;
            }
        }
        if matches!(state.as_str(), "done" | "failed" | "cancelled") {
            break (state, status_body);
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "run stuck: {status_body:?}");
        std::thread::sleep(Duration::from_millis(5));
    };
    assert_eq!(final_state, "done", "{final_status:?}");
    assert!(
        saw_running_partial_with_ci,
        "never observed a running-state partial snapshot with a CI"
    );
    // The stage-2 snapshot is live on the status endpoint.
    let inference = final_status.get("inference").unwrap();
    assert!(inference.get("scheduler").is_ok(), "{inference:?}");

    let (status, served) = request(addr, "GET", &format!("/runs/{id}/result"), None);
    assert_eq!(status, 200, "{served:?}");
    daemon.shutdown();

    // One-shot path: same task, same data, same shared cache directory.
    let mut oneshot = live_runner(0.03);
    oneshot.open_cache(&cache_dir, CachePolicy::Enabled).unwrap();
    let df = synth::generate_default(n, seed);
    let direct = oneshot.evaluate(&df, &task).unwrap().to_json();

    // Bit-identical metrics: full JSON equality, values and CIs alike.
    assert_eq!(served.get("metrics").unwrap(), direct.get("metrics").unwrap());
    assert_eq!(served.get("task_id").unwrap(), direct.get("task_id").unwrap());
}

// ---------------------------------------------------------------- cancel

#[test]
fn cancel_mid_inference_settles_cancelled_and_result_stays_409() {
    // Slow enough to cancel mid-inference: ~100ms median latency,
    // small batches so the scheduler checks the abort flag often.
    let mut task = EvalTask::default();
    task.task_id = "serve-cancel".into();
    task.executors = 2;
    task.inference.batch_size = 5;
    task.scheduler.speculation = false;

    let daemon = ServeDaemon::start_with_runner(&serve_cfg(), live_runner(0.3)).unwrap();
    let addr = daemon.addr();
    let id = submit(addr, &task, 300, 7);

    // Wait until it is actually running, let some inference happen.
    let t0 = Instant::now();
    loop {
        let (state, body) = state_of(addr, &id);
        if state == "running" {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "never started: {body:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(150));

    let (status, body) = request(addr, "POST", &format!("/runs/{id}/cancel"), None);
    assert_eq!(status, 200, "{body:?}");

    let (state, body) = wait_terminal(addr, &id, Duration::from_secs(30));
    assert_eq!(state, "cancelled", "{body:?}");
    assert!(body.get("error").unwrap().as_str().is_ok(), "{body:?}");

    let (status, body) = request(addr, "GET", &format!("/runs/{id}/result"), None);
    assert_eq!(status, 409, "{body:?}");
    assert_eq!(body.get("state").unwrap().as_str().unwrap(), "cancelled");

    // Cancelling a terminal run is a no-op, not an error.
    let (status, body) = request(addr, "POST", &format!("/runs/{id}/cancel"), None);
    assert_eq!(status, 200);
    assert_eq!(body.get("state").unwrap().as_str().unwrap(), "cancelled");
    daemon.shutdown();
}

// ---------------------------------------------------------------- malformed

#[test]
fn malformed_requests_are_client_errors_and_daemon_keeps_serving() {
    let daemon = ServeDaemon::start_with_runner(&serve_cfg(), fast_runner()).unwrap();
    let addr = daemon.addr();

    // Broken JSON body → 400.
    let (status, body) = request(addr, "POST", "/runs", Some("{not json"));
    assert_eq!(status, 400, "{body:?}");
    assert!(body.get("error").is_ok());

    // Valid JSON, invalid task → 400.
    let (status, _) = request(addr, "POST", "/runs", Some("{\"task\": {\"executors\": 0}}"));
    assert_eq!(status, 400);

    // Not even HTTP → 400 on the raw connection.
    let raw = raw_request(addr, "EHLO not-http\r\n\r\n");
    assert!(raw.starts_with("HTTP/1.1 400 "), "{raw:?}");

    // Unknown routes and wrong verbs.
    let (status, _) = request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = request(addr, "DELETE", "/runs", None);
    assert_eq!(status, 405);
    let (status, _) = request(addr, "GET", "/runs/run-000099", None);
    assert_eq!(status, 404);

    // After all of the above, the daemon still serves real work.
    let (status, body) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "{body:?}");
    let id = submit(addr, &EvalTask::default(), 40, 3);
    let (state, body) = wait_terminal(addr, &id, Duration::from_secs(60));
    assert_eq!(state, "done", "{body:?}");

    // The registry lists the (only) successful run.
    let (status, listing) = request(addr, "GET", "/runs", None);
    assert_eq!(status, 200);
    let runs = match listing.get("runs").unwrap() {
        Json::Arr(items) => items.clone(),
        other => panic!("runs not an array: {other:?}"),
    };
    assert_eq!(runs.len(), 1, "{listing:?}");
    assert_eq!(runs[0].get("id").unwrap().as_str().unwrap(), id);
    daemon.shutdown();
}

// ---------------------------------------------------------------- cache

/// The multi-tenant guarantee: two sequential submissions of the same
/// EvalTask through one daemon share its response cache — the second
/// reports zero provider calls and bit-identical metric values/CIs.
#[test]
fn resubmission_pays_zero_inference_and_is_bit_identical() {
    let cache_dir = tmp_dir("tenant-cache");
    let mut runner = fast_runner();
    runner.open_cache(&cache_dir, CachePolicy::Enabled).unwrap();
    let daemon = ServeDaemon::start_with_runner(&serve_cfg(), runner).unwrap();
    let addr = daemon.addr();

    let mut task = EvalTask::default();
    task.task_id = "serve-tenant".into();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];

    let first = submit(addr, &task, 150, 21);
    let (state, body) = wait_terminal(addr, &first, Duration::from_secs(60));
    assert_eq!(state, "done", "{body:?}");
    let second = submit(addr, &task, 150, 21);
    let (state, body) = wait_terminal(addr, &second, Duration::from_secs(60));
    assert_eq!(state, "done", "{body:?}");

    let (_, result_a) = request(addr, "GET", &format!("/runs/{first}/result"), None);
    let (_, result_b) = request(addr, "GET", &format!("/runs/{second}/result"), None);
    daemon.shutdown();

    let inference_a = result_a.get("inference").unwrap();
    let inference_b = result_b.get("inference").unwrap();
    assert!(inference_a.get("api_calls").unwrap().as_f64().unwrap() > 0.0, "{inference_a:?}");
    assert_eq!(inference_b.get("api_calls").unwrap().as_f64().unwrap(), 0.0, "{inference_b:?}");
    assert!(inference_b.get("cache_hits").unwrap().as_f64().unwrap() >= 150.0, "{inference_b:?}");
    assert_eq!(
        inference_b.get("total_cost_usd").unwrap().as_f64().unwrap(),
        0.0,
        "{inference_b:?}"
    );
    // Bit-identical metric values and CIs, run to run.
    assert_eq!(result_a.get("metrics").unwrap(), result_b.get("metrics").unwrap());
}
