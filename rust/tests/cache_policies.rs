//! Cache-policy matrix + Delta-table durability tests over the live
//! pipeline: every policy × (cold, warm) cache state, plus time travel
//! and storage accounting (paper §3.2, §5.3).

use spark_llm_eval::cache::ResponseCache;
use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-policy-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn task_with(policy: CachePolicy) -> EvalTask {
    let mut t = EvalTask::default();
    t.inference.cache_policy = policy;
    t.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    t
}

/// Warm a cache dir with one Enabled run; returns the dataset used.
fn warm(dir: &std::path::Path, n: usize) -> spark_llm_eval::data::DataFrame {
    let df = synth::generate_default(n, 71);
    let mut runner = fast_runner();
    runner.open_cache(dir, CachePolicy::Enabled).unwrap();
    runner.evaluate(&df, &task_with(CachePolicy::Enabled)).unwrap();
    df
}

#[test]
fn enabled_cold_then_warm() {
    let dir = tmp("enabled");
    let df = warm(&dir, 100);
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
    let r = runner.evaluate(&df, &task_with(CachePolicy::Enabled)).unwrap();
    assert_eq!(r.inference.cache_hits as usize, df.len());
    assert_eq!(r.inference.api_calls, 0);
}

#[test]
fn read_only_never_writes() {
    let dir = tmp("readonly");
    let df = warm(&dir, 60);
    // New data → misses; read-only must not persist them.
    let df2 = synth::generate_default(60, 72);
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::ReadOnly).unwrap();
    let r = runner.evaluate(&df2, &task_with(CachePolicy::ReadOnly)).unwrap();
    assert!(r.inference.api_calls > 0);
    // Reopen: still only the originally-warmed entries.
    let cache = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    let warmed_entries = cache.len().unwrap();
    let mut runner2 = fast_runner();
    runner2.open_cache(&dir, CachePolicy::ReadOnly).unwrap();
    let r2 = runner2.evaluate(&df2, &task_with(CachePolicy::ReadOnly)).unwrap();
    assert!(r2.inference.api_calls > 0, "still misses after read-only run");
    let reopened = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    assert_eq!(reopened.len().unwrap(), warmed_entries);
    let _ = df;
}

#[test]
fn write_only_always_infers_but_caches() {
    let dir = tmp("writeonly");
    let df = warm(&dir, 50);
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::WriteOnly).unwrap();
    let r = runner.evaluate(&df, &task_with(CachePolicy::WriteOnly)).unwrap();
    // Warm entries exist but write-only skips lookup → all API calls.
    assert_eq!(r.inference.cache_hits, 0);
    assert!(r.inference.api_calls as usize >= df.len());
    // And the entries are (re)persisted for later replay.
    let mut replay_runner = fast_runner();
    replay_runner.open_cache(&dir, CachePolicy::Replay).unwrap();
    let rr = replay_runner.evaluate(&df, &task_with(CachePolicy::Replay)).unwrap();
    assert_eq!(rr.inference.api_calls, 0);
}

#[test]
fn disabled_ignores_warm_cache() {
    let dir = tmp("disabled");
    let df = warm(&dir, 50);
    let mut runner = fast_runner();
    // Note: Disabled → runner drops the cache entirely.
    runner.open_cache(&dir, CachePolicy::Disabled).unwrap();
    let r = runner.evaluate(&df, &task_with(CachePolicy::Disabled)).unwrap();
    assert_eq!(r.inference.cache_hits, 0);
    assert!(r.inference.api_calls as usize >= df.len());
}

#[test]
fn replay_identical_metrics_and_judge_coverage() {
    // Replay must cover judge calls too (they flow through the same cache).
    let dir = tmp("replay-judge");
    let df = synth::generate_default(60, 73);
    let mut task = task_with(CachePolicy::Enabled);
    task.metrics.push(
        MetricConfig::new("helpfulness", "llm_judge")
            .with_param("rubric", spark_llm_eval::util::json::Json::str("helpfulness 1-5")),
    );
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
    let r1 = runner.evaluate(&df, &task).unwrap();

    let mut task2 = task.clone();
    task2.inference.cache_policy = CachePolicy::Replay;
    let mut runner2 = fast_runner();
    runner2.open_cache(&dir, CachePolicy::Replay).unwrap();
    let r2 = runner2.evaluate(&df, &task2).unwrap();
    assert_eq!(r2.inference.api_calls, 0);
    assert_eq!(
        r1.metric("helpfulness").unwrap().value,
        r2.metric("helpfulness").unwrap().value,
        "judge scores must replay bit-identically"
    );
}

#[test]
fn time_travel_reproduces_first_population() {
    let dir = tmp("timetravel");
    // Population 1.
    let df1 = synth::generate_default(30, 74);
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
    runner.evaluate(&df1, &task_with(CachePolicy::Enabled)).unwrap();
    let v1 = ResponseCache::open(&dir, CachePolicy::ReadOnly)
        .unwrap()
        .current_version()
        .unwrap()
        .unwrap();
    let len_v1 = ResponseCache::open_at_version(&dir, v1).unwrap().len().unwrap();

    // Population 2 extends the cache.
    let df2 = synth::generate_default(30, 75);
    let mut runner2 = fast_runner();
    runner2.open_cache(&dir, CachePolicy::Enabled).unwrap();
    runner2.evaluate(&df2, &task_with(CachePolicy::Enabled)).unwrap();

    // Historical read sees exactly the first population.
    let old = ResponseCache::open_at_version(&dir, v1).unwrap();
    assert_eq!(old.len().unwrap(), len_v1);
    let new = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    assert!(new.len().unwrap() > old.len().unwrap());
}

#[test]
fn storage_accounting_and_compaction() {
    let dir = tmp("storage");
    warm(&dir, 200);
    let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
    let before = cache.storage_bytes().unwrap();
    assert!(before > 0);
    cache.compact().unwrap();
    let after = cache.storage_bytes().unwrap();
    assert!(after <= before);
    // Content preserved post-compaction.
    let df = synth::generate_default(200, 71);
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::Replay).unwrap();
    let r = runner.evaluate(&df, &task_with(CachePolicy::Replay)).unwrap();
    assert_eq!(r.inference.api_calls, 0);
}

#[test]
fn cross_model_cache_isolation() {
    // Same prompts, different model → distinct cache keys → replay for
    // model B must fail after warming only model A.
    let dir = tmp("isolation");
    let df = warm(&dir, 30);
    let mut task_b = task_with(CachePolicy::Replay);
    task_b.model.model_name = "gpt-4o-mini".into();
    let mut runner = fast_runner();
    runner.open_cache(&dir, CachePolicy::Replay).unwrap();
    assert!(runner.evaluate(&df, &task_b).is_err(), "cache must be model-specific");
}
