//! Tier-1 gate: `slleval lint` must pass on this repository itself.
//!
//! This is the same pass the CLI subcommand and the CI step run — a
//! violation introduced anywhere in `rust/{src,tests,benches}` fails
//! `cargo test -q` with the rendered `file:line` diagnostics in the
//! assertion message. Suppression policy and the rule catalog live in
//! DESIGN.md ("Static analysis").

use spark_llm_eval::analysis;
use std::path::Path;

/// The repo root: the crate lives at `<root>/rust`.
fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate dir has a parent")
}

#[test]
fn repository_lints_clean() {
    let out = analysis::run(repo_root(), None).expect("lint pass runs");
    assert!(out.files_scanned > 20, "lint walked only {} files — wrong root?", out.files_scanned);
    let rendered: Vec<String> = out.violations.iter().map(|d| d.render()).collect();
    assert!(
        out.clean(),
        "`slleval lint` found {} violation(s); fix them or add a justified \
         `lint:allow` / baseline entry (see DESIGN.md):\n{}",
        rendered.len(),
        rendered.join("\n")
    );
}

#[test]
fn suppressions_all_carry_justifications() {
    let out = analysis::run(repo_root(), None).expect("lint pass runs");
    // The tree dogfoods its own lint: the deliberate wall-clock telemetry
    // sites are suppressed inline, so an empty list means the rule (or
    // the allow parser) silently stopped matching.
    assert!(!out.suppressed.is_empty(), "expected the dogfooded inline allows to show up");
    for (d, reason) in &out.suppressed {
        assert!(!reason.trim().is_empty(), "suppressed without a written reason: {}", d.render());
    }
}

#[test]
fn shipped_baseline_is_not_stale() {
    // Stale entries already fail `repository_lints_clean` (they surface
    // as `baseline` violations); this meta-test pins that contract and
    // additionally validates the shipped file parses and every entry is
    // justified.
    let path = repo_root().join(analysis::DEFAULT_BASELINE);
    let entries = match std::fs::read_to_string(&path) {
        Ok(text) => analysis::parse_baseline(&text).expect("shipped baseline parses"),
        Err(_) => Vec::new(), // no baseline checked in — nothing to go stale
    };
    for e in &entries {
        assert!(
            !e.reason.trim().is_empty(),
            "baseline entry for {} ({}, rule {}) has no justification",
            e.file,
            e.subject,
            e.rule
        );
    }
    let out = analysis::run(repo_root(), None).expect("lint pass runs");
    let stale: Vec<String> = out
        .violations
        .iter()
        .filter(|d| d.rule == "baseline")
        .map(|d| d.render())
        .collect();
    assert!(stale.is_empty(), "stale or unjustified baseline entries:\n{}", stale.join("\n"));
}
