//! Storage-subsystem integration tests: golden `_delta_log` fixture
//! replay + byte round-trip, writer determinism under pinned clocks, and
//! two-writer maintenance races (paper §3.2: the cache is a real
//! Delta-protocol table that concurrent workers and external readers
//! share safely).

use spark_llm_eval::storage::{is_commit_conflict, maintain, Action, DeltaTable};
use spark_llm_eval::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/storage/golden_table")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-storage-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn row(k: &str, v: f64) -> Json {
    Json::obj(vec![("key", Json::str(k)), ("value", Json::num(v))])
}

/// The checked-in golden table (written by an external tool, not this
/// crate) replays to the pinned state: one live clustered file, one
/// tombstone, working stats-based skipping, and time travel to v0.
#[test]
fn golden_fixture_replays_to_pinned_state() {
    let table = DeltaTable::open(&golden_dir()).unwrap();
    let state = table.state(None).unwrap().unwrap();
    assert_eq!(state.version, 1);
    assert_eq!(state.files.len(), 1);
    assert_eq!(state.tombstones.len(), 1);
    assert_eq!(state.files[0].path, "data/part-00000000000000000001-0000-golden.jsonl.gz");
    assert_eq!(state.tombstones[0].path, "data/part-00000000000000000000-0000-golden.jsonl.gz");
    assert_eq!(state.num_records(), Some(3));

    // Stats columns come from the persisted metaData configuration, not
    // this handle's defaults.
    let cols = table.effective_stats_columns(state.metadata.as_ref());
    assert_eq!(cols, vec!["key".to_string(), "model_name".to_string()]);

    // Skipping: in-range probes hit the one live file, out-of-range none.
    assert_eq!(state.candidates("key", "mike").len(), 1);
    assert_eq!(state.candidates("key", "zzzz").len(), 0);
    assert_eq!(state.candidates("model_name", "gpt-4o").len(), 1);

    let snap = table.snapshot_by_key("key", None).unwrap();
    assert_eq!(snap.len(), 3);
    assert_eq!(snap["alpha"].f64_or("value", -1.0), 1.0);
    assert_eq!(snap["mike"].f64_or("value", -1.0), 2.0);
    assert_eq!(snap["zulu"].f64_or("value", -1.0), 3.0);

    // Time travel: v0 still readable (its tombstoned file is on disk).
    let old = table.snapshot_by_key("key", Some(0)).unwrap();
    assert_eq!(old.len(), 1);
    assert_eq!(old["alpha"].f64_or("value", -1.0), 0.0);

    let history = table.history().unwrap();
    assert_eq!(history.len(), 2);
    assert_eq!(history[0].1, "WRITE");
    assert_eq!(history[1].1, "MERGE");
}

/// Every action line in the golden `_delta_log` parses and re-serializes
/// to the identical bytes — the writer emits exactly the spec shapes the
/// fixture pins (field names, key order, embedded stats string, number
/// formatting).
#[test]
fn golden_fixture_actions_round_trip_byte_identical() {
    let log_dir = golden_dir().join("_delta_log");
    let mut checked = 0;
    for version in 0..=1u64 {
        let path = log_dir.join(format!("{version:020}.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let action = Action::parse_line(line).unwrap().expect("known action type");
            assert_eq!(action.to_line(), line, "round-trip drift in {path:?}");
            checked += 1;
        }
    }
    assert_eq!(checked, 7, "fixture holds 7 pinned action lines");
}

fn dir_bytes(root: &Path, sub: &str) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(root.join(sub)).unwrap() {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().into_owned();
        out.insert(format!("{sub}/{name}"), std::fs::read(entry.path()).unwrap());
    }
    out
}

fn build_pinned(dir: &Path) {
    let mut table = DeltaTable::open_with_stats(dir, &["key"]).unwrap();
    table.pin_for_fixtures(1_700_000_000_000, "fixturewriter");
    table.append(&[row("alpha", 1.0), row("mike", 2.0)]).unwrap();
    table.append(&[row("golf", 3.0), row("zulu", 4.0)]).unwrap();
    table.upsert(&[row("mike", 5.0)], "key").unwrap();
    maintain::optimize(&table, u64::MAX).unwrap();
    maintain::vacuum(&table, 0, false).unwrap();
}

/// With the clock and writer discriminator pinned, two independent builds
/// of the same commit sequence produce byte-identical `_delta_log` and
/// `data/` trees — the determinism the golden fixture (and CI interop
/// checks) rely on.
#[test]
fn pinned_writer_is_byte_reproducible() {
    let a = tmp("repro-a");
    let b = tmp("repro-b");
    build_pinned(&a);
    build_pinned(&b);
    for sub in ["_delta_log", "data"] {
        let fa = dir_bytes(&a, sub);
        let fb = dir_bytes(&b, sub);
        assert_eq!(
            fa.keys().collect::<Vec<_>>(),
            fb.keys().collect::<Vec<_>>(),
            "{sub} file sets differ"
        );
        for (name, bytes) in &fa {
            assert_eq!(Some(bytes), fb.get(name).as_deref(), "{name} bytes differ");
        }
    }
    // The pinned protocol line is exactly the spec shape, first in commit 0.
    let commit0 =
        std::fs::read_to_string(a.join("_delta_log").join(format!("{:020}.json", 0))).unwrap();
    assert_eq!(
        commit0.lines().next().unwrap(),
        "{\"protocol\":{\"minReaderVersion\":1,\"minWriterVersion\":2}}"
    );
}

/// Optimize racing a concurrent appender: exactly one writer owns each
/// log version, losers see a retryable commit conflict, and no row is
/// ever lost — the rewrite is a single add+remove commit, so a conflicted
/// optimize has changed nothing.
#[test]
fn optimize_vs_append_race_loses_nothing() {
    let dir = tmp("optimize-race");
    let table = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
    for i in 0..8 {
        table.append(&[row(&format!("seed{i:02}"), i as f64)]).unwrap();
    }

    let appender = std::thread::spawn({
        let dir = dir.clone();
        move || {
            let table = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
            for i in 0..20 {
                loop {
                    match table.append(&[row(&format!("app{i:02}"), i as f64)]) {
                        Ok(_) => break,
                        Err(e) if is_commit_conflict(&e) => continue,
                        Err(e) => panic!("appender hit a non-conflict error: {e:?}"),
                    }
                }
            }
        }
    });
    // Optimize repeatedly while the appender runs; conflicts are expected
    // and must be the only failure mode.
    for _ in 0..6 {
        match maintain::optimize(&table, u64::MAX) {
            Ok(_) => {}
            Err(e) if is_commit_conflict(&e) => {}
            Err(e) => panic!("optimize hit a non-conflict error: {e:?}"),
        }
    }
    appender.join().unwrap();

    // A quiesced retry loop must succeed (or have nothing left to do).
    loop {
        match maintain::optimize(&table, u64::MAX) {
            Ok(_) => break,
            Err(e) if is_commit_conflict(&e) => continue,
            Err(e) => panic!("optimize hit a non-conflict error: {e:?}"),
        }
    }

    let snap = table.snapshot_by_key("key", None).unwrap();
    assert_eq!(snap.len(), 28, "8 seeds + 20 appends all survive the race");
    for i in 0..8 {
        assert!(snap.contains_key(&format!("seed{i:02}")));
    }
    for i in 0..20 {
        assert!(snap.contains_key(&format!("app{i:02}")));
    }
    // The log is a contiguous run of single-owner versions, and commit
    // files are never deleted by maintenance.
    let latest = table.current_version().unwrap().unwrap();
    for v in 0..=latest {
        let path = dir.join("_delta_log").join(format!("{v:020}.json"));
        assert!(path.exists(), "missing commit file for version {v}");
    }
}

/// Vacuum racing a concurrent appender: live data and fresh orphans are
/// untouchable — vacuum only reclaims tombstoned paths (never reused) and
/// orphans older than the grace window.
#[test]
fn vacuum_vs_append_race_preserves_live_data() {
    let dir = tmp("vacuum-race");
    let table = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
    for i in 0..4 {
        table.append(&[row(&format!("seed{i:02}"), i as f64)]).unwrap();
    }
    // Create reclaimable tombstones before the race.
    table.upsert(&[row("seed00", 10.0), row("seed01", 11.0)], "key").unwrap();
    // A fresh orphan, as a crashed writer would leave: inside the grace
    // window, so no vacuum below may touch it.
    let orphan = dir.join("data").join("part-inflight-0000-orphan.jsonl.gz");
    std::fs::write(&orphan, b"uncommitted writer data").unwrap();

    let appender = std::thread::spawn({
        let dir = dir.clone();
        move || {
            let table = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
            for i in 0..15 {
                loop {
                    match table.append(&[row(&format!("app{i:02}"), i as f64)]) {
                        Ok(_) => break,
                        Err(e) if is_commit_conflict(&e) => continue,
                        Err(e) => panic!("appender hit a non-conflict error: {e:?}"),
                    }
                }
            }
        }
    });
    let mut reclaimed = 0u64;
    for _ in 0..5 {
        // vacuum retries its bracketing commits internally, so conflicts
        // with the appender are absorbed.
        let outcome = maintain::vacuum(&table, 0, false).unwrap();
        reclaimed += outcome.deleted_files;
    }
    appender.join().unwrap();

    assert!(reclaimed >= 2, "the two pre-race tombstoned files get reclaimed");
    assert!(orphan.exists(), "fresh orphan survives every vacuum");

    // Every live row is present and every live file readable.
    let snap = table.snapshot_by_key("key", None).unwrap();
    assert_eq!(snap.len(), 19, "4 seeds + 15 appends");
    assert_eq!(snap["seed00"].f64_or("value", -1.0), 10.0);
    let state = table.state(None).unwrap().unwrap();
    for f in &state.files {
        assert!(dir.join(&f.path).exists(), "live file {} vanished", f.path);
        table.read_file(&f.path).unwrap();
    }
}
