//! Backend scheduler (driver loop) + thread-backend plan execution.
//!
//! Everything here runs in process: the driver's claim / steal /
//! speculate / retry / blacklist / death machinery is exercised with a
//! test-local `PlanTaskRunner` (no provider engines), and the
//! plan-built inference executor is pinned bit-for-bit against the
//! legacy closure scheduler — the PR-4-style compatibility gate for the
//! `ThreadBackend`.

use std::sync::Arc;

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig, SchedulerConfig};
use spark_llm_eval::coordinator::{EvalRunner, PlanExecutor, PlanHost, RowInference};
use spark_llm_eval::metrics::Example;
use spark_llm_eval::providers::simulated::{SimService, SimServiceConfig};
use spark_llm_eval::ratelimit::{Clock, VirtualClock};
use spark_llm_eval::sched::backend::{
    run_plan, PlanTaskRunner, RunnerFactory, TaskResultMsg, TaskSpec, ThreadBackend,
};
use spark_llm_eval::sched::plan::{
    InferencePlan, MetricPlan, PlanEnv, PlanWork, TaskPlan, WorkerFault,
};
use spark_llm_eval::sched::SchedulerStats;
use spark_llm_eval::util::json::Json;

/// Trivial runner: row i maps to Json::num(i); optionally errors on a
/// chosen executor, optionally sleeps per task (so every executor gets
/// to participate before the queues drain — fault-injection tests need
/// the targeted executor to actually receive work).
struct IdentityRunner {
    eid: usize,
    fail_on: Option<usize>,
    delay_ms: u64,
}

impl PlanTaskRunner for IdentityRunner {
    fn run(&mut self, spec: &TaskSpec, batch_size: usize) -> anyhow::Result<TaskResultMsg> {
        if self.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
        }
        if self.fail_on == Some(self.eid) {
            anyhow::bail!("executor {} always fails", self.eid);
        }
        let rows: Vec<Json> = (spec.start..spec.end).map(|i| Json::num(i as f64)).collect();
        Ok(TaskResultMsg {
            task_id: spec.task_id,
            start: spec.start,
            end: spec.end,
            attempt: spec.attempt,
            speculative: spec.speculative,
            rows_processed: rows.len(),
            batches: (spec.end - spec.start).div_ceil(batch_size.max(1)),
            rows,
            busy_secs: 0.0,
            peak_in_flight: 1,
            api_calls: (spec.end - spec.start) as u64,
            retries: 0,
            cost_usd: 0.0,
        })
    }
}

fn identity_factory(fail_on: Option<usize>, delay_ms: u64) -> RunnerFactory {
    Arc::new(move |eid| {
        Ok(Box::new(IdentityRunner { eid, fail_on, delay_ms }) as Box<dyn PlanTaskRunner>)
    })
}

fn expect_rows(rows: &[Json], n: usize) {
    assert_eq!(rows.len(), n);
    for (i, v) in rows.iter().enumerate() {
        assert_eq!(v.as_f64().unwrap(), i as f64, "row {i}");
    }
}

#[test]
fn driver_loop_is_row_exact_across_configs() {
    for (n, executors, tasks_per_executor) in
        [(0usize, 3usize, 2usize), (1, 4, 3), (37, 3, 1), (120, 4, 4), (200, 6, 2)]
    {
        let cfg = SchedulerConfig {
            tasks_per_executor,
            speculation: false,
            ..Default::default()
        };
        let mut backend = ThreadBackend::new(executors, 10, None, identity_factory(None, 0));
        let out =
            run_plan(n, executors, &cfg, &mut backend, None, Vec::new(), None, None).unwrap();
        expect_rows(&out.rows, n);
        assert_eq!(out.api_calls, n as u64, "per-task spend accumulates");
        assert_eq!(out.sched.executor_deaths, 0);
    }
}

#[test]
fn thread_backend_death_is_retried_counted_and_survived() {
    // Executor 1 dies on its first task; the survivors absorb its queue
    // and retry the lost in-flight task. Output stays row-exact.
    let n = 90;
    let cfg = SchedulerConfig {
        tasks_per_executor: 3,
        speculation: false,
        ..Default::default()
    };
    let fault = WorkerFault { executor_id: 1, kill_after_tasks: 1 };
    let mut backend = ThreadBackend::new(3, 10, Some(fault), identity_factory(None, 5));
    let out = run_plan(n, 3, &cfg, &mut backend, None, Vec::new(), None, None).unwrap();
    expect_rows(&out.rows, n);
    assert_eq!(out.sched.executor_deaths, 1, "{:?}", out.sched);
    assert!(out.sched.retries >= 1, "the lost in-flight task must be retried");
    assert!(
        out.sched.blacklisted_executors.contains(&1),
        "a dead executor takes no more work: {:?}",
        out.sched
    );
}

#[test]
fn all_executors_dead_fails_with_clear_error() {
    let cfg = SchedulerConfig { tasks_per_executor: 4, ..Default::default() };
    let fault = WorkerFault { executor_id: 0, kill_after_tasks: 2 };
    let mut backend = ThreadBackend::new(1, 10, Some(fault), identity_factory(None, 0));
    let err =
        run_plan(80, 1, &cfg, &mut backend, None, Vec::new(), None, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no live executors"), "{msg}");
}

#[test]
fn failing_executor_is_blacklisted_and_job_completes() {
    let n = 60;
    let cfg = SchedulerConfig {
        tasks_per_executor: 3,
        speculation: false,
        max_task_attempts: 4,
        blacklist_after: 2,
        ..Default::default()
    };
    let mut backend = ThreadBackend::new(3, 10, None, identity_factory(Some(1), 2));
    let out = run_plan(n, 3, &cfg, &mut backend, None, Vec::new(), None, None).unwrap();
    expect_rows(&out.rows, n);
    assert!(out.sched.blacklisted_executors.contains(&1), "{:?}", out.sched);
    assert_eq!(out.sched.executor_deaths, 0, "failures are not deaths");
    assert!(out.sched.retries >= 1);
}

#[test]
fn restored_ranges_are_injected_not_reexecuted() {
    // Rows [0, 50) come pre-completed with sentinel values: the driver
    // must keep them verbatim and only execute the gap.
    let n = 120;
    let cfg = SchedulerConfig { speculation: false, ..Default::default() };
    let restored: Vec<(usize, usize, Vec<Json>)> =
        vec![(0, 50, (0..50).map(|i| Json::num(10_000.0 + i as f64)).collect())];
    let mut backend = ThreadBackend::new(4, 10, None, identity_factory(None, 0));
    let out = run_plan(n, 4, &cfg, &mut backend, None, restored, None, None).unwrap();
    assert_eq!(out.rows.len(), n);
    for i in 0..50 {
        assert_eq!(out.rows[i].as_f64().unwrap(), 10_000.0 + i as f64, "restored row {i}");
    }
    for i in 50..n {
        assert_eq!(out.rows[i].as_f64().unwrap(), i as f64, "fresh row {i}");
    }
    assert_eq!(out.sched.restored_tasks, 1);
    assert_eq!(out.sched.restored_rows, 50);
    assert_eq!(out.api_calls, (n - 50) as u64, "restored rows cost nothing");
}

#[test]
fn invalid_restored_ranges_are_rejected() {
    let cfg = SchedulerConfig::default();
    let bad: Vec<(usize, usize, Vec<Json>)> = vec![
        (0, 10, (0..10).map(|i| Json::num(i as f64)).collect()),
        (5, 15, (5..15).map(|i| Json::num(i as f64)).collect()),
    ];
    let mut backend = ThreadBackend::new(2, 5, None, identity_factory(None, 0));
    assert!(run_plan(20, 2, &cfg, &mut backend, None, bad, None, None).is_err());

    let bad: Vec<(usize, usize, Vec<Json>)> = vec![(0, 10, vec![Json::num(1.0)])];
    let mut backend = ThreadBackend::new(2, 5, None, identity_factory(None, 0));
    assert!(run_plan(20, 2, &cfg, &mut backend, None, bad, None, None).is_err());
}

#[test]
fn scheduler_stats_merge_accumulates_deaths() {
    let mut a = SchedulerStats { executor_deaths: 1, ..Default::default() };
    let b = SchedulerStats { executor_deaths: 2, ..Default::default() };
    a.merge(&b);
    assert_eq!(a.executor_deaths, 3);
    let j = a.to_json();
    assert_eq!(j.get("executor_deaths").unwrap().as_f64().unwrap(), 3.0);
}

// ------------------------------------------------------------------------
// Plan-built inference executors on the thread backend, pinned against
// the legacy closure scheduler.

fn fast_service_config() -> SimServiceConfig {
    SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    }
}

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = fast_service_config();
    r
}

fn prompts(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("Question: what is the capital of country {i}?")).collect()
}

/// Build the inference plan + a thread backend sharing one simulated
/// endpoint, mirroring what the runner's process path ships to workers.
fn inference_plan(task: &EvalTask, prompts: &[String]) -> (Arc<TaskPlan>, ThreadBackend) {
    let plan = Arc::new(TaskPlan {
        work: PlanWork::Inference(InferencePlan {
            model: task.model.clone(),
            inference: task.inference.clone(),
            executors: task.executors,
            seed: task.statistics.seed,
            prompts: prompts.to_vec(),
        }),
        env: PlanEnv {
            service: fast_service_config(),
            virtual_clock: true,
            cache_dir: None,
            cache_policy: CachePolicy::Disabled,
        },
        stage: None,
        fault: None,
    });
    let clock: Arc<dyn Clock> = VirtualClock::new();
    let service = SimService::new(&task.model.provider, fast_service_config(), clock.clone());
    let factory = spark_llm_eval::coordinator::plan_exec::thread_runner_factory(
        plan.clone(),
        clock,
        Some(service),
        None,
    );
    let backend =
        ThreadBackend::new(task.executors, task.inference.batch_size, None, factory);
    (plan, backend)
}

#[test]
fn thread_backend_inference_is_bit_identical_to_legacy_scheduler() {
    // Pinned schedule (one task per executor, no stealing/speculation):
    // every engine sees the same call sequence as the legacy closure
    // path, so the full RowInference encoding — response, cost, latency
    // draw, attempts — must round-trip identically.
    let n = 60;
    let mut task = EvalTask::default();
    task.executors = 4;
    task.inference.batch_size = 7;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler = SchedulerConfig {
        tasks_per_executor: 1,
        work_stealing: false,
        speculation: false,
        adaptive_split: false,
        ..Default::default()
    };
    let prompts = prompts(n);

    let runner = fast_runner();
    let (legacy_rows, legacy_stats) = runner.run_inference(&prompts, &task).unwrap();

    let (_plan, mut backend) = inference_plan(&task, &prompts);
    let out = run_plan(
        n,
        task.executors,
        &task.scheduler,
        &mut backend,
        None,
        Vec::new(),
        None,
        None,
    )
    .unwrap();
    assert_eq!(out.rows.len(), n);
    for (i, (json, legacy)) in out.rows.iter().zip(&legacy_rows).enumerate() {
        assert_eq!(json, &legacy.to_json(), "row {i} must be bit-identical");
    }
    assert_eq!(out.api_calls, legacy_stats.api_calls, "same provider call count");
    assert!(
        (out.cost_usd - legacy_stats.total_cost_usd).abs() < 1e-12,
        "same spend: {} vs {}",
        out.cost_usd,
        legacy_stats.total_cost_usd
    );
}

#[test]
fn thread_backend_inference_values_match_legacy_under_dynamic_scheduling() {
    // With stealing on, schedules (and so per-call latency draws) differ,
    // but responses, costs, and attempt counts are content-deterministic.
    let n = 90;
    let mut task = EvalTask::default();
    task.executors = 3;
    task.inference.batch_size = 8;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    let prompts = prompts(n);

    let runner = fast_runner();
    let (legacy_rows, legacy_stats) = runner.run_inference(&prompts, &task).unwrap();

    let (_plan, mut backend) = inference_plan(&task, &prompts);
    let out = run_plan(
        n,
        task.executors,
        &task.scheduler,
        &mut backend,
        None,
        Vec::new(),
        None,
        None,
    )
    .unwrap();
    let rows: Vec<RowInference> =
        out.rows.iter().map(|v| RowInference::from_json(v).unwrap()).collect();
    for (i, (a, b)) in rows.iter().zip(&legacy_rows).enumerate() {
        assert_eq!(a.response, b.response, "row {i} response");
        assert_eq!(a.attempts, b.attempts, "row {i} attempts");
        assert!((a.cost_usd - b.cost_usd).abs() < 1e-12, "row {i} cost");
    }
    assert_eq!(out.api_calls, legacy_stats.api_calls);
    assert!((out.cost_usd - legacy_stats.total_cost_usd).abs() < 1e-12);
}

#[test]
fn metric_plan_scores_like_direct_scoring() {
    let examples: Vec<Example> = (0..40)
        .map(|i| Example {
            response: if i % 3 == 0 { "paris".into() } else { "rome".into() },
            reference: "paris".into(),
            ..Default::default()
        })
        .collect();
    let plan = Arc::new(TaskPlan {
        work: PlanWork::MetricScore(MetricPlan {
            metric: MetricConfig::new("exact_match", "lexical"),
            examples: examples.clone(),
        }),
        env: PlanEnv::default(),
        stage: None,
        fault: None,
    });
    let clock: Arc<dyn Clock> = VirtualClock::new();
    let factory = spark_llm_eval::coordinator::plan_exec::thread_runner_factory(
        plan.clone(),
        clock,
        None,
        None,
    );
    let mut backend = ThreadBackend::new(2, 10, None, factory);
    let out = run_plan(
        examples.len(),
        2,
        &SchedulerConfig::default(),
        &mut backend,
        None,
        Vec::new(),
        None,
        None,
    )
    .unwrap();
    assert_eq!(out.rows.len(), 40);
    for (i, v) in out.rows.iter().enumerate() {
        let expected = if i % 3 == 0 { 1.0 } else { 0.0 };
        assert_eq!(v.as_f64().unwrap(), expected, "row {i}");
    }
}

#[test]
fn plan_executor_rejects_out_of_bounds_tasks() {
    let plan = Arc::new(TaskPlan {
        work: PlanWork::MetricScore(MetricPlan {
            metric: MetricConfig::new("exact_match", "lexical"),
            examples: vec![Example::default(); 5],
        }),
        env: PlanEnv::default(),
        stage: None,
        fault: None,
    });
    let clock: Arc<dyn Clock> = VirtualClock::new();
    let host = PlanHost { clock, service: None, cache: None };
    let mut exec = PlanExecutor::new(plan, 0, host).unwrap();
    let spec = TaskSpec { task_id: 0, start: 2, end: 9, attempt: 1, speculative: false };
    assert!(exec.run(&spec, 10).is_err());
}
