//! Crash/resume integration tests: a scheduled job is killed mid-flight,
//! then resumed from its checkpoint manifest; the stitched output must be
//! identical to an uninterrupted run with completed ranges never
//! re-executed.

use spark_llm_eval::checkpoint::RunCheckpoint;
use spark_llm_eval::data::{DataFrame, Value};
use spark_llm_eval::sched::{
    run_scheduled, run_scheduled_ext, SchedulerConfig, TaskCheckpoint, TaskSink,
};
use spark_llm_eval::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

fn frame(n: usize) -> DataFrame {
    DataFrame::from_columns(vec![("x", (0..n as i64).map(Value::Int).collect::<Vec<_>>())])
        .unwrap()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-resume-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn encode(v: &f64) -> Json {
    Json::num(*v)
}

fn decode(j: &Json) -> anyhow::Result<f64> {
    Ok(j.as_f64()?)
}

fn cfg() -> SchedulerConfig {
    SchedulerConfig {
        tasks_per_executor: 6,
        speculation: false,
        adaptive_split: false,
        ..Default::default()
    }
}

#[test]
fn killed_run_resumes_row_exact_without_reexecuting_completed_ranges() {
    let n = 240;
    let df = frame(n);
    let dir = tmp_dir("kill-resume");
    let fingerprint = Json::str("identity-x3");

    // ---- run 1: killed mid-flight after ~100 rows -----------------------
    {
        let run = RunCheckpoint::create(&dir).unwrap();
        let stage = run.stage("map", &fingerprint, n).unwrap();
        let abort = AtomicBool::new(false);
        let processed = AtomicUsize::new(0);
        let err = run_scheduled_ext(
            &df,
            4,
            5,
            &cfg(),
            None,
            Some(TaskCheckpoint {
                restored: Vec::new(),
                sink: Some(TaskSink { stage: &stage, encode: &encode }),
            }),
            Some(&abort),
            |_| Ok(()),
            |_, df, slice| {
                if processed.fetch_add(slice.len(), Ordering::SeqCst) >= 100 {
                    abort.store(true, Ordering::SeqCst);
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap() * 3.0)
                    .collect::<Vec<f64>>())
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");
        let coverage = stage.coverage().unwrap();
        assert!(
            coverage > 0.0 && coverage < 1.0,
            "the killed run must leave a partial manifest, got {coverage}"
        );
    }

    // ---- run 2: resume from the manifest --------------------------------
    let run = RunCheckpoint::resume(&dir).unwrap();
    let stage = run.stage("map", &fingerprint, n).unwrap();
    let restored = stage.restore(&decode).unwrap();
    assert!(!restored.is_empty());
    let restored_spans: Vec<(usize, usize)> =
        restored.iter().map(|(s, e, _)| (*s, *e)).collect();

    let touched = Mutex::new(vec![0usize; n]);
    let out = run_scheduled_ext(
        &df,
        4,
        5,
        &cfg(),
        None,
        Some(TaskCheckpoint {
            restored,
            sink: Some(TaskSink { stage: &stage, encode: &encode }),
        }),
        None,
        |_| Ok(()),
        |_, df, slice| {
            {
                let mut touched = touched.lock().unwrap();
                for i in slice.indices() {
                    touched[i] += 1;
                }
            }
            Ok(slice
                .indices()
                .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap() * 3.0)
                .collect::<Vec<f64>>())
        },
    )
    .unwrap();

    // Identical to an uninterrupted run, row for row.
    let uninterrupted =
        run_scheduled(&df, 4, 5, &cfg(), None, |_| Ok(()), |_: &mut (), df, slice| {
            Ok(slice
                .indices()
                .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap() * 3.0)
                .collect::<Vec<f64>>())
        })
        .unwrap();
    assert_eq!(out.rows, uninterrupted.rows);
    assert_eq!(out.rows.len(), n);

    // Restored ranges were never re-executed; every gap row ran exactly
    // once (no speculation, no retries in this configuration).
    let touched = touched.into_inner().unwrap();
    for &(start, end) in &restored_spans {
        for i in start..end {
            assert_eq!(touched[i], 0, "restored row {i} was re-executed");
        }
    }
    let restored_rows: usize = restored_spans.iter().map(|(s, e)| e - s).sum();
    let fresh: usize = touched.iter().sum();
    assert_eq!(fresh, n - restored_rows, "each gap row runs exactly once");
    assert_eq!(out.sched.restored_rows, restored_rows);
    assert!(out.sched.restored_tasks > 0);

    // After the resumed run the manifest covers the whole stage, so a
    // third run would restore everything.
    assert!((stage.coverage().unwrap() - 1.0).abs() < 1e-12);
    let full = stage.restore(&decode).unwrap();
    let covered: usize = full.iter().map(|(s, e, _)| e - s).sum();
    assert_eq!(covered, n);
}

#[test]
fn restore_only_run_executes_nothing() {
    let n = 90;
    let df = frame(n);
    let dir = tmp_dir("restore-only");
    let fingerprint = Json::str("identity");

    {
        let run = RunCheckpoint::create(&dir).unwrap();
        let stage = run.stage("map", &fingerprint, n).unwrap();
        run_scheduled_ext(
            &df,
            3,
            7,
            &cfg(),
            None,
            Some(TaskCheckpoint {
                restored: Vec::new(),
                sink: Some(TaskSink { stage: &stage, encode: &encode }),
            }),
            None,
            |_| Ok(()),
            |_, df, slice| {
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect::<Vec<f64>>())
            },
        )
        .unwrap();
    }

    let run = RunCheckpoint::resume(&dir).unwrap();
    let stage = run.stage("map", &fingerprint, n).unwrap();
    let restored = stage.restore(&decode).unwrap();
    let out = run_scheduled_ext(
        &df,
        3,
        7,
        &cfg(),
        None,
        Some(TaskCheckpoint { restored, sink: None }),
        None,
        |_| Ok(()),
        |_, _df, _slice| -> anyhow::Result<Vec<f64>> {
            panic!("a fully restored run must not execute any UDF batch");
        },
    )
    .unwrap();
    assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
    assert_eq!(out.sched.restored_rows, n);
}
