//! Golden cross-validation of the from-scratch Rust statistics stack
//! against scipy (paper §5.4: "we compared against reference
//! implementations"). Fixtures generated at build time by
//! `python/compile/stats_fixtures.py`.

use spark_llm_eval::runtime::default_artifact_dir;
use spark_llm_eval::stats::special::{
    beta_inc, chi2_cdf, erf, ln_gamma, normal_cdf, normal_ppf, t_cdf, t_ppf,
};
use spark_llm_eval::stats::{
    mcnemar_test, paired_t_test, shapiro_wilk, t_interval, wilcoxon_signed_rank, wilson_interval,
};
use spark_llm_eval::util::json::Json;

fn fixtures() -> Option<Json> {
    let path = default_artifact_dir().join("stats_fixtures.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).unwrap())
}

fn vecf(v: &Json) -> Vec<f64> {
    v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect()
}

fn close(got: f64, want: f64, tol: f64, ctx: &str) {
    assert!(
        (got - want).abs() <= tol * (1.0 + want.abs()),
        "{ctx}: got {got}, scipy {want}"
    );
}

#[test]
fn special_functions_match_scipy() {
    let Some(fx) = fixtures() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for case in fx.get("ln_gamma").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(ln_gamma(c[0]), c[1], 1e-10, "ln_gamma");
    }
    for case in fx.get("erf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(erf(c[0]), c[1], 1e-10, "erf");
    }
    for case in fx.get("normal_cdf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(normal_cdf(c[0]), c[1], 1e-9, "normal_cdf");
    }
    for case in fx.get("normal_ppf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(normal_ppf(c[0]), c[1], 1e-7, "normal_ppf");
    }
    for case in fx.get("t_cdf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(t_cdf(c[0], c[1]), c[2], 1e-9, "t_cdf");
    }
    for case in fx.get("t_ppf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(t_ppf(c[0], c[1]), c[2], 1e-7, "t_ppf");
    }
    for case in fx.get("chi2_cdf").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(chi2_cdf(c[0], c[1]), c[2], 1e-9, "chi2_cdf");
    }
    for case in fx.get("beta_inc").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        close(beta_inc(c[0], c[1], c[2]), c[3], 1e-9, "beta_inc");
    }
}

#[test]
fn paired_tests_match_scipy() {
    let Some(fx) = fixtures() else { return };
    for (i, case) in fx.get("paired_tests").unwrap().as_arr().unwrap().iter().enumerate() {
        let a = vecf(case.get("a").unwrap());
        let b = vecf(case.get("b").unwrap());
        let t = paired_t_test(&a, &b);
        close(
            t.statistic,
            case.get("t_statistic").unwrap().as_f64().unwrap(),
            1e-9,
            &format!("t stat case {i}"),
        );
        close(
            t.p_value,
            case.get("t_pvalue").unwrap().as_f64().unwrap(),
            1e-8,
            &format!("t p case {i}"),
        );
        let w = wilcoxon_signed_rank(&a, &b);
        let scipy_p = case.get("wilcoxon_pvalue").unwrap().as_f64().unwrap();
        // scipy uses exact for n<=25 w/o ties, normal approx beyond; our
        // thresholds differ slightly, so allow a coarser band.
        let tol: f64 = if a.len() <= 12 { 1e-9 } else { 0.08 };
        assert!(
            (w.p_value - scipy_p).abs() < tol.max(0.08 * scipy_p),
            "wilcoxon case {i}: got {}, scipy {scipy_p}",
            w.p_value
        );
    }
}

#[test]
fn mcnemar_matches_reference() {
    let Some(fx) = fixtures() else { return };
    for (i, case) in fx.get("mcnemar").unwrap().as_arr().unwrap().iter().enumerate() {
        let a = vecf(case.get("a").unwrap());
        let b = vecf(case.get("b").unwrap());
        let want = case.get("pvalue").unwrap().as_f64().unwrap();
        let got = mcnemar_test(&a, &b).p_value;
        close(got, want, 1e-9, &format!("mcnemar case {i}"));
    }
}

#[test]
fn shapiro_matches_scipy_approximately() {
    let Some(fx) = fixtures() else { return };
    for (i, case) in fx.get("shapiro").unwrap().as_arr().unwrap().iter().enumerate() {
        let x = vecf(case.get("x").unwrap());
        let want_w = case.get("w").unwrap().as_f64().unwrap();
        let want_p = case.get("p").unwrap().as_f64().unwrap();
        let r = shapiro_wilk(&x);
        // Royston approximation vs scipy's exact coefficients: W to ~1e-2,
        // p to the same decision at α=0.05 and within a coarse band.
        assert!((r.w - want_w).abs() < 0.015, "case {i}: W {} vs {want_w}", r.w);
        assert_eq!(
            r.p_value < 0.05,
            want_p < 0.05,
            "case {i}: decision mismatch ({} vs {want_p})",
            r.p_value
        );
        assert!(
            (r.p_value - want_p).abs() < 0.05 + 0.3 * want_p,
            "case {i}: p {} vs {want_p}",
            r.p_value
        );
    }
}

#[test]
fn wilson_matches_reference() {
    let Some(fx) = fixtures() else { return };
    for case in fx.get("wilson").unwrap().as_arr().unwrap() {
        let c = vecf(case);
        let ci = wilson_interval(c[0] as u64, c[1] as u64, 0.95);
        close(ci.lo, c[2], 1e-9, "wilson lo");
        close(ci.hi, c[3], 1e-9, "wilson hi");
    }
}

#[test]
fn t_interval_matches_scipy() {
    let Some(fx) = fixtures() else { return };
    for (i, case) in fx.get("t_interval").unwrap().as_arr().unwrap().iter().enumerate() {
        let x = vecf(case.get("x").unwrap());
        let ci = t_interval(&x, 0.95);
        close(ci.lo, case.get("lo").unwrap().as_f64().unwrap(), 1e-7, &format!("t lo {i}"));
        close(ci.hi, case.get("hi").unwrap().as_f64().unwrap(), 1e-7, &format!("t hi {i}"));
    }
}
