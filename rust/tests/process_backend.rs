//! Process-backend integration: real `slleval worker` child processes
//! (via `CARGO_BIN_EXE_slleval`), hard kills, and checkpoint resume.
//!
//! These are the acceptance tests for the executor-backend redesign:
//!
//! - thread and process backends produce identical metric values, CIs,
//!   and cost accounting on the same task;
//! - a `kill -9`-equivalent executor death (deterministic, via the
//!   plan's fault hook → `std::process::abort`) costs only the dead
//!   executor's in-flight task: the run completes through retry +
//!   blacklist on the survivors;
//! - when *every* executor dies, the run fails — but a checkpoint-backed
//!   resume completes with row-identical results, re-executing only the
//!   work that was never spilled.

use spark_llm_eval::config::{BackendKind, CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::sched::plan::WorkerFault;

fn worker_exe() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_slleval"))
}

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r.worker_exe = Some(worker_exe());
    r
}

/// Deterministic-count task: cache disabled (1 provider call per row),
/// no speculation (no duplicated work), small batches.
fn task(executors: usize, backend: BackendKind) -> EvalTask {
    let mut task = EvalTask::default();
    task.executors = executors;
    task.backend = backend;
    task.inference.batch_size = 5;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    task
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-procbackend-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn process_backend_matches_thread_backend_exactly() {
    let n = 60;
    let df = synth::generate_default(n, 71);

    let thread = fast_runner().evaluate(&df, &task(3, BackendKind::Thread)).unwrap();
    let process = fast_runner().evaluate(&df, &task(3, BackendKind::Process)).unwrap();

    // Metric identity: values, CIs, per-row scores, n.
    for name in ["exact_match", "token_f1"] {
        let (a, b) = (thread.metric(name).unwrap(), process.metric(name).unwrap());
        assert_eq!(a.value, b.value, "{name} value");
        assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi), "{name} CI");
        assert_eq!(a.n, b.n, "{name} n");
        assert_eq!(
            thread.report(name).unwrap().values,
            process.report(name).unwrap().values,
            "{name} per-row values"
        );
    }
    // Cost accounting identity: one deterministic call per row on both
    // backends, same per-call pricing.
    assert_eq!(process.inference.api_calls, n as u64);
    assert_eq!(thread.inference.api_calls, process.inference.api_calls);
    assert!(
        (thread.inference.total_cost_usd - process.inference.total_cost_usd).abs() < 1e-9,
        "cost: thread {} vs process {}",
        thread.inference.total_cost_usd,
        process.inference.total_cost_usd
    );
    assert_eq!(process.inference.sched.executor_deaths, 0);
    assert_eq!(process.failed_examples, thread.failed_examples);
}

#[test]
fn hard_worker_kill_is_survived_via_retry_and_blacklist() {
    let n = 75;
    let df = synth::generate_default(n, 72);

    // Reference values from the thread backend.
    let reference = fast_runner().evaluate(&df, &task(3, BackendKind::Thread)).unwrap();

    // Executor 1's worker process aborts while executing its first task.
    let mut runner = fast_runner();
    runner.worker_fault = Some(WorkerFault { executor_id: 1, kill_after_tasks: 1 });
    let mut t = task(3, BackendKind::Process);
    t.scheduler.tasks_per_executor = 3;
    let result = runner.evaluate(&df, &t).unwrap();

    assert_eq!(result.inference.sched.executor_deaths, 1, "{:?}", result.inference.sched);
    assert!(
        result.inference.sched.blacklisted_executors.contains(&1),
        "dead executor must take no more work: {:?}",
        result.inference.sched
    );
    assert!(result.inference.sched.retries >= 1, "in-flight task must be retried");
    // The kill changes *where* rows ran, never what they evaluate to.
    assert_eq!(
        result.report("exact_match").unwrap().values,
        reference.report("exact_match").unwrap().values
    );
    assert_eq!(
        result.metric("exact_match").unwrap().value,
        reference.metric("exact_match").unwrap().value
    );
}

#[test]
fn killed_run_resumes_from_checkpoint_with_zero_reinference_of_spilled_rows() {
    let n = 80;
    let df = synth::generate_default(n, 73);

    // Reference: uninterrupted thread-backend run (row-identity oracle).
    let reference = fast_runner().evaluate(&df, &task(1, BackendKind::Thread)).unwrap();
    assert_eq!(reference.inference.api_calls, n as u64);

    // Crashing run: a single process executor, 4 tasks, killed while
    // executing task 2 — with every executor dead the run must fail.
    let dir = tmp_dir("kill-resume");
    let mut t = task(1, BackendKind::Process);
    t.scheduler.tasks_per_executor = 4;
    let mut runner = fast_runner();
    runner.worker_fault = Some(WorkerFault { executor_id: 0, kill_after_tasks: 2 });
    runner.attach_checkpoint(&dir, false).unwrap();
    let err = runner.evaluate(&df, &t).unwrap_err();
    assert!(format!("{err:#}").contains("no live executors"), "{err:#}");

    // Resume (no fault): completed tasks restore from the worker-side
    // spills; only the never-spilled rows are re-inferred.
    let mut runner = fast_runner();
    runner.attach_checkpoint(&dir, true).unwrap();
    let resumed = runner.evaluate(&df, &t).unwrap();

    let restored = resumed.inference.sched.restored_rows;
    assert!(restored > 0, "the killed run must have spilled completed tasks");
    assert!(restored < n, "the killed run must not have finished");
    assert_eq!(
        resumed.inference.api_calls,
        (n - restored) as u64,
        "zero re-inference of checkpointed rows"
    );
    assert_eq!(resumed.inference.examples, n);

    // Row-identical results versus the uninterrupted reference.
    assert_eq!(
        resumed.report("exact_match").unwrap().values,
        reference.report("exact_match").unwrap().values
    );
    let (a, b) =
        (reference.metric("exact_match").unwrap(), resumed.metric("exact_match").unwrap());
    assert_eq!(a.value, b.value);
    assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi));
}

#[test]
fn pairwise_judging_matches_across_backends() {
    let df = synth::generate(
        50,
        74,
        synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
    )
    .unwrap();
    let mk = |backend: BackendKind| {
        let mut a = task(2, backend);
        a.model.model_name = "gpt-4o".into();
        let mut b = a.clone();
        b.model.model_name = "gpt-3.5-turbo".into();
        (a, b)
    };

    let (ta, tb) = mk(BackendKind::Thread);
    let thread = fast_runner()
        .evaluate_pairwise(&df, &ta, &tb, "accuracy", "openai", "gpt-4o")
        .unwrap();
    let (ta, tb) = mk(BackendKind::Process);
    let process = fast_runner()
        .evaluate_pairwise(&df, &ta, &tb, "accuracy", "openai", "gpt-4o")
        .unwrap();

    // Judge responses are content-keyed, so verdicts are identical.
    assert_eq!(thread.verdicts, process.verdicts);
    assert_eq!((thread.a_wins, thread.b_wins), (process.a_wins, process.b_wins));
    assert_eq!(thread.p_value, process.p_value);
}

#[test]
fn cli_backend_flag_runs_end_to_end() {
    // The `--backend process` CLI path: spawn the real binary as the
    // driver (its workers resolve via current_exe) and check it reports
    // a healthy run.
    let out_path = tmp_dir("cli-run").join("result.json");
    std::fs::create_dir_all(out_path.parent().unwrap()).unwrap();
    let output = std::process::Command::new(worker_exe())
        .args([
            "run",
            "--fast",
            "--n",
            "40",
            "--seed",
            "75",
            "--executors",
            "2",
            "--backend",
            "process",
            "--out",
        ])
        .arg(&out_path)
        .output()
        .expect("running slleval");
    assert!(
        output.status.success(),
        "slleval run --backend process failed:\n{}\n{}",
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
    let result = std::fs::read_to_string(&out_path).unwrap();
    let json = spark_llm_eval::util::json::Json::parse(&result).unwrap();
    assert_eq!(json.get("inference").unwrap().usize_or("examples", 0), 40);
    assert_eq!(
        json.get("scheduler").unwrap().usize_or("executor_deaths", 99),
        0,
        "healthy run reports zero deaths"
    );
}
