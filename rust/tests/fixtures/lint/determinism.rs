// Fixture for the determinism rule; the driver test maps it to a
// sched/ path so the HashMap ban applies.
use std::collections::HashMap;
use std::time::{Instant, SystemTime};

fn clocks() -> f64 {
    let t0 = Instant::now();
    let _ts = SystemTime::now();
    t0.elapsed().as_secs_f64()
}

fn hashes() -> HashMap<String, u32> {
    HashMap::new()
}

fn rng() -> u64 {
    thread_rng()
}

fn allowed() -> f64 {
    // lint:allow(determinism): fixture — this wall-clock read is intended
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

fn negatives() {
    let _s = "Instant::now() inside a string literal";
    // Instant::now() inside a comment
    let _b = std::collections::BTreeMap::<String, u32>::new();
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_and_hash_in_test_code_are_fine() {
        let _t = std::time::Instant::now();
        let _m: std::collections::HashMap<u8, u8> = std::collections::HashMap::new();
    }
}
