// Fixture for suppression placement. Cases:
//   a: a suppression on the offending line works
//   b: a suppression on the line above works
//   c: two lines above does NOT suppress (and the suppression goes stale)
//   d: a suppression for a different rule does not silence determinism

fn a() -> std::time::Instant {
    std::time::Instant::now() // lint:allow(determinism): fixture case a
}

fn b() -> std::time::Instant {
    // lint:allow(determinism): fixture case b
    std::time::Instant::now()
}

fn c() -> std::time::Instant {
    // lint:allow(determinism): fixture case c — too far away
    let _pad = ();
    std::time::Instant::now()
}

fn d() -> std::time::Instant {
    // lint:allow(panic-safety): fixture case d — wrong rule
    std::time::Instant::now()
}
