//! Fixture protocol doc of record:
//!
//! driver -> worker   {"type":"hello"}
//! worker -> driver   {"type":"retired"}   (documented but long gone)

fn emit_hello() -> Json {
    Json::obj(vec![("type", Json::str("hello"))])
}

fn emit_cancel() -> Json {
    Json::obj(vec![("type", Json::str("cancel"))])
}
