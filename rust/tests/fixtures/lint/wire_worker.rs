// Fixture dispatcher: handles hello and ack; cancel never arrives.
fn dispatch(frame: &Json) {
    match frame.str_or("type", "") {
        "hello" => {}
        "ack" => {}
        _ => {}
    }
}
