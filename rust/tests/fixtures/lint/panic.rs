// Fixture for the panic-safety rule; the driver test maps it to an
// executor-side path.
use std::sync::Mutex;

fn positives(m: &Mutex<u32>) -> u32 {
    let v = *m.lock().unwrap();
    let w: u32 = "7".parse().expect("fixture");
    if v > w {
        panic!("boom");
    }
    unreachable!()
}

fn negatives(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_test_code_is_fine() {
        let _: u32 = "3".parse().unwrap();
    }
}
