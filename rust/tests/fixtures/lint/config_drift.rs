// Fixture for the config-doc rule: `seed` is documented in the docs
// text the driver test supplies; `frobnication_level` is not.
fn parse(v: &Json) -> (f64, f64) {
    let seed = v.f64_or("seed", 0.0);
    let frob = v.f64_or("frobnication_level", 1.0);
    (seed, frob)
}
