// Fixture for lexer trickiness: everything below is inert except the
// single real call at the bottom.

/* Instant::now() in a block comment
   /* SystemTime::now() in a nested block comment */
   thread_rng() still inside the outer comment
*/

fn strings() {
    let _a = "Instant::now() in a plain string";
    let _b = r##"raw string with a "# fence tease and SystemTime::now()"##;
    let _c = "escaped quote \" then Instant::now()";
    let _d = 'x'; // a char literal, not a lifetime
}

fn real() -> std::time::Instant {
    std::time::Instant::now()
}
