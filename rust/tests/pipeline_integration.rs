//! Integration tests over the full 4-stage pipeline: simulated providers,
//! rate limiting, retries, tracking, comparison, and the PJRT semantic
//! path when artifacts are present.

use std::sync::Arc;

use spark_llm_eval::config::{CiMethod, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::{compare_results, EvalRunner};
use spark_llm_eval::data::{io as dio, synth};
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::{Clock, VirtualClock};
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};
use spark_llm_eval::tracking::TrackingStore;
use spark_llm_eval::util::json::Json;

fn fast_runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("slleval-pipeline-test")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn full_pipeline_with_all_metric_families() {
    let dir = default_artifact_dir();
    let mut runner = fast_runner();
    let has_runtime = dir.join("manifest.json").exists();
    if has_runtime {
        runner.runtime = Some(SemanticRuntime::load(&dir).unwrap());
    }

    let df = synth::generate(
        150,
        51,
        synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
    )
    .unwrap();
    let mut task = EvalTask::default();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
        MetricConfig::new("bleu", "lexical"),
        MetricConfig::new("rouge_l", "lexical"),
        MetricConfig::new("contains", "lexical"),
        MetricConfig::new("helpfulness", "llm_judge")
            .with_param("rubric", Json::str("Rate helpfulness 1-5")),
        MetricConfig::new("faithfulness", "rag"),
        MetricConfig::new("context_precision", "rag"),
        MetricConfig::new("context_recall", "rag"),
    ];
    if has_runtime {
        task.metrics.push(MetricConfig::new("bertscore", "semantic"));
        task.metrics.push(MetricConfig::new("embedding_similarity", "semantic"));
        task.metrics.push(MetricConfig::new("answer_relevance", "rag"));
    }

    let result = runner.evaluate(&df, &task).unwrap();
    assert_eq!(result.metrics.len(), task.metrics.len());
    for m in &result.metrics {
        assert!(m.n > 0, "{} scored nothing", m.name);
        assert!(m.value.is_finite(), "{} value {}", m.name, m.value);
        assert!(m.ci.lo <= m.ci.hi, "{} CI order", m.name);
    }
    // Cross-family consistency: contains >= exact_match (substring is
    // weaker), and semantic similarity should be high when EM is high.
    let em = result.metric("exact_match").unwrap().value;
    let contains = result.metric("contains").unwrap().value;
    assert!(contains >= em - 1e-9, "contains {contains} < em {em}");
    if has_runtime {
        let sim = result.metric("embedding_similarity").unwrap().value;
        assert!(sim > 0.4, "similarity {sim} too low for {em} EM");
    }
}

#[test]
fn rate_limit_throttles_in_virtual_time() {
    // Tight client budget + virtual clock: the run must advance virtual
    // time while waiting on the bucket.
    let clock = VirtualClock::new();
    let mut runner = EvalRunner::with_clock(clock.clone());
    runner.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        global_rpm: 1e9, // server side open; client bucket binds
        ..Default::default()
    };
    let df = synth::generate_default(120, 52);
    let mut task = EvalTask::default();
    task.executors = 2;
    task.inference.rate_limit_rpm = 600.0; // 300/min per executor
    task.inference.rate_limit_tpm = 1e9;
    let before = clock.now();
    let result = runner.evaluate(&df, &task).unwrap();
    // 120 requests at 600 RPM from a full bucket: burst absorbs them —
    // so tighten: the elapsed virtual time must stay small OR throttling
    // kicked in; run again with a drained budget workload.
    assert!(result.failed_examples.is_empty());
    let df2 = synth::generate_default(1500, 53);
    let r2 = runner.evaluate(&df2, &task).unwrap();
    assert!(r2.failed_examples.is_empty());
    let elapsed = clock.now() - before;
    // 1620 total requests, budget 600/min, initial burst 600 → ≥ ~1.7 min.
    assert!(elapsed > 60.0, "virtual time only advanced {elapsed}s");
}

#[test]
fn server_side_429_recovered_by_backoff() {
    let clock = VirtualClock::new();
    let mut runner = EvalRunner::with_clock(clock.clone());
    runner.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        global_rpm: 200.0, // server budget far below client pacing
        ..Default::default()
    };
    let df = synth::generate_default(400, 54);
    let mut task = EvalTask::default();
    task.executors = 8;
    task.inference.rate_limit_rpm = 1e6; // client doesn't pace → 429s
    task.inference.max_retries = 8;
    let result = runner.evaluate(&df, &task).unwrap();
    assert!(result.inference.retries > 0, "expected 429-driven retries");
    assert!(
        result.failed_examples.len() < 40,
        "backoff should recover most: {} failed",
        result.failed_examples.len()
    );
}

#[test]
fn dataset_io_round_trip_through_pipeline() {
    let dir = tmp("io");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.jsonl");
    let df = synth::generate_default(60, 55);
    dio::write_jsonl(&df, &path).unwrap();
    let loaded = dio::read_jsonl(&path).unwrap();
    assert_eq!(loaded.len(), 60);

    let runner = fast_runner();
    let task = EvalTask::default();
    let a = runner.evaluate(&df, &task).unwrap();
    let b = runner.evaluate(&loaded, &task).unwrap();
    assert_eq!(
        a.metric("exact_match").unwrap().value,
        b.metric("exact_match").unwrap().value,
        "serialized dataset must evaluate identically"
    );
}

#[test]
fn tracking_integration() {
    let dir = tmp("tracking");
    let store = TrackingStore::open(&dir).unwrap();
    let runner = fast_runner();
    let df = synth::generate_default(40, 56);
    let task = EvalTask::default();
    let result = runner.evaluate(&df, &task).unwrap();

    let mut run = store.start_run("integration").unwrap();
    run.log_evaluation(&task, &result).unwrap();
    let id = run.run_id.clone();
    run.finish().unwrap();

    let metrics = store.load_metrics(&id).unwrap();
    assert!(metrics.contains_key("exact_match"));
    assert!(metrics.contains_key("exact_match_ci_lower"));
    assert!(metrics.contains_key("total_cost_usd"));
    assert_eq!(metrics["exact_match"], result.metric("exact_match").unwrap().value);
}

#[test]
fn ci_methods_agree_on_large_n() {
    let runner = fast_runner();
    let df = synth::generate_default(400, 57);
    let mut task = EvalTask::default();
    let mut values = Vec::new();
    for method in [CiMethod::Percentile, CiMethod::Bca, CiMethod::Analytic] {
        task.statistics.ci_method = method;
        let r = runner.evaluate(&df, &task).unwrap();
        let m = r.metric("exact_match").unwrap().clone();
        values.push((m.value, m.ci.lo, m.ci.hi));
    }
    // Same point estimate, CIs within a small band of each other.
    for w in values.windows(2) {
        assert_eq!(w[0].0, w[1].0);
        assert!((w[0].1 - w[1].1).abs() < 0.03, "lo {:?}", values);
        assert!((w[0].2 - w[1].2).abs() < 0.03, "hi {:?}", values);
    }
}

#[test]
fn comparison_three_providers() {
    // Cross-provider comparison: claude-3-5-sonnet vs gemini-1.5-flash.
    let runner = fast_runner();
    let df = synth::generate_default(300, 58);
    let mut task_a = EvalTask::default();
    task_a.model.provider = "anthropic".into();
    task_a.model.model_name = "claude-3-5-sonnet".into();
    let mut task_b = EvalTask::default();
    task_b.model.provider = "google".into();
    task_b.model.model_name = "gemini-1.5-flash".into();

    let ra = runner.evaluate(&df, &task_a).unwrap();
    let rb = runner.evaluate(&df, &task_b).unwrap();
    let cmp = compare_results(&ra, &rb, &task_a).unwrap();
    let em = cmp.comparisons.iter().find(|c| c.metric == "exact_match").unwrap();
    // quality 0.91 vs 0.74: sonnet must win.
    assert!(em.value_a > em.value_b);
    assert!(em.test.significant(0.05), "p {}", em.test.p_value);
}

#[test]
fn device_bootstrap_in_aggregation() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let mut runner = fast_runner();
    runner.runtime = Some(SemanticRuntime::load(&dir).unwrap());
    let df = synth::generate_default(200, 59);
    let mut task = EvalTask::default();
    task.statistics.ci_method = CiMethod::Percentile;
    task.statistics.use_device_bootstrap = true;
    task.statistics.bootstrap_iterations = 1000; // matches the artifact
    task.metrics = vec![MetricConfig::new("token_f1", "lexical")];
    let r = runner.evaluate(&df, &task).unwrap();
    let m = r.metric("token_f1").unwrap();
    assert_eq!(m.ci.method, "percentile_device");
    assert!(m.ci.lo <= m.value && m.value <= m.ci.hi);
    // Device CI must agree with the native bootstrap closely.
    task.statistics.use_device_bootstrap = false;
    let r2 = runner.evaluate(&df, &task).unwrap();
    let m2 = r2.metric("token_f1").unwrap();
    assert!((m.ci.lo - m2.ci.lo).abs() < 0.02, "{} vs {}", m.ci.lo, m2.ci.lo);
    assert!((m.ci.hi - m2.ci.hi).abs() < 0.02);
}

#[test]
fn adaptive_rate_coordinator_with_skewed_partitions() {
    use spark_llm_eval::ratelimit::adaptive::{DemandReport, RateCoordinator};
    // Simulated skew: two busy executors, six idle. After rebalancing the
    // busy pair should hold most of the global budget.
    let c = Arc::new(RateCoordinator::new(10_000.0, 2_000_000.0, 8));
    let mut reports = vec![DemandReport { admitted: 10, waited: 0.0, backlog: false }; 8];
    reports[0] = DemandReport { admitted: 500, waited: 40.0, backlog: true };
    reports[1] = DemandReport { admitted: 480, waited: 35.0, backlog: true };
    let shares = c.rebalance(&reports);
    let busy: f64 = shares[0].rpm + shares[1].rpm;
    assert!(busy > 6_000.0, "busy pair got {busy} of 10k");
    let total: f64 = shares.iter().map(|s| s.rpm).sum();
    assert!((total - 10_000.0).abs() < 1.0);
}

#[test]
fn every_builtin_metric_round_trips_config_and_registry() {
    // MetricConfig → EvalTask JSON serde → registry resolution for every
    // registered built-in: names, families, and scales survive the trip
    // and resolve to a metric whose declared name matches the config.
    use spark_llm_eval::metrics::builtin_registry;

    let reg = builtin_registry();
    let mut metrics = Vec::new();
    for family in ["lexical", "semantic", "rag"] {
        for name in reg.names_for_family(family) {
            metrics.push(MetricConfig::new(name, family));
        }
    }
    metrics.push(
        MetricConfig::new("helpfulness", "llm_judge")
            .with_param("rubric", Json::str("Rate helpfulness 1-5")),
    );
    assert!(metrics.len() >= 11, "expected all built-ins, got {}", metrics.len());

    let mut task = EvalTask::default();
    task.metrics = metrics;
    let restored = EvalTask::from_json(&task.to_json()).unwrap();
    assert_eq!(task, restored);

    for mc in &restored.metrics {
        let metric = reg.resolve(mc).unwrap();
        assert_eq!(metric.name(), mc.name, "resolution must preserve the name");
        assert_eq!(metric.scale(), reg.scale_of(mc).unwrap());
    }
}

#[test]
fn rescore_pipeline_matches_live_run_across_families() {
    // The paper's "iterate on metrics for free" claim end to end: one
    // cached live run, then a rescore that drops a metric, keeps two, and
    // adds two — zero inference calls, shared metrics bit-identical.
    let dir = tmp("rescore-e2e");
    let df = synth::generate(
        120,
        60,
        synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
    )
    .unwrap();

    let mut task = EvalTask::default();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
        MetricConfig::new("context_precision", "rag"),
    ];
    let mut runner = fast_runner();
    runner.open_cache(&dir, spark_llm_eval::config::CachePolicy::Enabled).unwrap();
    let live = runner.evaluate(&df, &task).unwrap();
    assert!(live.inference.api_calls > 0);

    let mut task2 = task.clone();
    task2.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("context_precision", "rag"),
        MetricConfig::new("bleu", "lexical"),
        MetricConfig::new("context_recall", "rag"),
    ];
    let mut runner2 = fast_runner();
    runner2.open_cache(&dir, spark_llm_eval::config::CachePolicy::Replay).unwrap();
    let re = runner2.rescore(&df, &task2, false).unwrap();

    assert_eq!(re.inference.api_calls, 0, "rescore must not call the provider");
    assert_eq!(re.inference.total_cost_usd, 0.0);
    assert_eq!(re.metric_calls.api_calls, 0, "pure metrics need no judge calls");
    for name in ["exact_match", "context_precision"] {
        assert_eq!(
            live.report(name).unwrap().values,
            re.report(name).unwrap().values,
            "{name} must be bit-identical from cache"
        );
        let (a, b) = (live.metric(name).unwrap(), re.metric(name).unwrap());
        assert_eq!(a.value, b.value);
        assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi), "{name} bootstrap CI");
    }
    for name in ["bleu", "context_recall"] {
        assert!(re.metric(name).unwrap().n > 0, "{name} scored nothing");
    }
    assert!(re.metric("token_f1").is_none(), "dropped metric must not reappear");
}
