//! End-to-end scheduler integration: the dynamic work-stealing scheduler
//! must produce results identical to the static-compatibility preset —
//! including under injected provider faults and heavy-tailed latency
//! (`SimServiceConfig` hooks) — and its telemetry must surface in run
//! reports.

use spark_llm_eval::config::{EvalTask, MetricConfig, SchedulerConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;

fn runner_with(sim: &SimServiceConfig) -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = sim.clone();
    r
}

#[test]
fn dynamic_scheduler_matches_static_results_under_latency_skew() {
    // Heavy-tailed latency keyed on prompt content: the exact straggler
    // profile the scheduler absorbs. Results must be row-identical to the
    // static engine regardless of the schedule.
    let sim = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        tail_latency_rate: 0.15,
        tail_latency_mult: 30.0,
        ..Default::default()
    };
    let df = synth::generate_default(300, 71);
    let mut task = EvalTask::default();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    assert_ne!(task.scheduler, SchedulerConfig::legacy(), "default must be dynamic");

    let dynamic = runner_with(&sim).evaluate(&df, &task).unwrap();

    let mut task_static = task.clone();
    task_static.scheduler = SchedulerConfig::legacy();
    let static_ = runner_with(&sim).evaluate(&df, &task_static).unwrap();

    for (i, name) in ["exact_match", "token_f1"].iter().enumerate() {
        let a = dynamic.metric(name).unwrap();
        let b = static_.metric(name).unwrap();
        assert!((a.value - b.value).abs() < 1e-12, "{name}: {} vs {}", a.value, b.value);
        // Row-for-row identical scores, not just identical aggregates.
        assert_eq!(dynamic.reports[i].values, static_.reports[i].values, "{name} rows");
    }
    assert_eq!(dynamic.inference.examples, 300);
    assert!(dynamic.inference.sched.tasks > 0, "scheduler telemetry missing");
}

#[test]
fn scheduler_survives_injected_server_faults() {
    // Transient 5xx injection: provider-level retries recover every row and
    // the scheduler never loses or duplicates one.
    let sim = SimServiceConfig {
        server_error_rate: 0.25,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    let df = synth::generate_default(200, 72);
    let mut task = EvalTask::default();
    task.inference.max_retries = 8;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];

    let result = runner_with(&sim).evaluate(&df, &task).unwrap();
    assert!(result.failed_examples.is_empty(), "retries should recover all rows");
    assert_eq!(result.reports[0].values.len(), 200);
    assert!(result.inference.retries > 0, "fault injection should force retries");

    // Same metric values as a clean run: responses are content-keyed.
    let clean = SimServiceConfig { server_error_rate: 0.0, ..sim };
    let clean_result = runner_with(&clean).evaluate(&df, &task).unwrap();
    assert_eq!(result.reports[0].values, clean_result.reports[0].values);
}

#[test]
fn run_report_carries_task_timeline_and_scheduler_telemetry() {
    let sim = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    let df = synth::generate_default(120, 73);
    let task = EvalTask::default();
    let result = runner_with(&sim).evaluate(&df, &task).unwrap();

    // Telemetry in the struct…
    let sched = &result.inference.sched;
    assert!(sched.tasks > 0);
    assert!(!result.inference.timeline.is_empty());
    let won_rows: usize = result
        .inference
        .timeline
        .iter()
        .filter(|t| t.outcome == spark_llm_eval::sched::TaskOutcome::Won)
        .map(|t| t.end - t.start)
        .sum();
    assert_eq!(won_rows, 120, "winning task attempts must cover every row exactly once");

    // …and in the serialized run report.
    let json = result.to_json();
    let sched_json = json.get("scheduler").unwrap();
    assert_eq!(
        sched_json.get("tasks").unwrap().as_f64().unwrap() as usize,
        sched.tasks
    );
    let timeline = json.get("task_timeline").unwrap().as_arr().unwrap();
    assert_eq!(timeline.len(), result.inference.timeline.len());

    // The human-readable summary mentions the scheduler line.
    let summary = spark_llm_eval::report::eval_summary(&result);
    assert!(summary.contains("scheduler:"), "{summary}");
}
