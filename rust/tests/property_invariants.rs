//! Property-based tests on coordinator-level invariants (routing,
//! batching, caching, statistics) using the hand-rolled harness in
//! `util::proptest`.

use spark_llm_eval::cache::{cache_key, ResponseCache};
use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::metrics::lexical;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::providers::InferenceResponse;
use spark_llm_eval::ratelimit::{Clock, TokenBucket, VirtualClock};
use spark_llm_eval::stats;
use spark_llm_eval::util::proptest::{check, ensure, gen};

#[test]
fn prop_token_bucket_never_exceeds_rate() {
    check("bucket admits <= limit + burst per window", 60, |rng| {
        let rpm = 10.0 + rng.f64() * 600.0;
        let clock = VirtualClock::new();
        let mut bucket = TokenBucket::new(rpm, 1e12, clock.as_ref());
        // Hammer for 3 virtual minutes.
        let mut admitted_after_burst = 0u64;
        while clock.now() < 180.0 {
            bucket.acquire(1.0, clock.as_ref());
            if clock.now() > 60.0 {
                admitted_after_burst += 1;
            }
        }
        // Steady state: two minutes of budget (+small slack).
        ensure(
            admitted_after_burst as f64 <= 2.0 * rpm + 2.0,
            format!("admitted {admitted_after_burst} at rpm {rpm}"),
        )
    });
}

#[test]
fn prop_cache_get_after_put() {
    let dir = std::env::temp_dir().join(format!("slleval-prop-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
    check("get-after-put returns the stored response", 100, |rng| {
        let prompt = gen::sentence(rng, 20);
        let model = if rng.chance(0.5) { "gpt-4o" } else { "claude-3-haiku" };
        let temp = if rng.chance(0.5) { 0.0 } else { 0.7 };
        let text = gen::sentence(rng, 10);
        let resp = InferenceResponse {
            text: text.clone(),
            input_tokens: rng.below(1000),
            output_tokens: rng.below(500),
            latency_ms: rng.f64() * 1000.0,
            cost_usd: rng.f64() * 0.01,
        };
        cache.put(&prompt, model, "prov", temp, 1024, &resp).unwrap();
        let hit = cache.get(&prompt, model, "prov", temp, 1024).unwrap();
        ensure(
            hit.map(|e| e.response_text) == Some(text),
            "stored response must round-trip",
        )
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_cache_key_injective_on_fields() {
    check("cache key differs when any field differs", 100, |rng| {
        let p1 = gen::sentence(rng, 8);
        let p2 = format!("{p1} extra");
        let k = cache_key(&p1, "m", "p", 0.0, 100);
        ensure(k != cache_key(&p2, "m", "p", 0.0, 100), "prompt")?;
        ensure(k != cache_key(&p1, "m2", "p", 0.0, 100), "model")?;
        ensure(k != cache_key(&p1, "m", "p2", 0.0, 100), "provider")?;
        ensure(k != cache_key(&p1, "m", "p", 0.1, 100), "temperature")?;
        ensure(k != cache_key(&p1, "m", "p", 0.0, 101), "max_tokens")?;
        ensure(k == cache_key(&p1, "m", "p", 0.0, 100), "determinism")
    });
}

#[test]
fn prop_lexical_metrics_bounded_and_reflexive() {
    check("lexical metrics in [0,1], identity scores 1", 200, |rng| {
        let a = gen::sentence(rng, 15);
        let b = gen::sentence(rng, 15);
        for (name, v) in [
            ("em", lexical::exact_match(&a, &b, lexical::Normalize::default())),
            ("contains", lexical::contains(&a, &b, lexical::Normalize::default())),
            ("f1", lexical::token_f1(&a, &b)),
            ("bleu", lexical::bleu(&a, &b)),
            ("rouge", lexical::rouge_l(&a, &b)),
        ] {
            ensure((0.0..=1.0).contains(&v), format!("{name} = {v} for ({a:?},{b:?})"))?;
        }
        if !a.is_empty() {
            ensure(
                lexical::token_f1(&a, &a) == 1.0 && lexical::rouge_l(&a, &a) == 1.0,
                "identity must score 1",
            )?;
        }
        // Symmetry of F1.
        ensure_close_f1(&a, &b)
    });

    fn ensure_close_f1(a: &str, b: &str) -> Result<(), String> {
        let ab = lexical::token_f1(a, b);
        let ba = lexical::token_f1(b, a);
        ensure((ab - ba).abs() < 1e-12, format!("f1 asymmetric: {ab} vs {ba}"))
    }
}

#[test]
fn prop_ci_contains_point_and_nested_levels() {
    check("CI ordering + monotone level", 40, |rng| {
        let n = 15 + rng.below(120);
        let xs = gen::values(rng, n);
        let mut r1 = rng.fork(1);
        let c90 = stats::percentile_bootstrap(&xs, stats::describe::mean, 0.90, 300, &mut r1);
        let mut r2 = rng.fork(1);
        let c99 = stats::percentile_bootstrap(&xs, stats::describe::mean, 0.99, 300, &mut r2);
        ensure(c90.lo <= c90.hi, "order")?;
        // Same bootstrap stream → nested intervals.
        ensure(
            c99.lo <= c90.lo + 1e-12 && c90.hi <= c99.hi + 1e-12,
            format!("nesting: 90% ({}, {}) vs 99% ({}, {})", c90.lo, c90.hi, c99.lo, c99.hi),
        )
    });
}

#[test]
fn prop_significance_tests_symmetry() {
    check("swapping models flips sign, keeps p", 40, |rng| {
        let n = 10 + rng.below(80);
        let a = gen::values(rng, n);
        let b = gen::values(rng, n);
        let t_ab = stats::paired_t_test(&a, &b);
        let t_ba = stats::paired_t_test(&b, &a);
        ensure((t_ab.p_value - t_ba.p_value).abs() < 1e-12, "t p symmetric")?;
        ensure((t_ab.statistic + t_ba.statistic).abs() < 1e-9, "t stat antisymmetric")?;
        let m_ab = stats::mcnemar_test(&gen::binary(rng, n), &gen::binary(rng, n));
        ensure((0.0..=1.0).contains(&m_ab.p_value), "mcnemar p bounded")
    });
}

#[test]
fn prop_pipeline_conservation() {
    // Over random task shapes: every example is accounted for exactly once
    // (hit, api success, or failure), and metric counts add up.
    let service = SimServiceConfig {
        server_error_rate: 0.02,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    check("inference accounting conserves examples", 12, |rng| {
        let n = 20 + rng.below(150);
        let df = synth::generate_default(n, rng.next_u64());
        let mut task = EvalTask::default();
        task.executors = 1 + rng.below(12);
        task.inference.batch_size = 1 + rng.below(60);
        task.inference.max_retries = rng.below(3);
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
        let mut runner = EvalRunner::with_clock(VirtualClock::new());
        runner.service_config = service.clone();
        let r = runner.evaluate(&df, &task).map_err(|e| e.to_string())?;
        let inf = &r.inference;
        ensure(inf.examples == n, "examples")?;
        ensure(
            (inf.cache_hits + inf.cache_misses) as usize == n,
            format!("hits {} + misses {} != {n}", inf.cache_hits, inf.cache_misses),
        )?;
        let m = r.metric("exact_match").unwrap();
        ensure(m.n + m.n_failed == n, "metric accounting")?;
        ensure(m.n_failed == r.failed_examples.len(), "failures consistent")
    });
}

#[test]
fn prop_partitioning_independent_of_executor_count() {
    // Metric values must not depend on how many executors computed them.
    check("executor count does not change results", 8, |rng| {
        let df = synth::generate_default(80, rng.next_u64());
        let service = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        let mut values = Vec::new();
        for execs in [1usize, 3, 8] {
            let mut task = EvalTask::default();
            task.executors = execs;
            let mut runner = EvalRunner::with_clock(VirtualClock::new());
            runner.service_config = service.clone();
            let r = runner.evaluate(&df, &task).map_err(|e| e.to_string())?;
            values.push(r.metric("exact_match").unwrap().value);
        }
        ensure(
            values.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12),
            format!("values differ across executor counts: {values:?}"),
        )
    });
}
