#[test]
#[ignore = "seed debug scratch: prints discordant-pair breakdowns and asserts \
            nothing; kept for manual quality probing (cargo test -- --ignored)"]
fn dbg_quality() {
    use spark_llm_eval::coordinator::runner::EvalRunner;
    use spark_llm_eval::providers::simulated::SimServiceConfig;
    use spark_llm_eval::ratelimit::VirtualClock;
    use spark_llm_eval::data::synth;
    use spark_llm_eval::config::EvalTask;
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig { server_error_rate: 0.0, unparseable_rate: 0.0, sleep_latency: false, ..Default::default() };
    let df = synth::generate(250, 21, synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 }).unwrap();
    let mut ta = EvalTask::default();
    ta.model.model_name = "gpt-4o".into();
    let mut tb = ta.clone();
    tb.model.model_name = "gpt-3.5-turbo".into();
    let ra = r.evaluate(&df, &ta).unwrap();
    let rb = r.evaluate(&df, &tb).unwrap();
    println!("a em = {}", ra.metric("exact_match").unwrap().value);
    println!("b em = {}", rb.metric("exact_match").unwrap().value);
    // discordant breakdown
    let va = &ra.reports[0].values; let vb = &rb.reports[0].values;
    let mut b01=0; let mut b10=0;
    for (x,y) in va.iter().zip(vb) { match (x.unwrap()>=0.5, y.unwrap()>=0.5) { (true,false)=>b10+=1,(false,true)=>b01+=1,_=>{} } }
    println!("b10={} b01={}", b10, b01);
}
