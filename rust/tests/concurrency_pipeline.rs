//! In-executor concurrency integration tests (ISSUE 4):
//!
//! - concurrency 1 is bit-identical to the pre-pipeline sequential hot
//!   path (same responses, same retry/cost accounting, same virtual
//!   timeline) — verified against a hand-rolled reference loop that *is*
//!   the old code;
//! - concurrency 8 cuts a latency-bound virtual-clock run's wall time
//!   ~8× while leaving metric values, CIs, and cost untouched;
//! - kill/resume with `--checkpoint` restores rows identically with
//!   concurrency > 1;
//! - occupancy telemetry: per-executor busy time is wall-clock pipeline
//!   occupancy (≤ stage wall time) and row counts are conserved.

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::retry::{infer_with_retry, RetryPolicy};
use spark_llm_eval::providers::simulated::{SimEngine, SimService, SimServiceConfig};
use spark_llm_eval::providers::tokenizer::estimate_request_tokens;
use spark_llm_eval::providers::InferenceRequest;
use spark_llm_eval::ratelimit::{Clock, TokenBucket, VirtualClock};
use spark_llm_eval::util::rng::Rng;

fn service_cfg(server_error_rate: f64, sleep_latency: bool) -> SimServiceConfig {
    SimServiceConfig {
        server_error_rate,
        unparseable_rate: 0.0,
        sleep_latency,
        ..Default::default()
    }
}

fn base_task(concurrency: usize, executors: usize) -> EvalTask {
    let mut task = EvalTask::default();
    task.executors = executors;
    task.inference.concurrency = concurrency;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    task
}

#[test]
fn concurrency_1_bit_identical_to_sequential_reference() {
    // Faults ON (5% transient 5xx) so retry accounting is exercised; the
    // reference loop below is the exact pre-pipeline per-row hot path.
    let cfg = service_cfg(0.05, true);
    let prompts: Vec<String> =
        (0..60).map(|i| format!("Question: what is the capital of country {i}?")).collect();

    let mut task = base_task(1, 1);
    task.inference.batch_size = 7;
    let clock = VirtualClock::new();
    let mut runner = EvalRunner::with_clock(clock.clone());
    runner.service_config = cfg.clone();
    let (rows, stats) = runner.run_inference(&prompts, &task).unwrap();
    let pipeline_wall = clock.now();

    // Reference: one engine, one bucket, one rng stream, rows in order.
    let ref_clock = VirtualClock::new();
    let svc = SimService::new(&task.model.provider, cfg, ref_clock.clone());
    let mut engine = SimEngine::new(
        svc,
        &task.model.provider,
        &task.model.model_name,
        ref_clock.clone(),
    )
    .unwrap();
    use spark_llm_eval::providers::InferenceEngine;
    engine.initialize().unwrap();
    let mut bucket = TokenBucket::per_executor(
        task.inference.rate_limit_rpm,
        task.inference.rate_limit_tpm,
        1,
        ref_clock.as_ref(),
    );
    let mut rng = Rng::with_stream(task.statistics.seed, 0);
    let policy = RetryPolicy {
        max_retries: task.inference.max_retries,
        base_delay: task.inference.retry_delay,
        ..Default::default()
    };
    let mut api_calls = 0u64;
    let mut retries = 0u64;
    let mut cost = 0.0f64;
    for (i, prompt) in prompts.iter().enumerate() {
        let est = estimate_request_tokens(prompt, task.model.max_tokens) as f64;
        bucket.acquire(est, ref_clock.as_ref());
        let mut req = InferenceRequest::new(prompt.clone());
        req.max_tokens = task.model.max_tokens;
        req.temperature = task.model.temperature;
        let out = infer_with_retry(&mut engine, &req, &policy, ref_clock.as_ref(), &mut rng);
        api_calls += out.attempts as u64;
        match out.result {
            Ok(resp) => {
                retries += (out.attempts - 1) as u64;
                cost += resp.cost_usd;
                assert_eq!(rows[i].response.as_deref(), Some(resp.text.as_str()), "row {i}");
                assert_eq!(rows[i].latency_ms.to_bits(), resp.latency_ms.to_bits(), "row {i}");
                assert_eq!(rows[i].cost_usd.to_bits(), resp.cost_usd.to_bits(), "row {i}");
                assert_eq!(rows[i].attempts, out.attempts, "row {i}");
            }
            Err(e) => {
                assert!(rows[i].response.is_none(), "row {i}");
                assert_eq!(rows[i].error.as_deref(), Some(e.to_string().as_str()), "row {i}");
                assert_eq!(rows[i].attempts, out.attempts, "row {i}");
            }
        }
    }
    assert_eq!(stats.api_calls, api_calls, "attempt accounting");
    assert_eq!(stats.retries, retries, "retry accounting");
    assert_eq!(stats.total_cost_usd.to_bits(), cost.to_bits(), "cost accounting");
    // Identical virtual timeline: same sleeps in the same order.
    assert_eq!(pipeline_wall.to_bits(), ref_clock.now().to_bits(), "virtual timeline");
    assert_eq!(stats.concurrency, 1);
}

#[test]
fn concurrency_8_speeds_up_latency_bound_run_with_identical_results() {
    // Latency is slept on the virtual clock: the run is latency-bound and
    // its virtual wall time is what the pipeline must cut ~8×.
    let df = synth::generate_default(96, 17);
    let run = |concurrency: usize| {
        let clock = VirtualClock::new();
        let mut runner = EvalRunner::with_clock(clock);
        runner.service_config = service_cfg(0.0, true);
        let mut task = base_task(concurrency, 1);
        task.inference.batch_size = 16;
        runner.evaluate(&df, &task).unwrap()
    };
    let seq = run(1);
    let pipe = run(8);

    // Throughput: ≥ 4× less virtual wall time at concurrency 8 (the
    // expected factor is ~5–8× depending on the latency tail).
    let speedup = seq.inference.wall_secs / pipe.inference.wall_secs;
    assert!(
        speedup >= 4.0,
        "concurrency 8 must cut latency-bound wall time ≥ 4x, got {speedup:.2}x \
         ({:.1}s -> {:.1}s)",
        seq.inference.wall_secs,
        pipe.inference.wall_secs
    );
    assert!(pipe.inference.peak_in_flight > 1, "pipeline must actually overlap requests");
    assert!(pipe.inference.peak_in_flight <= 8);

    // Identity: metric values, CIs, cost, and row-level responses are
    // unchanged — concurrency only reschedules the same work.
    let (ms, mp) = (&seq.metrics[0], &pipe.metrics[0]);
    assert_eq!(ms.value.to_bits(), mp.value.to_bits(), "metric value moved");
    assert_eq!(ms.ci.lo.to_bits(), mp.ci.lo.to_bits(), "CI lower moved");
    assert_eq!(ms.ci.hi.to_bits(), mp.ci.hi.to_bits(), "CI upper moved");
    assert_eq!(ms.n, mp.n);
    assert!(
        (seq.inference.total_cost_usd - pipe.inference.total_cost_usd).abs() < 1e-12,
        "cost accounting moved"
    );
    assert_eq!(seq.reports[0].values, pipe.reports[0].values, "per-row scores moved");
}

#[test]
fn kill_resume_restores_rows_identically_under_concurrency() {
    // A cost budget kills the first run mid-flight; the resume (still at
    // concurrency 4) restores the paid-for ranges and finishes, matching
    // an uninterrupted run bit for bit.
    let n = 120;
    let df = synth::generate_default(n, 23);
    let dir = std::env::temp_dir()
        .join("slleval-concurrency-test")
        .join(format!("kill-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let fast_runner = || {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = service_cfg(0.0, false);
        r
    };
    let mut task = base_task(4, 2);
    task.inference.batch_size = 10;

    // Uninterrupted reference (also sizes the abort budget).
    let reference = fast_runner().evaluate(&df, &task).unwrap();
    assert!(reference.inference.total_cost_usd > 0.0);

    // Run 1: killed by a spend budget of ~40% of the full cost.
    {
        let mut budget_task = task.clone();
        budget_task.inference.max_cost_usd = Some(0.4 * reference.inference.total_cost_usd);
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let err = runner.evaluate(&df, &budget_task).unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");
    }

    // Run 2: resume with the same concurrency; restored ranges are free.
    let resumed = {
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        runner.evaluate(&df, &task).unwrap()
    };
    assert!(
        resumed.inference.sched.restored_rows > 0,
        "the killed run must have checkpointed completed tasks"
    );
    assert!(
        (resumed.inference.api_calls as usize) < n,
        "restored rows must not be re-paid"
    );

    assert_eq!(resumed.reports[0].values, reference.reports[0].values);
    assert_eq!(
        resumed.metrics[0].value.to_bits(),
        reference.metrics[0].value.to_bits()
    );
    assert_eq!(
        resumed.metrics[0].ci.lo.to_bits(),
        reference.metrics[0].ci.lo.to_bits()
    );
}

#[test]
fn busy_secs_is_pipeline_occupancy_not_summed_latency() {
    // Real clock + real (scaled-down) latency sleeps: with 6-way
    // concurrency the per-executor busy time must stay within the stage
    // wall time — summed per-request latency would exceed it ~6×.
    let df = synth::generate_default(48, 29);
    let mut runner = EvalRunner::new();
    runner.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: true,
        latency_scale: 0.05, // p50 ≈ 16ms
        ..Default::default()
    };
    let mut task = base_task(6, 2);
    task.inference.batch_size = 8;
    let result = runner.evaluate(&df, &task).unwrap();
    let inf = &result.inference;

    assert_eq!(inf.executors.len(), 2);
    let mut total_rows = 0usize;
    for e in &inf.executors {
        assert!(
            e.busy_secs <= inf.wall_secs + 0.05,
            "executor {} busy {:.3}s exceeds stage wall {:.3}s — busy time is \
             double-counting per-request latency",
            e.executor_id,
            e.busy_secs,
            inf.wall_secs
        );
        total_rows += e.rows_processed;
    }
    // No speculation/retries in this config: telemetry sums exactly.
    assert_eq!(total_rows, 48, "executor row telemetry must conserve rows");
    assert!(inf.peak_in_flight >= 2, "expected real overlap, got {}", inf.peak_in_flight);
    assert!(inf.peak_in_flight <= 6);
}

#[test]
fn streaming_with_concurrency_matches_sequential_values() {
    let df = synth::generate_default(90, 31);
    let run = |concurrency: usize| {
        let clock = VirtualClock::new();
        let mut runner = EvalRunner::with_clock(clock);
        runner.service_config = service_cfg(0.0, false);
        let mut task = base_task(concurrency, 2);
        task.inference.batch_size = 15;
        let (reports, last) = runner
            .evaluate_streaming(&df, &task, 30, |_| {
                spark_llm_eval::coordinator::StreamControl::Continue
            })
            .unwrap();
        (reports, last)
    };
    let (seq_reports, seq_last) = run(1);
    let (pipe_reports, pipe_last) = run(6);
    assert_eq!(seq_reports[0].values, pipe_reports[0].values);
    assert_eq!(seq_last.api_calls, pipe_last.api_calls);
    assert!((seq_last.cost_usd - pipe_last.cost_usd).abs() < 1e-12);
}

#[test]
fn pairwise_with_concurrency_matches_sequential_verdicts() {
    let df = synth::generate(
        60,
        37,
        synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
    )
    .unwrap();
    let run = |concurrency: usize| {
        let clock = VirtualClock::new();
        let mut runner = EvalRunner::with_clock(clock);
        runner.service_config = service_cfg(0.0, false);
        let mut task_a = base_task(concurrency, 2);
        task_a.model.model_name = "gpt-4o".into();
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();
        runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-4o")
            .unwrap()
    };
    let seq = run(1);
    let pipe = run(8);
    assert_eq!(seq.verdicts, pipe.verdicts, "verdicts must not depend on concurrency");
    assert_eq!((seq.a_wins, seq.b_wins), (pipe.a_wins, pipe.b_wins));
    assert_eq!(seq.p_value.to_bits(), pipe.p_value.to_bits());
}
