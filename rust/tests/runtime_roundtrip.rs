//! End-to-end AOT bridge test: the PJRT-compiled artifacts must reproduce
//! the numbers JAX computed at build time (artifacts/fixtures.json), and the
//! text-level semantic APIs must satisfy their invariants.

use std::path::PathBuf;

use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};
use spark_llm_eval::util::json::Json;
use spark_llm_eval::util::rng::Rng;

fn runtime() -> Option<SemanticRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(SemanticRuntime::load(&dir).expect("loading artifacts"))
}

fn fixtures() -> Option<Json> {
    let path: PathBuf = default_artifact_dir().join("fixtures.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("parsing fixtures.json"))
}

fn to_i32(v: &Json) -> Vec<i32> {
    v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as i32).collect()
}

fn to_f32(v: &Json) -> Vec<f32> {
    v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32).collect()
}

fn assert_allclose(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{ctx}[{i}]: got {g}, want {w}"
        );
    }
}

#[test]
fn embedder_matches_jax() {
    let (Some(rt), Some(fx)) = (runtime(), fixtures()) else { return };
    let e = fx.get("embed").unwrap();
    let ids = to_i32(e.get("ids").unwrap());
    let mask = to_f32(e.get("mask").unwrap());
    let want = to_f32(e.get("pooled").unwrap());
    let got = rt.embed_batch(&ids, &mask).unwrap();
    assert_allclose(&got, &want, 2e-4, "pooled embedding");
}

#[test]
fn bertscore_matches_jax() {
    let (Some(rt), Some(fx)) = (runtime(), fixtures()) else { return };
    let b = fx.get("bertscore").unwrap();
    let scores = rt
        .bertscore_batch(
            &to_i32(b.get("ids_a").unwrap()),
            &to_f32(b.get("mask_a").unwrap()),
            &to_i32(b.get("ids_b").unwrap()),
            &to_f32(b.get("mask_b").unwrap()),
        )
        .unwrap();
    let p: Vec<f32> = scores.iter().map(|s| s.precision).collect();
    let r: Vec<f32> = scores.iter().map(|s| s.recall).collect();
    let f1: Vec<f32> = scores.iter().map(|s| s.f1).collect();
    assert_allclose(&p, &to_f32(b.get("precision").unwrap()), 2e-4, "precision");
    assert_allclose(&r, &to_f32(b.get("recall").unwrap()), 2e-4, "recall");
    assert_allclose(&f1, &to_f32(b.get("f1").unwrap()), 2e-4, "f1");
    // Rows 0/1 were made identical in the fixture generator: F1 ≈ 1.
    assert!(f1[0] > 0.999 && f1[1] > 0.999, "identical rows must score 1");
}

#[test]
fn bootstrap_artifact_reproduces_fixture_pattern() {
    let (Some(rt), Some(fx)) = (runtime(), fixtures()) else { return };
    let b = fx.get("bootstrap").unwrap();
    let n = b.get("n").unwrap().as_usize().unwrap();
    let values: Vec<f64> =
        b.get("values").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
    assert_eq!(values.len(), n);

    // The artifact draws indices from our RNG, so we can't reproduce the
    // fixed fixture pattern exactly; instead verify the statistical
    // contract: resample means average to the sample mean.
    let mut rng = Rng::new(7);
    let means = rt.bootstrap_means(&values, &mut rng).unwrap().expect("n <= max_n");
    assert_eq!(means.len(), rt.manifest.bootstrap.resamples);
    let sample_mean = values.iter().sum::<f64>() / n as f64;
    let grand = means.iter().sum::<f64>() / means.len() as f64;
    let sd = (values.iter().map(|v| (v - sample_mean).powi(2)).sum::<f64>() / n as f64).sqrt();
    let se = sd / (n as f64).sqrt();
    assert!(
        (grand - sample_mean).abs() < 4.0 * se / (means.len() as f64).sqrt() + 1e-3,
        "grand mean {grand} vs sample mean {sample_mean}"
    );
    // And the fixture's own mean-of-means sanity value from JAX:
    let want = b.get("means_mean").unwrap().as_f64().unwrap();
    assert!((want - sample_mean).abs() < 0.5, "fixture sanity");
}

#[test]
fn embed_texts_semantic_invariants() {
    let Some(rt) = runtime() else { return };
    let texts = vec![
        "the capital of france is paris",
        "the capital of france is paris",
        "a completely different sentence about rate limits",
    ];
    let embs = rt.embed_texts(&texts.iter().map(|s| *s).collect::<Vec<_>>()).unwrap();
    assert_eq!(embs.len(), 3);
    // Unit norm.
    for e in &embs {
        let norm: f32 = e.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
    }
    let same = SemanticRuntime::cosine(&embs[0], &embs[1]);
    let diff = SemanticRuntime::cosine(&embs[0], &embs[2]);
    assert!(same > 0.9999, "identical texts cosine {same}");
    assert!(diff < same, "different texts must score lower ({diff} vs {same})");
}

#[test]
fn bertscore_texts_identity_and_order() {
    let Some(rt) = runtime() else { return };
    let pairs = vec![
        ("new york city", "new york city"),
        ("new york city", "the big apple new york"),
        ("new york city", "quantum flux capacitor"),
    ];
    let scores = rt.bertscore_texts(&pairs).unwrap();
    assert!(scores[0].f1 > 0.999, "identity f1 {}", scores[0].f1);
    assert!(
        scores[1].f1 > scores[2].f1,
        "partial overlap {} must beat disjoint {}",
        scores[1].f1,
        scores[2].f1
    );
    for s in &scores {
        assert!(s.precision <= 1.0 + 1e-4 && s.recall <= 1.0 + 1e-4);
    }
}

#[test]
fn batch_padding_is_transparent() {
    let Some(rt) = runtime() else { return };
    // 1 text vs the same text inside a full batch must embed identically.
    let single = rt.embed_texts(&["hello world"]).unwrap();
    let many: Vec<&str> = std::iter::repeat("hello world").take(17).collect();
    let batch = rt.embed_texts(&many).unwrap();
    for e in &batch {
        let cos = SemanticRuntime::cosine(&single[0], e);
        assert!(cos > 0.9999, "padding changed embedding: cos {cos}");
    }
}
