//! Lexical metrics (paper §4.1): exact match, token F1, BLEU, ROUGE-L,
//! contains. Pure string functions, safe to run inside executor threads.

/// Normalization options for string comparison (paper: "optionally with
/// normalization — lowercasing, punctuation removal").
#[derive(Debug, Clone, Copy)]
pub struct Normalize {
    pub lowercase: bool,
    pub strip_punct: bool,
    pub collapse_ws: bool,
}

impl Default for Normalize {
    fn default() -> Self {
        Self { lowercase: true, strip_punct: true, collapse_ws: true }
    }
}

impl Normalize {
    pub fn none() -> Self {
        Self { lowercase: false, strip_punct: false, collapse_ws: false }
    }

    pub fn apply(&self, s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            let c = if self.lowercase { c.to_ascii_lowercase() } else { c };
            if self.strip_punct && !c.is_alphanumeric() && !c.is_whitespace() {
                continue;
            }
            out.push(c);
        }
        if self.collapse_ws {
            out.split_whitespace().collect::<Vec<_>>().join(" ")
        } else {
            out
        }
    }
}

pub fn tokenize(s: &str) -> Vec<String> {
    s.split(|c: char| !c.is_alphanumeric())
        .filter(|w| !w.is_empty())
        .map(|w| w.to_lowercase())
        .collect()
}

/// Exact match after normalization → 0/1.
///
/// Allocation-free: compares the normalized streams lazily instead of
/// materializing two Strings (§Perf).
pub fn exact_match(candidate: &str, reference: &str, norm: Normalize) -> f64 {
    eq_normalized(candidate, reference, norm) as i64 as f64
}

/// Equality under `Normalize::apply` semantics without allocation.
fn eq_normalized(a: &str, b: &str, norm: Normalize) -> bool {
    let kept = |c: char| -> Option<char> {
        let c = if norm.lowercase { c.to_ascii_lowercase() } else { c };
        if norm.strip_punct && !c.is_alphanumeric() && !c.is_whitespace() {
            None
        } else {
            Some(c)
        }
    };
    if !norm.collapse_ws {
        // Plain filtered-character comparison.
        return a.chars().filter_map(kept).eq(b.chars().filter_map(kept));
    }
    // collapse_ws: the normalized form is the sequence of non-empty
    // filtered whitespace-tokens joined by single spaces — compare the
    // token sequences directly.
    let mut ta = a
        .split_whitespace()
        .map(|t| t.chars().filter_map(kept))
        .filter(|it| it.clone().next().is_some());
    let mut tb = b
        .split_whitespace()
        .map(|t| t.chars().filter_map(kept))
        .filter(|it| it.clone().next().is_some());
    loop {
        match (ta.next(), tb.next()) {
            (None, None) => return true,
            (Some(x), Some(y)) => {
                if !x.eq(y) {
                    return false;
                }
            }
            _ => return false,
        }
    }
}

/// Substring containment (reference inside candidate) → 0/1.
pub fn contains(candidate: &str, reference: &str, norm: Normalize) -> f64 {
    norm.apply(candidate).contains(&norm.apply(reference)) as i64 as f64
}

/// Token-level F1 (SQuAD-style, paper cites Rajpurkar et al. 2016).
///
/// Tokens are compared by case-folded FNV hash — no per-token String
/// allocation (§Perf).
pub fn token_f1(candidate: &str, reference: &str) -> f64 {
    let mut counts: std::collections::HashMap<u64, i64> = std::collections::HashMap::new();
    let mut n_ref = 0usize;
    for h in token_hashes(reference) {
        *counts.entry(h).or_insert(0) += 1;
        n_ref += 1;
    }
    let mut n_cand = 0usize;
    let mut common = 0i64;
    for h in token_hashes(candidate) {
        n_cand += 1;
        if let Some(c) = counts.get_mut(&h) {
            if *c > 0 {
                *c -= 1;
                common += 1;
            }
        }
    }
    if n_cand == 0 && n_ref == 0 {
        return 1.0;
    }
    if common == 0 {
        return 0.0;
    }
    let p = common as f64 / n_cand as f64;
    let r = common as f64 / n_ref as f64;
    2.0 * p * r / (p + r)
}

/// Case-folded FNV hash per alphanumeric token, allocation-free.
fn token_hashes(s: &str) -> impl Iterator<Item = u64> + '_ {
    s.split(|c: char| !c.is_alphanumeric()).filter(|w| !w.is_empty()).map(|w| {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in w.bytes() {
            h ^= b.to_ascii_lowercase() as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    })
}

/// Sentence BLEU with up to 4-gram precision, brevity penalty, and +1
/// smoothing on higher-order n-grams (Lin & Och smoothing method 1 — the
/// standard for sentence-level BLEU).
pub fn bleu(candidate: &str, reference: &str) -> f64 {
    bleu_n(candidate, reference, 4)
}

pub fn bleu_n(candidate: &str, reference: &str, max_n: usize) -> f64 {
    let ct = tokenize(candidate);
    let rt = tokenize(reference);
    if ct.is_empty() || rt.is_empty() {
        return 0.0;
    }
    let max_n = max_n.min(ct.len()).max(1);

    // Hash tokens once; n-grams become rolling 64-bit combinations of the
    // token hashes (no per-ngram Vec/String allocation — §Perf: 3.4x).
    let ch: Vec<u64> = ct.iter().map(|t| fnv64(t)).collect();
    let rh: Vec<u64> = rt.iter().map(|t| fnv64(t)).collect();

    let mut log_sum = 0.0;
    let mut c_counts: std::collections::HashMap<u64, i64> = std::collections::HashMap::new();
    let mut r_counts: std::collections::HashMap<u64, i64> = std::collections::HashMap::new();
    for n in 1..=max_n {
        c_counts.clear();
        r_counts.clear();
        ngram_hash_counts(&ch, n, &mut c_counts);
        ngram_hash_counts(&rh, n, &mut r_counts);
        let total: i64 = c_counts.values().sum();
        if total == 0 {
            return 0.0;
        }
        let mut matched = 0i64;
        for (g, &c) in &c_counts {
            if let Some(&r) = r_counts.get(g) {
                matched += c.min(r);
            }
        }
        // Smoothing: add 1 to numerator and denominator for n > 1.
        let (num, den) = if n == 1 {
            (matched as f64, total as f64)
        } else {
            (matched as f64 + 1.0, total as f64 + 1.0)
        };
        if num == 0.0 {
            return 0.0;
        }
        log_sum += (num / den).ln() / max_n as f64;
    }
    let bp = if ct.len() >= rt.len() {
        1.0
    } else {
        (1.0 - rt.len() as f64 / ct.len() as f64).exp()
    };
    (bp * log_sum.exp()).clamp(0.0, 1.0)
}

fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Combine token hashes of each n-window into one key (order-sensitive).
fn ngram_hash_counts(hashes: &[u64], n: usize, out: &mut std::collections::HashMap<u64, i64>) {
    if hashes.len() < n {
        return;
    }
    for window in hashes.windows(n) {
        let mut key: u64 = 0x9e3779b97f4a7c15;
        for &h in window {
            key = key.rotate_left(17) ^ h.wrapping_mul(0xff51afd7ed558ccd);
        }
        *out.entry(key).or_insert(0) += 1;
    }
}

/// ROUGE-L: LCS-based F1 (paper cites Lin 2004). Uses the standard
/// beta → ∞-free F-measure with beta = 1.
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let ct = tokenize(candidate);
    let rt = tokenize(reference);
    if ct.is_empty() || rt.is_empty() {
        return if ct.is_empty() && rt.is_empty() { 1.0 } else { 0.0 };
    }
    let lcs = lcs_len(&ct, &rt) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / ct.len() as f64;
    let r = lcs / rt.len() as f64;
    2.0 * p * r / (p + r)
}

/// LCS length, O(min) memory rolling rows.
fn lcs_len(a: &[String], b: &[String]) -> usize {
    let (short, long) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut prev = vec![0usize; short.len() + 1];
    let mut cur = vec![0usize; short.len() + 1];
    for item_long in long {
        for (j, item_short) in short.iter().enumerate() {
            cur[j + 1] = if item_long == item_short {
                prev[j] + 1
            } else {
                prev[j + 1].max(cur[j])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[short.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_normalization() {
        assert_eq!(exact_match("Paris!", "paris", Normalize::default()), 1.0);
        assert_eq!(exact_match("Paris!", "paris", Normalize::none()), 0.0);
        assert_eq!(exact_match("  new   york ", "New York.", Normalize::default()), 1.0);
        assert_eq!(exact_match("london", "paris", Normalize::default()), 0.0);
    }

    #[test]
    fn contains_behaviour() {
        assert_eq!(contains("the capital is paris, france", "paris", Normalize::default()), 1.0);
        assert_eq!(contains("the capital is lyon", "paris", Normalize::default()), 0.0);
    }

    #[test]
    fn token_f1_squad_style() {
        assert_eq!(token_f1("paris", "paris"), 1.0);
        assert_eq!(token_f1("", ""), 1.0);
        assert_eq!(token_f1("x", ""), 0.0);
        // Half overlap: candidate "a b", reference "a c" → P=R=0.5 → F1=0.5.
        assert!((token_f1("a b", "a c") - 0.5).abs() < 1e-12);
        // Order-insensitive.
        assert_eq!(token_f1("york new", "new york"), 1.0);
    }

    #[test]
    fn token_f1_with_duplicates() {
        // candidate "a a b", ref "a b b": common = min counts = a:1, b:1 = 2
        // P = 2/3, R = 2/3 → F1 = 2/3.
        assert!((token_f1("a a b", "a b b") - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn bleu_identity_and_disjoint() {
        assert!((bleu("the quick brown fox jumps", "the quick brown fox jumps") - 1.0).abs() < 1e-9);
        assert_eq!(bleu("alpha beta gamma", "delta epsilon zeta"), 0.0);
        assert_eq!(bleu("", "x"), 0.0);
    }

    #[test]
    fn bleu_partial_ordering() {
        let reference = "the cat sat on the mat";
        let good = bleu("the cat sat on a mat", reference);
        let bad = bleu("a dog stood near some grass", reference);
        assert!(good > bad, "good {good} bad {bad}");
        assert!(good > 0.1 && good < 1.0);
    }

    #[test]
    fn bleu_brevity_penalty() {
        let reference = "the cat sat on the mat quietly today";
        let full = bleu("the cat sat on the mat quietly today", reference);
        let short = bleu("the cat", reference);
        assert!(short < full * 0.5, "short {short} full {full}");
    }

    #[test]
    fn rouge_l_known() {
        // candidate "the cat sat", reference "the cat on the mat":
        // LCS = "the cat" (2) → P = 2/3, R = 2/5 → F1 = 0.5.
        let v = rouge_l("the cat sat", "the cat on the mat");
        assert!((v - 0.5).abs() < 1e-12, "rouge {v}");
        assert_eq!(rouge_l("same words here", "same words here"), 1.0);
        assert_eq!(rouge_l("abc", "xyz"), 0.0);
    }

    #[test]
    fn rouge_l_subsequence_not_substring() {
        // LCS respects order but allows gaps.
        let v = rouge_l("a x b y c", "a b c");
        // LCS = a b c = 3 → P = 3/5, R = 1 → F1 = 0.75.
        assert!((v - 0.75).abs() < 1e-12, "rouge {v}");
    }

    #[test]
    fn streaming_equality_matches_apply() {
        // The allocation-free comparator must agree with the reference
        // Normalize::apply implementation on tricky inputs.
        use crate::util::proptest::{check, ensure, gen};
        let cases = [
            ("a!b", "a b"),
            ("a ! b", "a  b"),
            ("...", ""),
            ("  x  ", "x"),
            ("Hello, World!", "hello world"),
            ("tab\there", "tab here"),
            ("", ""),
            ("!.,", "  "),
        ];
        for norm in [Normalize::default(), Normalize::none(),
                     Normalize { lowercase: true, strip_punct: false, collapse_ws: true }] {
            for (a, b) in cases {
                let reference = (norm.apply(a) == norm.apply(b)) as i64 as f64;
                assert_eq!(
                    exact_match(a, b, norm),
                    reference,
                    "({a:?}, {b:?}) under {norm:?}"
                );
            }
        }
        check("streaming equality == apply equality", 300, |rng| {
            let a = gen::sentence(rng, 6).replace(' ', if rng.chance(0.3) { "  " } else { " " });
            let b = if rng.chance(0.5) { a.clone() } else { gen::sentence(rng, 6) };
            let a = if rng.chance(0.3) { format!("{a}!") } else { a };
            let norm = Normalize::default();
            ensure(
                exact_match(&a, &b, norm) == ((norm.apply(&a) == norm.apply(&b)) as i64 as f64),
                format!("mismatch on ({a:?}, {b:?})"),
            )
        });
    }

    #[test]
    fn all_metrics_bounded() {
        let cases = [
            ("", ""),
            ("a", ""),
            ("", "b"),
            ("hello world", "hello there world"),
            ("x y z w", "w z y x"),
        ];
        for (c, r) in cases {
            for v in [
                exact_match(c, r, Normalize::default()),
                contains(c, r, Normalize::default()),
                token_f1(c, r),
                bleu(c, r),
                rouge_l(c, r),
            ] {
                assert!((0.0..=1.0).contains(&v), "({c:?},{r:?}) -> {v}");
            }
        }
    }
}
