//! Metric taxonomy (paper §4.1): lexical, semantic, LLM-as-judge, and RAG
//! metrics, behind a common per-example interface.
//!
//! - Lexical metrics are pure string functions, computed inside the
//!   engine's distributed metric stage.
//! - Semantic metrics batch through the PJRT runtime (SimLM embeddings /
//!   the Pallas BERTScore kernel) on the driver.
//! - Judge and RAG metrics issue additional LLM calls through the same
//!   inference infrastructure (and therefore the same cache) as the main
//!   evaluation.

pub mod judge;
pub mod trajectory;
pub mod lexical;
pub mod rag;
pub mod semantic;

use crate::config::MetricConfig;
use crate::stats::MetricScale;
use anyhow::{bail, Result};

/// Everything a metric may need about one example.
#[derive(Debug, Clone, Default)]
pub struct Example {
    pub prompt: String,
    pub response: String,
    pub reference: String,
    pub question: String,
    pub context: Vec<String>,
    /// Rank of the gold context chunk (-1 = no context / unknown).
    pub gold_position: i64,
}

/// Per-metric result over a set of examples. `None` marks an example the
/// metric could not score (failed inference, unparseable judge output);
/// these are excluded from aggregation and counted (paper §A.3).
#[derive(Debug, Clone)]
pub struct MetricReport {
    pub name: String,
    pub values: Vec<Option<f64>>,
    pub scale: MetricScale,
    /// Unparseable judge responses (subset of the `None`s).
    pub unparseable: usize,
}

impl MetricReport {
    /// The scored values (Nones dropped).
    pub fn scored(&self) -> Vec<f64> {
        self.values.iter().filter_map(|v| *v).collect()
    }

    pub fn n_scored(&self) -> usize {
        self.values.iter().filter(|v| v.is_some()).count()
    }

    pub fn n_failed(&self) -> usize {
        self.values.len() - self.n_scored()
    }
}

/// Declared scale for a registry metric name (drives Table 2 selection).
pub fn metric_scale(name: &str) -> MetricScale {
    match name {
        "exact_match" | "contains" => MetricScale::Binary,
        "token_f1" | "bleu" | "rouge_l" | "embedding_similarity" | "bertscore"
        | "answer_relevance" | "context_precision" | "context_recall" | "faithfulness"
        | "context_relevance" => MetricScale::Continuous,
        name if name.starts_with("judge:") => MetricScale::Ordinal,
        _ => MetricScale::Complex,
    }
}

/// Validate that a metric config names a known metric for its family.
pub fn validate_metric(config: &MetricConfig) -> Result<()> {
    let known_lexical = ["exact_match", "token_f1", "bleu", "rouge_l", "contains"];
    let known_semantic = ["embedding_similarity", "bertscore"];
    let known_rag = [
        "faithfulness",
        "context_relevance",
        "answer_relevance",
        "context_precision",
        "context_recall",
    ];
    match config.metric_type.as_str() {
        "lexical" if known_lexical.contains(&config.name.as_str()) => Ok(()),
        "semantic" if known_semantic.contains(&config.name.as_str()) => Ok(()),
        "llm_judge" => Ok(()), // any name; rubric comes from params
        "rag" if known_rag.contains(&config.name.as_str()) => Ok(()),
        t => bail!("unknown metric '{}' for type '{t}'", config.name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales() {
        assert_eq!(metric_scale("exact_match"), MetricScale::Binary);
        assert_eq!(metric_scale("bleu"), MetricScale::Continuous);
        assert_eq!(metric_scale("judge:helpfulness"), MetricScale::Ordinal);
        assert_eq!(metric_scale("custom_thing"), MetricScale::Complex);
    }

    #[test]
    fn report_accounting() {
        let r = MetricReport {
            name: "m".into(),
            values: vec![Some(1.0), None, Some(0.0)],
            scale: MetricScale::Binary,
            unparseable: 1,
        };
        assert_eq!(r.scored(), vec![1.0, 0.0]);
        assert_eq!(r.n_scored(), 2);
        assert_eq!(r.n_failed(), 1);
    }

    #[test]
    fn validation() {
        assert!(validate_metric(&MetricConfig::new("exact_match", "lexical")).is_ok());
        assert!(validate_metric(&MetricConfig::new("bertscore", "semantic")).is_ok());
        assert!(validate_metric(&MetricConfig::new("helpfulness", "llm_judge")).is_ok());
        assert!(validate_metric(&MetricConfig::new("faithfulness", "rag")).is_ok());
        assert!(validate_metric(&MetricConfig::new("bogus", "lexical")).is_err());
        assert!(validate_metric(&MetricConfig::new("exact_match", "semantic")).is_err());
    }
}
