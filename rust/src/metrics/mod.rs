//! Metric taxonomy (paper §4.1): lexical, semantic, LLM-as-judge, and RAG
//! metrics, behind a common per-example interface.
//!
//! - Lexical metrics are pure string functions, computed inside the
//!   engine's distributed metric stage.
//! - Semantic metrics batch through the PJRT runtime (SimLM embeddings /
//!   the Pallas BERTScore kernel) on the driver.
//! - Judge and RAG metrics issue additional LLM calls through the same
//!   inference infrastructure (and therefore the same cache) as the main
//!   evaluation.

pub mod judge;
pub mod trajectory;
pub mod lexical;
pub mod rag;
pub mod registry;
pub mod semantic;

pub use registry::{
    builtin_registry, JudgeBroker, Metric, MetricContext, MetricFactory, MetricRegistry,
    MetricRequirements, ResolvedMetric, ScoreBatch,
};

use crate::stats::MetricScale;
use crate::util::json::Json;

/// Everything a metric may need about one example.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Example {
    pub prompt: String,
    pub response: String,
    pub reference: String,
    pub question: String,
    pub context: Vec<String>,
    /// Rank of the gold context chunk (-1 = no context / unknown).
    pub gold_position: i64,
}

impl Example {
    /// Wire encoding for serializable task plans
    /// ([`crate::sched::plan::MetricPlan`]): out-of-process metric
    /// scoring ships examples to the worker as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prompt", Json::str(&self.prompt)),
            ("response", Json::str(&self.response)),
            ("reference", Json::str(&self.reference)),
            ("question", Json::str(&self.question)),
            ("context", Json::arr(self.context.iter().map(|c| Json::str(c)).collect())),
            ("gold_position", Json::num(self.gold_position as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Example> {
        Ok(Example {
            prompt: v.str_or("prompt", "").to_string(),
            response: v.str_or("response", "").to_string(),
            reference: v.str_or("reference", "").to_string(),
            question: v.str_or("question", "").to_string(),
            context: match v.opt("context") {
                Some(c) => c
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<anyhow::Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            gold_position: v.f64_or("gold_position", -1.0) as i64,
        })
    }
}

/// Per-metric result over a set of examples. `None` marks an example the
/// metric could not score (failed inference, unparseable judge output);
/// these are excluded from aggregation and counted (paper §A.3).
#[derive(Debug, Clone)]
pub struct MetricReport {
    pub name: String,
    pub values: Vec<Option<f64>>,
    pub scale: MetricScale,
    /// Unparseable judge responses (subset of the `None`s).
    pub unparseable: usize,
}

impl MetricReport {
    /// The scored values (Nones dropped).
    pub fn scored(&self) -> Vec<f64> {
        self.values.iter().filter_map(|v| *v).collect()
    }

    pub fn n_scored(&self) -> usize {
        self.values.iter().filter(|v| v.is_some()).count()
    }

    pub fn n_failed(&self) -> usize {
        self.values.len() - self.n_scored()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MetricConfig;

    #[test]
    fn scales_come_from_the_registry() {
        // `metric_scale(name)` and its hardcoded name lists are gone: the
        // registry resolves scale from (name, family), and unknown names
        // error at load time instead of silently becoming Complex.
        let reg = builtin_registry();
        let scale =
            |n: &str, f: &str| reg.scale_of(&MetricConfig::new(n, f)).unwrap();
        assert_eq!(scale("exact_match", "lexical"), MetricScale::Binary);
        assert_eq!(scale("bleu", "lexical"), MetricScale::Continuous);
        assert_eq!(scale("judge:helpfulness", "llm_judge"), MetricScale::Ordinal);
        assert_eq!(scale("helpfulness", "llm_judge"), MetricScale::Ordinal);
        assert!(reg.scale_of(&MetricConfig::new("custom_thing", "lexical")).is_err());
    }

    #[test]
    fn report_accounting() {
        let r = MetricReport {
            name: "m".into(),
            values: vec![Some(1.0), None, Some(0.0)],
            scale: MetricScale::Binary,
            unparseable: 1,
        };
        assert_eq!(r.scored(), vec![1.0, 0.0]);
        assert_eq!(r.n_scored(), 2);
        assert_eq!(r.n_failed(), 1);
    }

    #[test]
    fn validation() {
        let reg = builtin_registry();
        assert!(reg.check(&MetricConfig::new("exact_match", "lexical")).is_ok());
        assert!(reg.check(&MetricConfig::new("bertscore", "semantic")).is_ok());
        assert!(reg.check(&MetricConfig::new("helpfulness", "llm_judge")).is_ok());
        assert!(reg.check(&MetricConfig::new("faithfulness", "rag")).is_ok());
        assert!(reg.check(&MetricConfig::new("bogus", "lexical")).is_err());
        assert!(reg.check(&MetricConfig::new("exact_match", "semantic")).is_err());
    }
}
