//! Semantic metrics (paper §4.1): embedding cosine similarity and
//! BERTScore, computed through the PJRT artifacts (SimLM encoder + the L1
//! Pallas max-matching kernel). Batched on the driver — PJRT handles are
//! not `Send`.

use super::Example;
use crate::runtime::SemanticRuntime;
use anyhow::Result;

/// Cosine similarity between pooled embeddings of response and reference.
pub fn embedding_similarity_batch(
    runtime: &SemanticRuntime,
    examples: &[Example],
) -> Result<Vec<Option<f64>>> {
    if examples.is_empty() {
        return Ok(vec![]);
    }
    // One interleaved embed pass: [resp0, ref0, resp1, ref1, ...] halves
    // the number of PJRT batches vs two separate passes.
    let mut texts: Vec<&str> = Vec::with_capacity(examples.len() * 2);
    for ex in examples {
        texts.push(&ex.response);
        texts.push(&ex.reference);
    }
    let embs = runtime.embed_texts(&texts)?;
    Ok((0..examples.len())
        .map(|i| {
            let cos = SemanticRuntime::cosine(&embs[2 * i], &embs[2 * i + 1]) as f64;
            Some(cos.clamp(-1.0, 1.0))
        })
        .collect())
}

/// BERTScore F1 between response and reference (the L1 kernel path).
pub fn bertscore_batch(
    runtime: &SemanticRuntime,
    examples: &[Example],
) -> Result<Vec<Option<f64>>> {
    if examples.is_empty() {
        return Ok(vec![]);
    }
    let pairs: Vec<(&str, &str)> = examples
        .iter()
        .map(|ex| (ex.response.as_str(), ex.reference.as_str()))
        .collect();
    let scores = runtime.bertscore_texts(&pairs)?;
    Ok(scores.into_iter().map(|s| Some(s.f1 as f64)).collect())
}

/// Answer relevance (RAG family, but embedding-based per the paper §4.1:
/// "computed via embedding similarity between question and answer").
pub fn answer_relevance_batch(
    runtime: &SemanticRuntime,
    examples: &[Example],
) -> Result<Vec<Option<f64>>> {
    if examples.is_empty() {
        return Ok(vec![]);
    }
    let mut texts: Vec<&str> = Vec::with_capacity(examples.len() * 2);
    for ex in examples {
        texts.push(&ex.response);
        texts.push(&ex.question);
    }
    let embs = runtime.embed_texts(&texts)?;
    Ok((0..examples.len())
        .map(|i| {
            let cos = SemanticRuntime::cosine(&embs[2 * i], &embs[2 * i + 1]) as f64;
            Some(cos.clamp(-1.0, 1.0))
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn runtime() -> Option<SemanticRuntime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(SemanticRuntime::load(&dir).unwrap())
    }

    fn ex(response: &str, reference: &str, question: &str) -> Example {
        Example {
            response: response.into(),
            reference: reference.into(),
            question: question.into(),
            ..Default::default()
        }
    }

    #[test]
    fn similarity_orders_by_relatedness() {
        let Some(rt) = runtime() else { return };
        let examples = vec![
            ex("paris", "paris", ""),
            ex("the capital city paris", "paris", ""),
            ex("bananas are yellow fruit", "paris", ""),
        ];
        let sims = embedding_similarity_batch(&rt, &examples).unwrap();
        let s: Vec<f64> = sims.into_iter().flatten().collect();
        assert!(s[0] > 0.999, "identity {}", s[0]);
        assert!(s[1] > s[2], "partial {} > unrelated {}", s[1], s[2]);
    }

    #[test]
    fn bertscore_identity() {
        let Some(rt) = runtime() else { return };
        let examples = vec![
            ex("exact same answer", "exact same answer", ""),
            ex("totally different words entirely", "exact same answer", ""),
        ];
        let scores = bertscore_batch(&rt, &examples).unwrap();
        assert!(scores[0].unwrap() > 0.999);
        assert!(scores[1].unwrap() < scores[0].unwrap());
    }

    #[test]
    fn answer_relevance_uses_question() {
        let Some(rt) = runtime() else { return };
        let examples = vec![
            ex("the capital of france is paris", "", "what is the capital of france"),
            ex("unrelated response about databases", "", "what is the capital of france"),
        ];
        let rel = answer_relevance_batch(&rt, &examples).unwrap();
        assert!(rel[0].unwrap() > rel[1].unwrap());
    }

    #[test]
    fn empty_input_ok() {
        let Some(rt) = runtime() else { return };
        assert!(embedding_similarity_batch(&rt, &[]).unwrap().is_empty());
        assert!(bertscore_batch(&rt, &[]).unwrap().is_empty());
    }
}
