//! LLM-as-judge metrics (paper §4.1, §A.3): pointwise rubric grading and
//! pairwise comparison. Judge prompts follow a structured format (after
//! Zheng et al. 2023) requesting a numeric score and explanation; scores
//! are extracted by hand-rolled scanners (the offline crate set has no
//! `regex`), and unparseable responses are logged, excluded from
//! aggregation, and counted.

use super::Example;
use crate::providers::{InferenceEngine, InferenceRequest};

/// Build the pointwise judge prompt. The `### SLLEVAL-JUDGE-POINTWISE`
/// sentinel is part of the template structure the simulated judge (and a
//  real judge prompt) keys on.
pub fn pointwise_prompt(rubric: &str, ex: &Example) -> String {
    format!(
        "### SLLEVAL-JUDGE-POINTWISE\n\
         You are an impartial judge. Rate the candidate response on the\n\
         rubric below with an integer score from 1 to 5, then explain.\n\
         Rubric: {rubric}\n\
         ### QUESTION\n{q}\n\
         ### CANDIDATE\n{c}\n\
         ### REFERENCE\n{r}\n\
         ### END\n\
         Respond exactly as:\nScore: <1-5>\nExplanation: <why>",
        q = ex.question,
        c = ex.response,
        r = ex.reference,
    )
}

/// Build the pairwise comparison prompt (A = response_a, B = response_b).
pub fn pairwise_prompt(rubric: &str, question: &str, a: &str, b: &str, reference: &str) -> String {
    format!(
        "### SLLEVAL-JUDGE-PAIRWISE\n\
         You are an impartial judge. Decide which response better satisfies\n\
         the rubric. Answer with Verdict: A or Verdict: B.\n\
         Rubric: {rubric}\n\
         ### QUESTION\n{question}\n\
         ### RESPONSE-A\n{a}\n\
         ### RESPONSE-B\n{b}\n\
         ### REFERENCE\n{reference}\n\
         ### END",
    )
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets (leftmost-first) where `needle` occurs in `haystack`,
/// compared ASCII-case-insensitively.
fn find_ci(haystack: &[u8], needle: &[u8]) -> impl Iterator<Item = usize> + '_ {
    let needle: Vec<u8> = needle.to_ascii_lowercase();
    (0..haystack.len().saturating_sub(needle.len() - 1)).filter(move |&i| {
        haystack[i..i + needle.len()].eq_ignore_ascii_case(&needle)
    })
}

/// Advance past ASCII whitespace starting at `pos`.
fn skip_ws(bytes: &[u8], mut pos: usize) -> usize {
    while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
        pos += 1;
    }
    pos
}

/// Parse a single digit in `lo..=hi` at `pos`, requiring a word boundary
/// after it (equivalent of the `([lo-hi])\b` capture).
fn digit_at(bytes: &[u8], pos: usize, lo: u8, hi: u8) -> Option<u8> {
    let b = *bytes.get(pos)?;
    if !(lo..=hi).contains(&b) {
        return None;
    }
    match bytes.get(pos + 1) {
        Some(&next) if is_word_byte(next) => None,
        _ => Some(b - b'0'),
    }
}

/// Extract `Score: N` (1–5). Returns None when unparseable.
///
/// Primary pattern `score [:=] N`, then looser fallbacks ("4/5",
/// "score of 3") — hand-rolled equivalents of the original regexes.
pub fn parse_score(text: &str) -> Option<f64> {
    let bytes = text.as_bytes();

    // (?i)score\s*[:=]\s*([1-5])\b
    for start in find_ci(bytes, b"score") {
        let pos = skip_ws(bytes, start + 5);
        if !matches!(bytes.get(pos), Some(b':') | Some(b'=')) {
            continue;
        }
        let pos = skip_ws(bytes, pos + 1);
        if let Some(d) = digit_at(bytes, pos, b'1', b'5') {
            return Some(d as f64);
        }
    }

    // \b([1-5])\s*/\s*5\b
    for (i, &b) in bytes.iter().enumerate() {
        if !(b'1'..=b'5').contains(&b) {
            continue;
        }
        if i > 0 && is_word_byte(bytes[i - 1]) {
            continue; // no word boundary before the digit
        }
        let pos = skip_ws(bytes, i + 1);
        if bytes.get(pos) != Some(&b'/') {
            continue;
        }
        let pos = skip_ws(bytes, pos + 1);
        if digit_at(bytes, pos, b'5', b'5').is_some() {
            return Some((b - b'0') as f64);
        }
    }

    // (?i)score of\s*([1-5])\b
    for start in find_ci(bytes, b"score of") {
        let pos = skip_ws(bytes, start + 8);
        if let Some(d) = digit_at(bytes, pos, b'1', b'5') {
            return Some(d as f64);
        }
    }

    None
}

/// Extract `Verdict: A|B` from a pairwise judge response (the hand-rolled
/// equivalent of `(?i)verdict\s*[:=]\s*([AB])\b`).
pub fn parse_verdict(text: &str) -> Option<char> {
    let bytes = text.as_bytes();
    for start in find_ci(bytes, b"verdict") {
        let pos = skip_ws(bytes, start + 7);
        if !matches!(bytes.get(pos), Some(b':') | Some(b'=')) {
            continue;
        }
        let pos = skip_ws(bytes, pos + 1);
        let verdict = match bytes.get(pos) {
            Some(b'A') | Some(b'a') => 'A',
            Some(b'B') | Some(b'b') => 'B',
            _ => continue,
        };
        match bytes.get(pos + 1) {
            Some(&next) if is_word_byte(next) => continue,
            _ => return Some(verdict),
        }
    }
    None
}

/// Outcome of a pointwise judging pass.
#[derive(Debug, Clone)]
pub struct JudgeOutcome {
    pub scores: Vec<Option<f64>>,
    pub unparseable: usize,
    /// (example index, raw response) of unparseable outputs, for review.
    pub unparseable_log: Vec<(usize, String)>,
    pub failed_calls: usize,
}

/// Grade each example with the judge engine (sequential; the coordinator
/// parallelizes across executors when the judge runs distributed).
pub fn grade_pointwise(
    engine: &mut dyn InferenceEngine,
    rubric: &str,
    examples: &[Example],
    max_tokens: usize,
) -> JudgeOutcome {
    let mut scores = Vec::with_capacity(examples.len());
    let mut unparseable = 0;
    let mut unparseable_log = Vec::new();
    let mut failed_calls = 0;
    for (i, ex) in examples.iter().enumerate() {
        let mut req = InferenceRequest::new(pointwise_prompt(rubric, ex));
        req.max_tokens = max_tokens;
        match engine.infer(&req) {
            Ok(resp) => match parse_score(&resp.text) {
                Some(s) => scores.push(Some(s)),
                None => {
                    unparseable += 1;
                    unparseable_log.push((i, resp.text));
                    scores.push(None);
                }
            },
            Err(_) => {
                failed_calls += 1;
                scores.push(None);
            }
        }
    }
    JudgeOutcome { scores, unparseable, unparseable_log, failed_calls }
}

/// Pairwise comparison outcome: +1 = A wins, -1 = B wins, None unparseable.
pub fn compare_pairwise(
    engine: &mut dyn InferenceEngine,
    rubric: &str,
    question: &str,
    response_a: &str,
    response_b: &str,
    reference: &str,
) -> Option<i32> {
    let req = InferenceRequest::new(pairwise_prompt(rubric, question, response_a, response_b, reference));
    match engine.infer(&req) {
        Ok(resp) => parse_verdict(&resp.text).map(|v| if v == 'A' { 1 } else { -1 }),
        Err(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::providers::simulated::{SimEngine, SimService, SimServiceConfig};
    use crate::ratelimit::VirtualClock;

    #[test]
    fn parse_score_patterns() {
        assert_eq!(parse_score("Score: 4\nExplanation: good"), Some(4.0));
        assert_eq!(parse_score("score = 2"), Some(2.0));
        assert_eq!(parse_score("I'd give it 3/5 overall"), Some(3.0));
        assert_eq!(parse_score("a score of 5 seems right"), Some(5.0));
        assert_eq!(parse_score("this is quite good"), None);
        assert_eq!(parse_score("Score: 9"), None); // out of rubric range
    }

    #[test]
    fn parse_verdict_patterns() {
        assert_eq!(parse_verdict("Verdict: A\nbecause..."), Some('A'));
        assert_eq!(parse_verdict("verdict = b"), Some('B'));
        assert_eq!(parse_verdict("both are fine"), None);
    }

    fn judge_engine(unparseable_rate: f64) -> SimEngine {
        let clock = VirtualClock::new();
        let svc = SimService::new(
            "openai",
            SimServiceConfig {
                server_error_rate: 0.0,
                unparseable_rate,
                sleep_latency: false,
                ..Default::default()
            },
            clock.clone(),
        );
        let mut e = SimEngine::new(svc, "openai", "gpt-4o", clock).unwrap();
        e.initialize().unwrap();
        e
    }

    fn ex(response: &str, reference: &str) -> Example {
        Example {
            question: "what is the capital of france?".into(),
            response: response.into(),
            reference: reference.into(),
            ..Default::default()
        }
    }

    #[test]
    fn grading_correlates_with_quality() {
        let mut engine = judge_engine(0.0);
        let good = vec![ex("paris", "paris"); 5];
        let bad = vec![ex("completely wrong rambling answer", "paris"); 5];
        let g = grade_pointwise(&mut engine, "helpfulness", &good, 256);
        let b = grade_pointwise(&mut engine, "helpfulness", &bad, 256);
        let gm: f64 = g.scores.iter().flatten().sum::<f64>() / g.scores.len() as f64;
        let bm: f64 = b.scores.iter().flatten().sum::<f64>() / b.scores.len() as f64;
        assert!(gm > bm + 1.0, "good {gm} bad {bm}");
        assert_eq!(g.unparseable, 0);
    }

    #[test]
    fn unparseable_tracked() {
        let mut engine = judge_engine(0.5);
        // Distinct examples so the per-prompt corruption draw varies.
        let examples: Vec<Example> = (0..60)
            .map(|i| ex(&format!("answer variant {i}"), "reference"))
            .collect();
        let out = grade_pointwise(&mut engine, "helpfulness", &examples, 256);
        assert!(out.unparseable > 10, "unparseable {}", out.unparseable);
        assert_eq!(out.unparseable_log.len(), out.unparseable);
        assert_eq!(
            out.scores.iter().filter(|s| s.is_none()).count(),
            out.unparseable + out.failed_calls
        );
    }

    #[test]
    fn pairwise_prefers_better() {
        let mut engine = judge_engine(0.0);
        let v = compare_pairwise(
            &mut engine,
            "accuracy",
            "what is the capital of france?",
            "paris",
            "rome",
            "paris",
        );
        assert_eq!(v, Some(1));
        let v = compare_pairwise(
            &mut engine,
            "accuracy",
            "what is the capital of france?",
            "rome",
            "paris",
            "paris",
        );
        assert_eq!(v, Some(-1));
    }
}
