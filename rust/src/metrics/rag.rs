//! RAG metrics (paper §4.1, after the RAGAS framework):
//!
//! - **Faithfulness** — split the answer into claims, ask the judge to
//!   verify each against the retrieved context; score = supported/total.
//! - **Context relevance** — judge-scored relevance of the retrieved
//!   context to the question (rubric 1–5 normalized to [0,1]).
//! - **Answer relevance** — embedding similarity question↔answer
//!   (implemented in [`super::semantic`]).
//! - **Context precision** — rank-weighted position of the gold chunk.
//! - **Context recall** — fraction of reference tokens covered by the
//!   context (needs ground truth).

use super::judge::parse_score;
use super::lexical::tokenize;
use super::Example;
use crate::providers::{InferenceEngine, InferenceRequest};

/// Split an answer into claim sentences (simple clause splitter).
pub fn split_claims(answer: &str) -> Vec<String> {
    answer
        .split(['.', ';', '\n'])
        .map(|s| s.trim())
        .filter(|s| s.split_whitespace().count() >= 2)
        .map(|s| s.to_string())
        .collect()
}

/// Build the claim-verification judge prompt.
pub fn verify_prompt(claim: &str, context: &str) -> String {
    format!(
        "### SLLEVAL-JUDGE-VERIFY\n\
         Does the context support the claim? Answer Verdict: SUPPORTED or\n\
         Verdict: UNSUPPORTED.\n\
         ### CLAIM\n{claim}\n\
         ### CONTEXT\n{context}\n\
         ### END",
    )
}

/// Faithfulness: fraction of answer claims supported by the context.
/// Answers with no extractable claims score None (excluded + counted).
pub fn faithfulness(engine: &mut dyn InferenceEngine, ex: &Example) -> Option<f64> {
    if ex.context.is_empty() {
        return None;
    }
    let claims = {
        let c = split_claims(&ex.response);
        if c.is_empty() {
            // Short answers ("paris") are a single claim.
            if ex.response.trim().is_empty() {
                return None;
            }
            vec![ex.response.trim().to_string()]
        } else {
            c
        }
    };
    let context = ex.context.join("\n");
    let mut supported = 0usize;
    let mut judged = 0usize;
    for claim in &claims {
        let req = InferenceRequest::new(verify_prompt(claim, &context));
        if let Ok(resp) = engine.infer(&req) {
            judged += 1;
            if resp.text.to_uppercase().contains("SUPPORTED")
                && !resp.text.to_uppercase().contains("UNSUPPORTED")
            {
                supported += 1;
            }
        }
    }
    if judged == 0 {
        None
    } else {
        Some(supported as f64 / judged as f64)
    }
}

/// Context relevance: judge-scored 1–5 normalized to [0,1].
pub fn context_relevance(engine: &mut dyn InferenceEngine, ex: &Example) -> Option<f64> {
    if ex.context.is_empty() {
        return None;
    }
    let prompt = format!(
        "### SLLEVAL-JUDGE-POINTWISE\n\
         Rate how relevant the candidate context passage is to the question\n\
         from 1 (irrelevant) to 5 (directly answers it).\n\
         Rubric: context relevance\n\
         ### QUESTION\n{q}\n\
         ### CANDIDATE\n{c}\n\
         ### REFERENCE\n{q}\n\
         ### END\n\
         Respond exactly as:\nScore: <1-5>",
        q = ex.question,
        c = ex.context.join("\n"),
    );
    let resp = engine.infer(&InferenceRequest::new(prompt)).ok()?;
    parse_score(&resp.text).map(|s| (s - 1.0) / 4.0)
}

/// Context precision: reciprocal-rank weighting of the gold chunk
/// (1.0 when the relevant chunk is ranked first).
pub fn context_precision(ex: &Example) -> Option<f64> {
    if ex.context.is_empty() || ex.gold_position < 0 {
        return None;
    }
    let pos = ex.gold_position as usize;
    if pos >= ex.context.len() {
        return Some(0.0);
    }
    Some(1.0 / (pos as f64 + 1.0))
}

/// Context recall: fraction of reference tokens present in the context.
pub fn context_recall(ex: &Example) -> Option<f64> {
    if ex.context.is_empty() || ex.reference.is_empty() {
        return None;
    }
    let ref_tokens = tokenize(&ex.reference);
    if ref_tokens.is_empty() {
        return None;
    }
    let ctx_tokens: std::collections::HashSet<String> =
        tokenize(&ex.context.join(" ")).into_iter().collect();
    let covered = ref_tokens.iter().filter(|t| ctx_tokens.contains(*t)).count();
    Some(covered as f64 / ref_tokens.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::providers::simulated::{SimEngine, SimService, SimServiceConfig};
    use crate::ratelimit::VirtualClock;

    fn engine() -> SimEngine {
        let clock = VirtualClock::new();
        let svc = SimService::new(
            "openai",
            SimServiceConfig {
                server_error_rate: 0.0,
                unparseable_rate: 0.0,
                sleep_latency: false,
                ..Default::default()
            },
            clock.clone(),
        );
        let mut e = SimEngine::new(svc, "openai", "gpt-4o", clock).unwrap();
        e.initialize().unwrap();
        e
    }

    fn rag_example(response: &str, gold_position: i64) -> Example {
        Example {
            question: "what is the capital of france?".into(),
            response: response.into(),
            reference: "paris".into(),
            context: vec![
                "japan is an island nation; its capital city is tokyo".into(),
                "france is a european country; its capital city is paris".into(),
                "brazil is a large country; its capital city is brasilia".into(),
            ],
            gold_position,
            ..Default::default()
        }
    }

    #[test]
    fn split_claims_behaviour() {
        let claims = split_claims("paris is the capital. it is in france; europe contains it.");
        assert_eq!(claims.len(), 3);
        assert!(split_claims("").is_empty());
    }

    #[test]
    fn faithfulness_grounded_vs_not() {
        let mut e = engine();
        let grounded = rag_example("the capital city is paris, france is a european country", 1);
        let fabricated = rag_example("the moon is made of swiss cheese entirely", 1);
        let fg = faithfulness(&mut e, &grounded).unwrap();
        let ff = faithfulness(&mut e, &fabricated).unwrap();
        assert!(fg > ff, "grounded {fg} fabricated {ff}");
        assert!(fg > 0.5);
    }

    #[test]
    fn faithfulness_none_without_context() {
        let mut e = engine();
        let ex = Example { response: "paris".into(), ..Default::default() };
        assert!(faithfulness(&mut e, &ex).is_none());
    }

    #[test]
    fn context_relevance_scores() {
        let mut e = engine();
        let ex = rag_example("paris", 1);
        let rel = context_relevance(&mut e, &ex).unwrap();
        assert!((0.0..=1.0).contains(&rel));
    }

    #[test]
    fn context_precision_rank_weighting() {
        assert_eq!(context_precision(&rag_example("x", 0)), Some(1.0));
        assert_eq!(context_precision(&rag_example("x", 1)), Some(0.5));
        assert_eq!(context_precision(&rag_example("x", 2)), Some(1.0 / 3.0));
        assert_eq!(context_precision(&rag_example("x", -1)), None);
        // Out-of-range gold position scores 0, not a crash.
        assert_eq!(context_precision(&rag_example("x", 99)), Some(0.0));
    }

    #[test]
    fn context_recall_coverage() {
        let ex = rag_example("whatever", 1);
        // "paris" appears in the context → full recall of the 1-token ref.
        assert_eq!(context_recall(&ex), Some(1.0));
        let mut ex2 = rag_example("whatever", 1);
        ex2.reference = "paris unknownword".into();
        assert_eq!(context_recall(&ex2), Some(0.5));
        let mut ex3 = rag_example("whatever", 1);
        ex3.context.clear();
        assert_eq!(context_recall(&ex3), None);
    }
}
