//! Multi-turn / agent-trajectory metrics (paper §6.2: "richer support for
//! conversational evaluation where context accumulates across turns").
//!
//! A [`Trajectory`] is an ordered list of turns, each with its own
//! response and reference. Metrics:
//!
//! - **per-turn score** with any single-turn metric, with the running
//!   conversation prefixed to the prompt (context accumulation);
//! - **trajectory success** — all turns above a threshold (binary);
//! - **goal completion** — final-turn score (did the conversation land);
//! - **consistency decay** — slope of per-turn scores (does quality
//!   degrade as context grows).

use super::lexical;

/// One conversational turn.
#[derive(Debug, Clone)]
pub struct Turn {
    pub user: String,
    pub response: String,
    pub reference: String,
}

/// A conversation / agent trajectory.
#[derive(Debug, Clone, Default)]
pub struct Trajectory {
    pub turns: Vec<Turn>,
}

impl Trajectory {
    pub fn new(turns: Vec<Turn>) -> Self {
        Self { turns }
    }

    /// Accumulated conversation context up to (excluding) turn `i`.
    pub fn context_before(&self, i: usize) -> String {
        let mut out = String::new();
        for t in &self.turns[..i.min(self.turns.len())] {
            out.push_str(&format!("User: {}\nAssistant: {}\n", t.user, t.response));
        }
        out
    }
}

/// Per-turn scores with a single-turn scorer.
pub fn per_turn_scores<F>(traj: &Trajectory, scorer: F) -> Vec<f64>
where
    F: Fn(&str, &str) -> f64,
{
    traj.turns.iter().map(|t| scorer(&t.response, &t.reference)).collect()
}

/// Trajectory success: every turn ≥ threshold → 1.0, else 0.0.
pub fn trajectory_success(traj: &Trajectory, threshold: f64) -> f64 {
    if traj.turns.is_empty() {
        return 0.0;
    }
    let ok = per_turn_scores(traj, lexical::token_f1)
        .iter()
        .all(|&s| s >= threshold);
    ok as i64 as f64
}

/// Goal completion: final-turn token F1.
pub fn goal_completion(traj: &Trajectory) -> f64 {
    traj.turns
        .last()
        .map(|t| lexical::token_f1(&t.response, &t.reference))
        .unwrap_or(0.0)
}

/// Consistency decay: least-squares slope of per-turn scores over turn
/// index. Negative = quality degrades as context accumulates.
pub fn consistency_decay(traj: &Trajectory) -> f64 {
    let scores = per_turn_scores(traj, lexical::token_f1);
    let n = scores.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let mean_x = (nf - 1.0) / 2.0;
    let mean_y = scores.iter().sum::<f64>() / nf;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in scores.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (y - mean_y);
        den += dx * dx;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn turn(u: &str, r: &str, reference: &str) -> Turn {
        Turn { user: u.into(), response: r.into(), reference: reference.into() }
    }

    fn good_traj() -> Trajectory {
        Trajectory::new(vec![
            turn("book a table", "booked a table for two", "booked a table for two"),
            turn("make it 8pm", "moved the booking to 8pm", "moved the booking to 8pm"),
            turn("confirm", "your booking is confirmed", "your booking is confirmed"),
        ])
    }

    fn degrading_traj() -> Trajectory {
        Trajectory::new(vec![
            turn("q1", "perfect answer one", "perfect answer one"),
            turn("q2", "partial answer two-ish", "perfect answer two"),
            turn("q3", "completely lost now", "perfect answer three"),
        ])
    }

    #[test]
    fn success_and_goal() {
        assert_eq!(trajectory_success(&good_traj(), 0.9), 1.0);
        assert_eq!(trajectory_success(&degrading_traj(), 0.9), 0.0);
        assert_eq!(goal_completion(&good_traj()), 1.0);
        assert!(goal_completion(&degrading_traj()) < 0.5);
    }

    #[test]
    fn decay_slope_signs() {
        assert!(consistency_decay(&degrading_traj()) < -0.1);
        assert!(consistency_decay(&good_traj()).abs() < 1e-9);
        assert_eq!(consistency_decay(&Trajectory::default()), 0.0);
    }

    #[test]
    fn context_accumulates() {
        let t = good_traj();
        assert_eq!(t.context_before(0), "");
        let ctx = t.context_before(2);
        assert!(ctx.contains("book a table"));
        assert!(ctx.contains("moved the booking"));
        assert!(!ctx.contains("confirmed"));
    }

    #[test]
    fn per_turn_scores_align() {
        let s = per_turn_scores(&degrading_traj(), lexical::token_f1);
        assert_eq!(s.len(), 3);
        assert!(s[0] > s[1] && s[1] > s[2], "{s:?}");
    }

    #[test]
    fn empty_trajectory_safe() {
        let t = Trajectory::default();
        assert_eq!(trajectory_success(&t, 0.5), 0.0);
        assert_eq!(goal_completion(&t), 0.0);
    }
}
