//! First-class metric API: the [`Metric`] trait and the [`MetricRegistry`].
//!
//! The registry is the **single source of truth** for metric names,
//! families, scales, and validation. Every built-in metric (lexical,
//! semantic, LLM-judge, RAG) is a registry entry; custom metrics are
//! registered objects; and an [`crate::config::EvalTask`]'s
//! `MetricConfig`s resolve through the registry at *load* time — a typo'd
//! metric name fails before any inference spend, and a judge metric named
//! plainly (`helpfulness`, no `judge:` prefix) still gets the `Ordinal`
//! scale its significance test depends on (Miller 2024: the scale must
//! drive the CI/test machinery).
//!
//! A metric's [`MetricRequirements`] drive how the coordinator dispatches
//! it:
//!
//! - [`MetricRequirements::Pure`] — a pure function of the [`Example`];
//!   schedulable as distributed executor tasks (lexical metrics,
//!   rank-based RAG metrics, custom scorers). This is what makes
//!   `slleval rescore` scale across executors like inference does.
//! - [`MetricRequirements::Runtime`] — needs the PJRT semantic runtime
//!   (embeddings / BERTScore); batched on the driver because PJRT handles
//!   are not `Send`.
//! - [`MetricRequirements::Judge`] — issues LLM calls through a
//!   [`JudgeBroker`]-built engine (and therefore through the response
//!   cache, so replay/rescore cover judge metrics too).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::{judge, lexical, rag, semantic, Example};
use crate::config::{EvalTask, MetricConfig};
use crate::providers::InferenceEngine;
use crate::runtime::SemanticRuntime;
use crate::stats::MetricScale;

/// What a metric needs from the coordinator to score a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricRequirements {
    /// Pure function of the example — safe to run inside executor threads.
    Pure,
    /// Needs the PJRT semantic runtime (driver-side batches).
    Runtime,
    /// Needs LLM judge calls through a [`JudgeBroker`] engine.
    Judge,
}

/// Scored batch: one value per input example (`None` = unscorable) plus
/// the number of unparseable judge responses among the `None`s.
///
/// `unparseable` is meaningful only for judge-backed metrics; `Pure`
/// metrics must leave it 0 (the coordinator enforces this — their
/// batches may be re-executed speculatively, where a side count could
/// not be attributed) and report unscorable rows as `None` values.
#[derive(Debug, Clone, Default)]
pub struct ScoreBatch {
    pub values: Vec<Option<f64>>,
    pub unparseable: usize,
}

impl ScoreBatch {
    /// A batch where every example scored (the common pure-metric case).
    pub fn scored(values: Vec<Option<f64>>) -> Self {
        Self { values, unparseable: 0 }
    }
}

/// Builds judge engines on demand. Implemented by the coordinator so
/// judge calls flow through its provider services, the response cache,
/// and call metering — metrics never construct engines themselves.
pub trait JudgeBroker {
    fn engine(&self, provider: &str, model: &str) -> Result<Box<dyn InferenceEngine>>;
}

/// Everything a metric may draw on while scoring. Pure metrics receive a
/// [`MetricContext::detached`] context inside executor threads; runtime
/// and judge metrics receive the driver's full context.
pub struct MetricContext<'a> {
    pub runtime: Option<&'a SemanticRuntime>,
    pub judge: Option<&'a dyn JudgeBroker>,
    /// Fallback judge provider/model (the task's main model) when the
    /// metric config doesn't override them.
    pub default_provider: &'a str,
    pub default_model: &'a str,
}

impl MetricContext<'_> {
    /// A context with no driver facilities — what pure metrics get when
    /// dispatched as scheduler tasks.
    pub fn detached() -> MetricContext<'static> {
        MetricContext { runtime: None, judge: None, default_provider: "", default_model: "" }
    }
}

/// A scoring metric. Implementations must be cheap to construct (the
/// registry builds one per resolved `MetricConfig`) and thread-safe
/// (pure metrics are scored inside executor threads).
pub trait Metric: Send + Sync {
    /// Registry/report name (e.g. `exact_match`, `helpfulness`).
    fn name(&self) -> &str;
    /// Measurement scale — drives CI method and significance-test
    /// selection (paper Table 2).
    fn scale(&self) -> MetricScale;
    /// What the coordinator must provide to score this metric.
    fn requirements(&self) -> MetricRequirements;
    /// Score a batch of examples: exactly one value per example, in
    /// order. Failed-inference masking is the coordinator's job.
    fn score_batch(&self, ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch>;
}

/// A metric resolved from config, ready to score.
pub type ResolvedMetric = Arc<dyn Metric>;

/// Builds a metric instance from its (validated) config — parameters like
/// normalization flags and judge rubrics bind here, at resolve time.
pub type MetricFactory = Arc<dyn Fn(&MetricConfig) -> Result<ResolvedMetric> + Send + Sync>;

#[derive(Clone)]
struct RegistryEntry {
    family: String,
    factory: MetricFactory,
}

/// Name → (family, factory) table with built-ins pre-registered.
#[derive(Clone, Default)]
pub struct MetricRegistry {
    entries: BTreeMap<String, RegistryEntry>,
}

impl MetricRegistry {
    /// An empty registry (tests, fully custom setups).
    pub fn empty() -> Self {
        Self::default()
    }

    /// The standard registry: every built-in metric family.
    pub fn with_builtins() -> Self {
        let mut reg = Self::empty();
        for (name, kind, scale) in [
            ("exact_match", LexicalKind::ExactMatch, MetricScale::Binary),
            ("contains", LexicalKind::Contains, MetricScale::Binary),
            ("token_f1", LexicalKind::TokenF1, MetricScale::Continuous),
            ("bleu", LexicalKind::Bleu, MetricScale::Continuous),
            ("rouge_l", LexicalKind::RougeL, MetricScale::Continuous),
        ] {
            reg.register(
                name,
                "lexical",
                Arc::new(move |cfg| {
                    let norm = if cfg.param_bool("normalize", true) {
                        lexical::Normalize::default()
                    } else {
                        lexical::Normalize::none()
                    };
                    Ok(Arc::new(LexicalMetric { name, kind, norm, scale }) as ResolvedMetric)
                }),
            );
        }
        for (name, kind, family) in [
            ("embedding_similarity", SemanticKind::EmbeddingSimilarity, "semantic"),
            ("bertscore", SemanticKind::BertScore, "semantic"),
            // RAG by taxonomy, but embedding-based per the paper §4.1.
            ("answer_relevance", SemanticKind::AnswerRelevance, "rag"),
        ] {
            reg.register(
                name,
                family,
                Arc::new(move |_cfg| Ok(Arc::new(SemanticMetric { name, kind }) as ResolvedMetric)),
            );
        }
        for (name, kind) in [
            ("context_precision", RagPureKind::Precision),
            ("context_recall", RagPureKind::Recall),
        ] {
            reg.register(
                name,
                "rag",
                Arc::new(move |_cfg| Ok(Arc::new(RagPureMetric { name, kind }) as ResolvedMetric)),
            );
        }
        for (name, kind) in [
            ("faithfulness", RagJudgeKind::Faithfulness),
            ("context_relevance", RagJudgeKind::ContextRelevance),
        ] {
            reg.register(
                name,
                "rag",
                Arc::new(move |cfg| {
                    Ok(Arc::new(RagJudgeMetric {
                        name,
                        kind,
                        provider: cfg.param_str("judge_provider").map(String::from),
                        model: cfg.param_str("judge_model").map(String::from),
                    }) as ResolvedMetric)
                }),
            );
        }
        reg
    }

    /// Register (or replace) a metric factory under `name`/`family`.
    pub fn register(&mut self, name: &str, family: &str, factory: MetricFactory) {
        self.entries
            .insert(name.to_string(), RegistryEntry { family: family.to_string(), factory });
    }

    /// Register a pre-built metric object (custom metrics): resolution
    /// returns the object itself, ignoring config params.
    pub fn register_metric(&mut self, family: &str, metric: ResolvedMetric) {
        let name = metric.name().to_string();
        self.register(&name, family, Arc::new(move |_cfg| Ok(metric.clone())));
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Registered names in a family (sorted; error messages, docs).
    pub fn names_for_family(&self, family: &str) -> Vec<&str> {
        self.entries
            .iter()
            .filter(|(_, e)| e.family == family)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Resolve one metric config into a scorable metric. Unknown names
    /// and family mismatches are errors — there is no silent fallback.
    /// Exception by design: *any* name under `llm_judge` resolves to the
    /// pointwise rubric judge (the rubric names the behaviour; the metric
    /// name is the user's label), always with `Ordinal` scale.
    pub fn resolve(&self, config: &MetricConfig) -> Result<ResolvedMetric> {
        if let Some(entry) = self.entries.get(&config.name) {
            if entry.family == config.metric_type {
                return (entry.factory)(config);
            }
            // A judge label may collide with a name from another family
            // ("faithfulness" as a rubric judge): under `llm_judge` the
            // label is the user's, so fall through to the generic judge
            // instead of erroring on the collision.
            if config.metric_type != "llm_judge" {
                bail!(
                    "metric '{}' belongs to family '{}', not '{}'",
                    config.name,
                    entry.family,
                    config.metric_type
                );
            }
        }
        if config.metric_type == "llm_judge" {
            return Ok(Arc::new(JudgeMetric::from_config(config)));
        }
        bail!(
            "unknown metric '{}' for type '{}' (known: {})",
            config.name,
            config.metric_type,
            self.names_for_family(&config.metric_type).join(", ")
        )
    }

    /// Resolve every metric of a task (load-time validation), in order.
    pub fn resolve_task(&self, task: &EvalTask) -> Result<Vec<ResolvedMetric>> {
        task.metrics.iter().map(|m| self.resolve(m)).collect()
    }

    /// Validate a config without keeping the metric.
    pub fn check(&self, config: &MetricConfig) -> Result<()> {
        self.resolve(config).map(|_| ())
    }

    /// Declared scale for a config (via resolution — no name lists).
    pub fn scale_of(&self, config: &MetricConfig) -> Result<MetricScale> {
        Ok(self.resolve(config)?.scale())
    }
}

/// The shared built-in registry (config-layer load-time validation).
/// Runners hold their own [`MetricRegistry::with_builtins`] copy so custom
/// registrations stay scoped to the runner that made them.
pub fn builtin_registry() -> &'static MetricRegistry {
    static REG: OnceLock<MetricRegistry> = OnceLock::new();
    REG.get_or_init(MetricRegistry::with_builtins)
}

// ------------------------------------------------------------ built-ins

#[derive(Debug, Clone, Copy)]
enum LexicalKind {
    ExactMatch,
    Contains,
    TokenF1,
    Bleu,
    RougeL,
}

struct LexicalMetric {
    name: &'static str,
    kind: LexicalKind,
    norm: lexical::Normalize,
    scale: MetricScale,
}

impl Metric for LexicalMetric {
    fn name(&self) -> &str {
        self.name
    }

    fn scale(&self) -> MetricScale {
        self.scale
    }

    fn requirements(&self) -> MetricRequirements {
        MetricRequirements::Pure
    }

    fn score_batch(&self, _ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch> {
        let values = examples
            .iter()
            .map(|ex| {
                Some(match self.kind {
                    LexicalKind::ExactMatch => {
                        lexical::exact_match(&ex.response, &ex.reference, self.norm)
                    }
                    LexicalKind::Contains => {
                        lexical::contains(&ex.response, &ex.reference, self.norm)
                    }
                    LexicalKind::TokenF1 => lexical::token_f1(&ex.response, &ex.reference),
                    LexicalKind::Bleu => lexical::bleu(&ex.response, &ex.reference),
                    LexicalKind::RougeL => lexical::rouge_l(&ex.response, &ex.reference),
                })
            })
            .collect();
        Ok(ScoreBatch::scored(values))
    }
}

#[derive(Debug, Clone, Copy)]
enum SemanticKind {
    EmbeddingSimilarity,
    BertScore,
    AnswerRelevance,
}

struct SemanticMetric {
    name: &'static str,
    kind: SemanticKind,
}

impl Metric for SemanticMetric {
    fn name(&self) -> &str {
        self.name
    }

    fn scale(&self) -> MetricScale {
        MetricScale::Continuous
    }

    fn requirements(&self) -> MetricRequirements {
        MetricRequirements::Runtime
    }

    fn score_batch(&self, ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch> {
        let runtime = ctx.runtime.ok_or_else(|| {
            anyhow!("semantic metric '{}' needs the PJRT runtime (make artifacts)", self.name)
        })?;
        let values = match self.kind {
            SemanticKind::EmbeddingSimilarity => {
                semantic::embedding_similarity_batch(runtime, examples)?
            }
            SemanticKind::BertScore => semantic::bertscore_batch(runtime, examples)?,
            SemanticKind::AnswerRelevance => semantic::answer_relevance_batch(runtime, examples)?,
        };
        Ok(ScoreBatch::scored(values))
    }
}

/// Pointwise rubric judge — what every `llm_judge` config resolves to.
struct JudgeMetric {
    name: String,
    rubric: String,
    provider: Option<String>,
    model: Option<String>,
    max_tokens: usize,
}

impl JudgeMetric {
    fn from_config(cfg: &MetricConfig) -> Self {
        Self {
            name: cfg.name.clone(),
            rubric: cfg.param_str("rubric").unwrap_or("overall quality").to_string(),
            provider: cfg.param_str("judge_provider").map(String::from),
            model: cfg.param_str("judge_model").map(String::from),
            max_tokens: cfg.param_f64("judge_max_tokens", 256.0) as usize,
        }
    }
}

impl Metric for JudgeMetric {
    fn name(&self) -> &str {
        &self.name
    }

    fn scale(&self) -> MetricScale {
        MetricScale::Ordinal
    }

    fn requirements(&self) -> MetricRequirements {
        MetricRequirements::Judge
    }

    fn score_batch(&self, ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch> {
        let broker = ctx.judge.ok_or_else(|| {
            anyhow!("judge metric '{}' needs a judge broker (driver-side scoring)", self.name)
        })?;
        let mut engine = broker.engine(
            self.provider.as_deref().unwrap_or(ctx.default_provider),
            self.model.as_deref().unwrap_or(ctx.default_model),
        )?;
        let outcome = judge::grade_pointwise(engine.as_mut(), &self.rubric, examples, self.max_tokens);
        Ok(ScoreBatch { values: outcome.scores, unparseable: outcome.unparseable })
    }
}

#[derive(Debug, Clone, Copy)]
enum RagPureKind {
    Precision,
    Recall,
}

struct RagPureMetric {
    name: &'static str,
    kind: RagPureKind,
}

impl Metric for RagPureMetric {
    fn name(&self) -> &str {
        self.name
    }

    fn scale(&self) -> MetricScale {
        MetricScale::Continuous
    }

    fn requirements(&self) -> MetricRequirements {
        MetricRequirements::Pure
    }

    fn score_batch(&self, _ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch> {
        let values = examples
            .iter()
            .map(|ex| match self.kind {
                RagPureKind::Precision => rag::context_precision(ex),
                RagPureKind::Recall => rag::context_recall(ex),
            })
            .collect();
        Ok(ScoreBatch::scored(values))
    }
}

#[derive(Debug, Clone, Copy)]
enum RagJudgeKind {
    Faithfulness,
    ContextRelevance,
}

struct RagJudgeMetric {
    name: &'static str,
    kind: RagJudgeKind,
    provider: Option<String>,
    model: Option<String>,
}

impl Metric for RagJudgeMetric {
    fn name(&self) -> &str {
        self.name
    }

    fn scale(&self) -> MetricScale {
        MetricScale::Continuous
    }

    fn requirements(&self) -> MetricRequirements {
        MetricRequirements::Judge
    }

    fn score_batch(&self, ctx: &MetricContext<'_>, examples: &[Example]) -> Result<ScoreBatch> {
        let broker = ctx.judge.ok_or_else(|| {
            anyhow!("RAG metric '{}' needs a judge broker (driver-side scoring)", self.name)
        })?;
        let mut engine = broker.engine(
            self.provider.as_deref().unwrap_or(ctx.default_provider),
            self.model.as_deref().unwrap_or(ctx.default_model),
        )?;
        let values = examples
            .iter()
            .map(|ex| match self.kind {
                RagJudgeKind::Faithfulness => rag::faithfulness(engine.as_mut(), ex),
                RagJudgeKind::ContextRelevance => rag::context_relevance(engine.as_mut(), ex),
            })
            .collect();
        Ok(ScoreBatch::scored(values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn cfg(name: &str, family: &str) -> MetricConfig {
        MetricConfig::new(name, family)
    }

    #[test]
    fn builtin_scales_resolve_through_registry() {
        let reg = MetricRegistry::with_builtins();
        assert_eq!(reg.scale_of(&cfg("exact_match", "lexical")).unwrap(), MetricScale::Binary);
        assert_eq!(reg.scale_of(&cfg("contains", "lexical")).unwrap(), MetricScale::Binary);
        assert_eq!(reg.scale_of(&cfg("bleu", "lexical")).unwrap(), MetricScale::Continuous);
        assert_eq!(
            reg.scale_of(&cfg("bertscore", "semantic")).unwrap(),
            MetricScale::Continuous
        );
        assert_eq!(
            reg.scale_of(&cfg("faithfulness", "rag")).unwrap(),
            MetricScale::Continuous
        );
    }

    #[test]
    fn plain_judge_names_get_ordinal_scale() {
        // The scale-misclassification fix: a judge metric named without a
        // `judge:` prefix must still be Ordinal (it used to silently fall
        // back to Complex and draw the wrong significance test).
        let reg = MetricRegistry::with_builtins();
        assert_eq!(
            reg.scale_of(&cfg("helpfulness", "llm_judge")).unwrap(),
            MetricScale::Ordinal
        );
        assert_eq!(
            reg.scale_of(&cfg("judge:helpfulness", "llm_judge")).unwrap(),
            MetricScale::Ordinal
        );
    }

    #[test]
    fn unknown_names_are_load_time_errors_not_complex() {
        let reg = MetricRegistry::with_builtins();
        let err = reg.check(&cfg("custom_thing", "lexical")).unwrap_err();
        assert!(format!("{err}").contains("unknown metric"), "{err}");
        assert!(reg.check(&cfg("bogus", "rag")).is_err());
        // Family mismatch is an error too, with the right family named.
        let err = reg.check(&cfg("exact_match", "semantic")).unwrap_err();
        assert!(format!("{err}").contains("family 'lexical'"), "{err}");
    }

    #[test]
    fn requirements_drive_dispatch() {
        let reg = MetricRegistry::with_builtins();
        let req = |n: &str, f: &str| reg.resolve(&cfg(n, f)).unwrap().requirements();
        assert_eq!(req("exact_match", "lexical"), MetricRequirements::Pure);
        assert_eq!(req("context_precision", "rag"), MetricRequirements::Pure);
        assert_eq!(req("context_recall", "rag"), MetricRequirements::Pure);
        assert_eq!(req("embedding_similarity", "semantic"), MetricRequirements::Runtime);
        assert_eq!(req("answer_relevance", "rag"), MetricRequirements::Runtime);
        assert_eq!(req("faithfulness", "rag"), MetricRequirements::Judge);
        assert_eq!(req("anything_at_all", "llm_judge"), MetricRequirements::Judge);
    }

    #[test]
    fn judge_labels_may_collide_with_builtin_names() {
        // "faithfulness" as an llm_judge label is the user's rubric
        // judge, not the RAG built-in — the collision must not error.
        let reg = MetricRegistry::with_builtins();
        for name in ["faithfulness", "contains", "bleu"] {
            let metric = reg.resolve(&cfg(name, "llm_judge")).unwrap();
            assert_eq!(metric.name(), name);
            assert_eq!(metric.scale(), MetricScale::Ordinal);
            assert_eq!(metric.requirements(), MetricRequirements::Judge);
        }
    }

    #[test]
    fn judge_params_bind_at_resolve_time() {
        let reg = MetricRegistry::with_builtins();
        let config = cfg("clarity", "llm_judge")
            .with_param("rubric", Json::str("Rate clarity 1-5"))
            .with_param("judge_model", Json::str("gpt-4o-mini"));
        let metric = reg.resolve(&config).unwrap();
        assert_eq!(metric.name(), "clarity");
        assert_eq!(metric.scale(), MetricScale::Ordinal);
    }

    #[test]
    fn pure_metrics_score_detached() {
        let reg = MetricRegistry::with_builtins();
        let metric = reg.resolve(&cfg("exact_match", "lexical")).unwrap();
        let examples = vec![
            Example { response: "Paris!".into(), reference: "paris".into(), ..Default::default() },
            Example { response: "london".into(), reference: "paris".into(), ..Default::default() },
        ];
        let out = metric.score_batch(&MetricContext::detached(), &examples).unwrap();
        assert_eq!(out.values, vec![Some(1.0), Some(0.0)]);
        assert_eq!(out.unparseable, 0);
    }

    #[test]
    fn normalize_param_binds_at_resolve_time() {
        let reg = MetricRegistry::with_builtins();
        let strict = reg
            .resolve(&cfg("exact_match", "lexical").with_param("normalize", Json::Bool(false)))
            .unwrap();
        let ex = vec![Example {
            response: "Paris!".into(),
            reference: "paris".into(),
            ..Default::default()
        }];
        let out = strict.score_batch(&MetricContext::detached(), &ex).unwrap();
        assert_eq!(out.values, vec![Some(0.0)]);
    }

    #[test]
    fn custom_metric_registration_round_trips() {
        struct ResponseWords;
        impl Metric for ResponseWords {
            fn name(&self) -> &str {
                "response_words"
            }
            fn scale(&self) -> MetricScale {
                MetricScale::Continuous
            }
            fn requirements(&self) -> MetricRequirements {
                MetricRequirements::Pure
            }
            fn score_batch(
                &self,
                _ctx: &MetricContext<'_>,
                examples: &[Example],
            ) -> Result<ScoreBatch> {
                Ok(ScoreBatch::scored(
                    examples
                        .iter()
                        .map(|ex| Some(ex.response.split_whitespace().count() as f64))
                        .collect(),
                ))
            }
        }
        let mut reg = MetricRegistry::with_builtins();
        assert!(reg.check(&cfg("response_words", "custom")).is_err());
        reg.register_metric("custom", Arc::new(ResponseWords));
        assert!(reg.contains("response_words"));
        let metric = reg.resolve(&cfg("response_words", "custom")).unwrap();
        let ex = vec![Example { response: "three short words".into(), ..Default::default() }];
        let out = metric.score_batch(&MetricContext::detached(), &ex).unwrap();
        assert_eq!(out.values, vec![Some(3.0)]);
        // Family mismatch still checked for custom entries.
        assert!(reg.check(&cfg("response_words", "lexical")).is_err());
    }

    #[test]
    fn builtin_names_listing() {
        let reg = MetricRegistry::with_builtins();
        assert_eq!(
            reg.names_for_family("lexical"),
            vec!["bleu", "contains", "exact_match", "rouge_l", "token_f1"]
        );
        assert_eq!(reg.names_for_family("semantic"), vec!["bertscore", "embedding_similarity"]);
        assert_eq!(
            reg.names_for_family("rag"),
            vec![
                "answer_relevance",
                "context_precision",
                "context_recall",
                "context_relevance",
                "faithfulness"
            ]
        );
    }
}
