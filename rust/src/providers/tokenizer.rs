//! Token-count estimation for rate limiting and cost accounting.
//!
//! Real providers meter BPE tokens; a faithful estimator here only needs to
//! be deterministic and roughly proportional (the paper's TPM buckets and
//! cost model consume estimates too). We use the standard heuristic of
//! ~4 characters per token blended with a word count, which tracks BPE
//! within ~10% on English text.

/// Estimate the token count of `text`.
pub fn estimate_tokens(text: &str) -> usize {
    if text.is_empty() {
        return 0;
    }
    let chars = text.chars().count();
    let words = text.split_whitespace().count();
    // Average of chars/4 and words*4/3, min 1.
    let est = (chars as f64 / 4.0 + words as f64 * 4.0 / 3.0) / 2.0;
    est.ceil().max(1.0) as usize
}

/// Estimate for a prompt + expected completion (bucket acquisition).
pub fn estimate_request_tokens(prompt: &str, max_tokens: usize) -> usize {
    // Providers count the completion against TPM at reservation time; use
    // half of max_tokens as the expected completion (responses rarely
    // exhaust the cap).
    estimate_tokens(prompt) + max_tokens / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(estimate_tokens(""), 0);
    }

    #[test]
    fn single_word() {
        assert!(estimate_tokens("hello") >= 1);
    }

    #[test]
    fn proportional_to_length() {
        let short = estimate_tokens("one two three");
        let long = estimate_tokens(&"one two three ".repeat(10));
        assert!(long > short * 8, "short={short} long={long}");
        assert!(long < short * 12);
    }

    #[test]
    fn english_text_plausible() {
        // ~50 tokens of typical English should estimate within 2x.
        let text = "The quick brown fox jumps over the lazy dog and then \
                    continues running through the forest looking for food \
                    while the dog sleeps peacefully near the warm fire inside";
        let est = estimate_tokens(text);
        assert!((20..60).contains(&est), "estimate {est}");
    }

    #[test]
    fn request_estimate_includes_completion() {
        let with = estimate_request_tokens("prompt", 1000);
        let without = estimate_request_tokens("prompt", 0);
        assert_eq!(with - without, 500);
    }

    #[test]
    fn deterministic() {
        assert_eq!(estimate_tokens("same text"), estimate_tokens("same text"));
    }
}
