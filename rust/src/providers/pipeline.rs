//! In-executor pipelined inference: a hand-rolled submit/poll completion-
//! queue client that multiplexes up to `concurrency` in-flight requests
//! per executor (the paper's §3.1 throughput model, previously only
//! simulated by [`crate::sim::SimParams::concurrency`]).
//!
//! No async runtime: the offline crate set has no tokio, so concurrency is
//! built from scoped worker threads and a slot-limited completion queue.
//! One [`PipelinedClient`] lives inside each executor's local state
//! (Listing 1's `_ENGINE_CACHE`), owning `concurrency` slot engines, a
//! shared rate-limit token bucket, and the retry policy. A batch is
//! *submitted* by striding its requests over the slots (request `i` goes
//! to slot `i % concurrency` — deterministic, so per-slot engine call
//! sequences replay identically run to run); each slot worker drives its
//! requests through admission → issue → latency wait → retry, and posts
//! finished requests to the completion queue, which the driver *polls*
//! back into request order.
//!
//! What makes the overlap real on both clock regimes:
//!
//! - engines issue through [`InferenceEngine::infer_deferred`], which
//!   returns the response together with the **remaining delivery wait**
//!   instead of sleeping it out internally;
//! - on a wall clock each slot worker sleeps its own wait — OS threads
//!   overlap physically, so a batch costs max-completion, not
//!   sum-of-latencies;
//! - on a virtual clock ([`Clock::is_virtual`]) independent sleeps would
//!   *serialize* (each `sleep` advances shared time), so waits go through
//!   a [`LatencyGate`]: workers park their deadlines and, only once every
//!   live slot is parked, the gate advances the clock to the **earliest**
//!   deadline — a miniature discrete-event engine that makes a
//!   latency-bound batch cost ~1/concurrency of its sequential virtual
//!   wall time.
//!
//! Semantics preserved from the sequential path:
//!
//! - **retry/backoff** per request matches
//!   [`crate::providers::retry::infer_with_retry`]: recoverable errors
//!   back off exponentially (slept through the gate) and retry on the
//!   *same slot engine*, so only the failed slot stalls — its siblings
//!   keep draining their requests;
//! - **rate limiting**: all slots consume one shared [`TokenBucket`]
//!   ([`TokenBucket::acquire_at`]), so `concurrency` multiplies in-flight
//!   latency overlap but never the configured RPM/TPM budget;
//! - **panics** in a slot are caught per request and surfaced as an error
//!   from [`PipelinedClient::run_batch`], which the task scheduler then
//!   treats as a retryable task failure (PR 2 semantics) instead of
//!   tearing the pool down;
//! - `concurrency == 1` bypasses the machinery entirely and runs the
//!   exact sequential admission + [`infer_with_retry`] loop, bit-identical
//!   to the pre-pipeline path.

use super::retry::{infer_with_retry, RetryOutcome, RetryPolicy};
use super::{InferenceEngine, InferenceRequest};
use crate::ratelimit::{Clock, TokenBucket};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Occupancy telemetry for one pipelined batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Requests driven through the pipeline.
    pub requests: usize,
    /// Peak number of simultaneously in-flight requests observed
    /// (issued, response not yet delivered).
    pub peak_in_flight: usize,
}

/// One batch's outcome: per-request results in submission order.
#[derive(Debug)]
pub struct BatchOutput {
    pub outcomes: Vec<RetryOutcome>,
    pub stats: PipelineStats,
}

/// Coordinates latency waits for one pipelined batch. On a wall clock
/// each waiter simply sleeps (threads overlap physically); on a virtual
/// clock workers park their deadlines and the gate advances shared time
/// to the earliest deadline only once every live slot is parked, so
/// concurrent waits overlap instead of serializing.
struct LatencyGate {
    clock: Arc<dyn Clock>,
    state: Mutex<GateState>,
    woken: Condvar,
}

struct GateState {
    /// Slots still running the batch (not yet exited).
    active: usize,
    /// Deadline per parked slot (`None` = running or released).
    parked: Vec<Option<f64>>,
}

impl GateState {
    fn parked_count(&self) -> usize {
        self.parked.iter().flatten().count()
    }
}

impl LatencyGate {
    fn new(clock: Arc<dyn Clock>, slots: usize) -> Self {
        Self {
            clock,
            state: Mutex::new(GateState { active: slots, parked: vec![None; slots] }),
            woken: Condvar::new(),
        }
    }

    /// Under the lock: every live slot is parked — advance the clock to
    /// the earliest pending deadline and release every slot it satisfies.
    fn advance_locked(&self, st: &mut GateState) {
        let min = st.parked.iter().flatten().cloned().fold(f64::INFINITY, f64::min);
        if !min.is_finite() {
            return;
        }
        let now = self.clock.now();
        if min > now {
            self.clock.sleep(min - now);
        }
        // Another executor's pipeline may have advanced the shared clock
        // past several of our deadlines; release everything satisfied.
        let now = self.clock.now();
        for slot in st.parked.iter_mut() {
            if slot.is_some_and(|d| d <= now) {
                *slot = None;
            }
        }
        self.woken.notify_all();
    }

    /// Block slot `slot` until the clock reaches `deadline`.
    fn wait_until(&self, slot: usize, deadline: f64) {
        if !self.clock.is_virtual() {
            let delay = deadline - self.clock.now();
            if delay > 0.0 {
                self.clock.sleep(delay);
            }
            return;
        }
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if self.clock.now() >= deadline {
                st.parked[slot] = None;
                return;
            }
            st.parked[slot] = Some(deadline);
            if st.parked_count() >= st.active {
                self.advance_locked(&mut st);
                continue;
            }
            st = self.woken.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Slot `slot` finished its requests (or unwound): it no longer
    /// counts toward the everyone-parked condition. If the survivors are
    /// all parked, advance on their behalf — without this, a finished
    /// slot would leave its siblings waiting forever.
    fn exit(&self, slot: usize) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.parked[slot] = None;
        st.active -= 1;
        if st.active > 0 && st.parked_count() >= st.active {
            self.advance_locked(&mut st);
        }
    }
}

/// Release the gate and the completion queue even when the worker
/// unwinds, so a dying slot can never strand its parked siblings or leave
/// the driver polling forever.
struct WorkerExitGuard<'a> {
    gate: &'a LatencyGate,
    queue: &'a CompletionQueue,
    slot: usize,
}

impl Drop for WorkerExitGuard<'_> {
    fn drop(&mut self) {
        self.gate.exit(self.slot);
        self.queue.worker_done();
    }
}

/// Slot-limited completion queue: workers push finished requests, the
/// driver polls them back out. Completion order is whatever the schedule
/// produced; the driver reassembles submission order by index.
struct CompletionQueue {
    slots: Mutex<CompletionState>,
    ready: Condvar,
}

struct CompletionState {
    done: Vec<Option<RetryOutcome>>,
    completed: usize,
    /// First slot panic observed (message); poisons the whole batch.
    panic: Option<String>,
    /// Workers still running (panicked workers count down too, via the
    /// completion of their poison entry).
    live_workers: usize,
}

impl CompletionQueue {
    fn new(requests: usize, workers: usize) -> Self {
        Self {
            slots: Mutex::new(CompletionState {
                done: (0..requests).map(|_| None).collect(),
                completed: 0,
                panic: None,
                live_workers: workers,
            }),
            ready: Condvar::new(),
        }
    }

    fn push(&self, index: usize, outcome: RetryOutcome) {
        let mut st = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        debug_assert!(st.done[index].is_none(), "request {index} completed twice");
        st.done[index] = Some(outcome);
        st.completed += 1;
        self.ready.notify_all();
    }

    fn push_panic(&self, message: String) {
        let mut st = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        if st.panic.is_none() {
            st.panic = Some(message);
        }
        self.ready.notify_all();
    }

    fn worker_done(&self) {
        let mut st = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        st.live_workers -= 1;
        self.ready.notify_all();
    }

    /// Poll until every request completed or a slot panicked and all
    /// workers wound down. Returns outcomes in submission order.
    fn poll_all(&self) -> Result<Vec<RetryOutcome>> {
        let mut st = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if st.panic.is_some() {
                // Wait for the surviving workers to drain before failing
                // the batch: their engines must be quiescent when the
                // scheduler retries the task attempt.
                while st.live_workers > 0 {
                    st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
                }
                return Err(anyhow!(
                    "inference slot panicked: {}",
                    st.panic.as_deref().unwrap_or("unknown payload")
                ));
            }
            if st.completed == st.done.len() {
                let mut out = Vec::with_capacity(st.done.len());
                for (i, slot) in st.done.iter_mut().enumerate() {
                    match slot.take() {
                        Some(o) => out.push(o),
                        None => {
                            return Err(anyhow!(
                                "completion queue corrupt: request {i} counted complete but never settled"
                            ))
                        }
                    }
                }
                return Ok(out);
            }
            if st.live_workers == 0 {
                // A worker died without completing its requests and
                // without recording a panic — surface it rather than
                // polling forever.
                return Err(anyhow!(
                    "pipeline worker exited with {}/{} requests complete",
                    st.completed,
                    st.done.len()
                ));
            }
            st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Tracks the peak number of simultaneously in-flight requests.
#[derive(Default)]
struct InFlightMeter {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl InFlightMeter {
    fn enter(&self) {
        let now = self.current.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn exit(&self) {
        self.current.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Per-executor pipelined inference client: slot engines + shared rate
/// limiter + retry policy behind a submit/poll batch interface. See the
/// module docs for the design.
pub struct PipelinedClient {
    slots: Vec<Box<dyn InferenceEngine>>,
    rngs: Vec<Rng>,
    policy: RetryPolicy,
    /// Shared across slots; `None` disables rate limiting (judge stages).
    bucket: Option<Mutex<TokenBucket>>,
    clock: Arc<dyn Clock>,
}

impl PipelinedClient {
    /// `slots` are the concurrency-many engines this client multiplexes
    /// over (one in-flight request per slot); `rngs` seed the per-slot
    /// backoff jitter and must have the same length.
    pub fn new(
        slots: Vec<Box<dyn InferenceEngine>>,
        rngs: Vec<Rng>,
        policy: RetryPolicy,
        bucket: Option<TokenBucket>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        assert!(!slots.is_empty(), "pipelined client needs at least one slot");
        assert_eq!(slots.len(), rngs.len(), "one rng per slot");
        Self { slots, rngs, policy, bucket: bucket.map(Mutex::new), clock }
    }

    pub fn concurrency(&self) -> usize {
        self.slots.len()
    }

    /// Split out slot 0's engine + rng and the shared bucket for the
    /// sequential compatibility path (concurrency 1), where callers drive
    /// `infer_with_retry` themselves to stay bit-identical to the
    /// pre-pipeline hot path.
    pub fn sequential_parts(
        &mut self,
    ) -> (&mut dyn InferenceEngine, &mut Rng, Option<&mut TokenBucket>) {
        (
            self.slots[0].as_mut(),
            &mut self.rngs[0],
            self.bucket.as_mut().map(|b| b.get_mut().unwrap_or_else(|p| p.into_inner())),
        )
    }

    /// Drive `requests` to completion, overlapping up to `concurrency`
    /// in-flight latencies. `estimate` prices each request against the
    /// token bucket (ignored when rate limiting is disabled).
    /// `on_complete` fires as each request settles — *while the rest of
    /// the batch is still in flight* — so callers can account spend and
    /// trip cost budgets at per-request granularity instead of waiting
    /// for the whole batch to drain. Outcomes come back in request
    /// order; a slot panic fails the whole batch with an error (the
    /// scheduler's retryable-task-failure contract).
    pub fn run_batch(
        &mut self,
        requests: &[InferenceRequest],
        estimate: &(dyn Fn(&InferenceRequest) -> f64 + Sync),
        on_complete: Option<&(dyn Fn(&RetryOutcome) + Sync)>,
    ) -> Result<BatchOutput> {
        let n = requests.len();
        if n == 0 {
            return Ok(BatchOutput { outcomes: Vec::new(), stats: PipelineStats::default() });
        }

        if self.slots.len() == 1 {
            // Sequential fast path: the exact pre-pipeline loop
            // (admission via the blocking `acquire`, then
            // `infer_with_retry`), bit-identical to the old hot path.
            let clock = self.clock.clone();
            let mut outcomes = Vec::with_capacity(n);
            for req in requests {
                if let Some(bucket) = self.bucket.as_mut() {
                    bucket
                        .get_mut()
                        .unwrap_or_else(|p| p.into_inner())
                        .acquire(estimate(req), clock.as_ref());
                }
                let outcome = infer_with_retry(
                    self.slots[0].as_mut(),
                    req,
                    &self.policy,
                    clock.as_ref(),
                    &mut self.rngs[0],
                );
                if let Some(hook) = on_complete {
                    hook(&outcome);
                }
                outcomes.push(outcome);
            }
            return Ok(BatchOutput {
                outcomes,
                stats: PipelineStats { requests: n, peak_in_flight: 1 },
            });
        }

        let n_slots = self.slots.len().min(n);
        let gate = LatencyGate::new(self.clock.clone(), n_slots);
        let queue = CompletionQueue::new(n, n_slots);
        let meter = InFlightMeter::default();
        let policy = self.policy;
        let bucket = &self.bucket;
        let clock = &self.clock;

        std::thread::scope(|scope| {
            for (slot, (engine, rng)) in
                self.slots.iter_mut().zip(self.rngs.iter_mut()).take(n_slots).enumerate()
            {
                let gate = &gate;
                let queue = &queue;
                let meter = &meter;
                scope.spawn(move || {
                    let _exit = WorkerExitGuard { gate, queue, slot };
                    for index in (slot..n).step_by(n_slots) {
                        let req = &requests[index];
                        let est = estimate(req);
                        match drive_request(
                            engine.as_mut(),
                            req,
                            est,
                            &policy,
                            bucket.as_ref(),
                            gate,
                            slot,
                            meter,
                            clock.as_ref(),
                            rng,
                        ) {
                            Ok(outcome) => {
                                // Per-completion accounting while the
                                // batch is still in flight (spend /
                                // budget watchdogs stay per-request).
                                if let Some(hook) = on_complete {
                                    hook(&outcome);
                                }
                                queue.push(index, outcome);
                            }
                            Err(panic_msg) => {
                                // Stop issuing from this slot: its engine
                                // state is suspect after an unwind.
                                queue.push_panic(panic_msg);
                                break;
                            }
                        }
                    }
                });
            }
            // Driver side of the queue: poll completions back into
            // submission order (blocks until the batch drains).
            let outcomes = queue.poll_all()?;
            Ok(BatchOutput {
                outcomes,
                stats: PipelineStats {
                    requests: n,
                    peak_in_flight: meter.peak.load(Ordering::Relaxed),
                },
            })
        })
    }
}

/// Drive one request through admission → issue → latency wait → retry on
/// one slot. Mirrors [`infer_with_retry`] exactly, with every wait routed
/// through the gate so concurrent slots overlap. `Err` carries a panic
/// payload message (the engine unwound mid-call).
#[allow(clippy::too_many_arguments)]
fn drive_request(
    engine: &mut dyn InferenceEngine,
    req: &InferenceRequest,
    estimated_tokens: f64,
    policy: &RetryPolicy,
    bucket: Option<&Mutex<TokenBucket>>,
    gate: &LatencyGate,
    slot: usize,
    meter: &InFlightMeter,
    clock: &dyn Clock,
    rng: &mut Rng,
) -> Result<RetryOutcome, String> {
    // Admission: consume the shared budget at the current instant; the
    // returned admission time already accounts for every other slot's
    // consumption, so concurrency never exceeds the configured RPM/TPM.
    if let Some(bucket) = bucket {
        let admission = bucket
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .acquire_at(estimated_tokens, clock.now());
        gate.wait_until(slot, admission);
    }
    let mut backoff_secs = 0.0;
    for attempt in 0..=policy.max_retries {
        meter.enter();
        let issued = std::panic::catch_unwind(AssertUnwindSafe(|| engine.infer_deferred(req)));
        let (result, wait_secs) = match issued {
            Ok(r) => r,
            Err(payload) => {
                meter.exit();
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                return Err(msg);
            }
        };
        match result {
            Ok(resp) => {
                if wait_secs > 0.0 {
                    gate.wait_until(slot, clock.now() + wait_secs);
                }
                meter.exit();
                return Ok(RetryOutcome { result: Ok(resp), attempts: attempt + 1, backoff_secs });
            }
            Err(e) if e.recoverable() && attempt < policy.max_retries => {
                meter.exit();
                // Only this slot backs off; its siblings keep draining.
                let delay = policy.delay_for_attempt(attempt, rng);
                gate.wait_until(slot, clock.now() + delay);
                backoff_secs += delay;
            }
            Err(e) => {
                meter.exit();
                return Ok(RetryOutcome { result: Err(e), attempts: attempt + 1, backoff_secs });
            }
        }
    }
    Err("retry loop exhausted without settling the request".to_string())
}

/// Convenience: did every outcome succeed?
pub fn all_ok(out: &BatchOutput) -> bool {
    out.outcomes.iter().all(|o| o.result.is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::providers::{ApiError, InferenceResponse};
    use crate::ratelimit::VirtualClock;
    use std::sync::atomic::AtomicUsize;

    /// Scripted slot engine: fixed per-call latency, optional one-shot
    /// failures keyed on prompt text, optional panic trigger. Honors the
    /// engine contract: blocking `infer` sleeps the latency on its clock,
    /// `infer_deferred` returns it for the pipeline to overlap.
    struct Scripted {
        latency_secs: f64,
        fail_once: std::collections::BTreeSet<String>,
        panic_on: Option<String>,
        calls: u64,
        clock: Arc<dyn Clock>,
    }

    impl Scripted {
        fn new(latency_secs: f64, clock: Arc<dyn Clock>) -> Self {
            Self {
                latency_secs,
                fail_once: Default::default(),
                panic_on: None,
                calls: 0,
                clock,
            }
        }
    }

    impl InferenceEngine for Scripted {
        fn initialize(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(&mut self, request: &InferenceRequest) -> Result<InferenceResponse, ApiError> {
            let (r, wait) = self.infer_deferred(request);
            if wait > 0.0 {
                self.clock.sleep(wait);
            }
            r
        }

        fn infer_deferred(
            &mut self,
            request: &InferenceRequest,
        ) -> (Result<InferenceResponse, ApiError>, f64) {
            self.calls += 1;
            if self.panic_on.as_deref() == Some(request.prompt.as_str()) {
                panic!("scripted slot panic");
            }
            if self.fail_once.remove(&request.prompt) {
                return (Err(ApiError::RateLimited("scripted".into())), 0.0);
            }
            (
                Ok(InferenceResponse {
                    text: format!("echo:{}", request.prompt),
                    input_tokens: 1,
                    output_tokens: 1,
                    latency_ms: self.latency_secs * 1000.0,
                    cost_usd: 0.001,
                }),
                self.latency_secs,
            )
        }

        fn model_id(&self) -> (String, String) {
            ("test".into(), "scripted".into())
        }
    }

    fn client_with(
        engines: Vec<Scripted>,
        clock: Arc<VirtualClock>,
        policy: RetryPolicy,
    ) -> PipelinedClient {
        let n = engines.len();
        PipelinedClient::new(
            engines.into_iter().map(|e| Box::new(e) as Box<dyn InferenceEngine>).collect(),
            (0..n).map(|s| Rng::with_stream(7, s as u64)).collect(),
            policy,
            None,
            clock,
        )
    }

    fn reqs(n: usize) -> Vec<InferenceRequest> {
        (0..n).map(|i| InferenceRequest::new(format!("p{i}"))).collect()
    }

    #[test]
    fn overlaps_latency_on_virtual_clock() {
        // 16 requests × 1s latency: sequential virtual time = 16s; with 4
        // slots the gate advances per wave → 4s.
        let clock = VirtualClock::new();
        let engines = (0..4).map(|_| Scripted::new(1.0, clock.clone())).collect();
        let mut client =
            client_with(engines, clock.clone(), RetryPolicy { jitter: 0.0, ..Default::default() });
        let out = client.run_batch(&reqs(16), &|_| 0.0, None).unwrap();
        assert_eq!(out.outcomes.len(), 16);
        assert!(all_ok(&out));
        assert!(
            (clock.now() - 4.0).abs() < 1e-9,
            "4 slots × 4 waves of 1s should take 4 virtual secs, took {}",
            clock.now()
        );
        assert_eq!(out.stats.peak_in_flight, 4);
        // Submission order preserved.
        for (i, o) in out.outcomes.iter().enumerate() {
            assert_eq!(o.result.as_ref().unwrap().text, format!("echo:p{i}"));
        }
    }

    #[test]
    fn single_slot_is_sequential() {
        let clock = VirtualClock::new();
        let mut client = client_with(
            vec![Scripted::new(0.5, clock.clone())],
            clock.clone(),
            RetryPolicy { jitter: 0.0, ..Default::default() },
        );
        let out = client.run_batch(&reqs(6), &|_| 0.0, None).unwrap();
        assert!(all_ok(&out));
        assert!((clock.now() - 3.0).abs() < 1e-9, "6 × 0.5s sequential, got {}", clock.now());
        assert_eq!(out.stats.peak_in_flight, 1);
    }

    #[test]
    fn mid_batch_error_retries_only_the_failed_slot() {
        // Request p2 (slot 2 of 4) fails once with a recoverable 429; only
        // that slot backs off (1s), siblings drain undisturbed, and the
        // retried request succeeds with attempts == 2.
        let clock = VirtualClock::new();
        let mut engines: Vec<Scripted> =
            (0..4).map(|_| Scripted::new(1.0, clock.clone())).collect();
        engines[2].fail_once.insert("p2".into());
        let mut client = client_with(
            engines,
            clock.clone(),
            RetryPolicy { base_delay: 1.0, jitter: 0.0, ..Default::default() },
        );
        let out = client.run_batch(&reqs(8), &|_| 0.0, None).unwrap();
        assert!(all_ok(&out));
        for (i, o) in out.outcomes.iter().enumerate() {
            let want_attempts = if i == 2 { 2 } else { 1 };
            assert_eq!(o.attempts, want_attempts, "request {i}");
            assert_eq!(o.result.as_ref().unwrap().text, format!("echo:p{i}"));
        }
        assert!((out.outcomes[2].backoff_secs - 1.0).abs() < 1e-9);
        // Slot 2's chain: 1s backoff + 2 × 1s latency = 3s; the other
        // slots finish their two 1s requests inside that window.
        assert!((clock.now() - 3.0).abs() < 1e-9, "virtual wall {}", clock.now());
    }

    #[test]
    fn non_recoverable_error_is_data_not_failure() {
        struct AlwaysAuth;
        impl InferenceEngine for AlwaysAuth {
            fn initialize(&mut self) -> Result<()> {
                Ok(())
            }
            fn infer(
                &mut self,
                _r: &InferenceRequest,
            ) -> Result<InferenceResponse, ApiError> {
                Err(ApiError::Auth("bad key".into()))
            }
            fn model_id(&self) -> (String, String) {
                ("t".into(), "auth".into())
            }
        }
        let clock = VirtualClock::new();
        let mut client = PipelinedClient::new(
            vec![Box::new(AlwaysAuth), Box::new(AlwaysAuth)],
            vec![Rng::new(0), Rng::new(1)],
            RetryPolicy::default(),
            None,
            clock,
        );
        let out = client.run_batch(&reqs(4), &|_| 0.0, None).unwrap();
        assert_eq!(out.outcomes.len(), 4);
        for o in &out.outcomes {
            assert!(matches!(o.result, Err(ApiError::Auth(_))));
            assert_eq!(o.attempts, 1);
        }
    }

    #[test]
    fn slot_panic_fails_the_batch_without_hanging() {
        let clock = VirtualClock::new();
        let mut engines: Vec<Scripted> =
            (0..3).map(|_| Scripted::new(0.5, clock.clone())).collect();
        engines[1].panic_on = Some("p1".into());
        let mut client = client_with(engines, clock, RetryPolicy::default());
        let err = client.run_batch(&reqs(9), &|_| 0.0, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("scripted slot panic"), "{msg}");
    }

    #[test]
    fn shared_bucket_caps_concurrent_admission() {
        // rpm 60 with a drained burst: after the initial 60-request burst,
        // admissions pace at 1/s regardless of 8-way concurrency.
        let clock = VirtualClock::new();
        let bucket = TokenBucket::new(60.0, 1e12, clock.as_ref());
        let engines: Vec<Box<dyn InferenceEngine>> =
            (0..8)
                .map(|_| {
                    Box::new(Scripted::new(0.0, clock.clone())) as Box<dyn InferenceEngine>
                })
                .collect();
        let mut client = PipelinedClient::new(
            engines,
            (0..8).map(|s| Rng::with_stream(3, s as u64)).collect(),
            RetryPolicy { jitter: 0.0, ..Default::default() },
            Some(bucket),
            clock.clone(),
        );
        let out = client.run_batch(&reqs(120), &|_| 1.0, None).unwrap();
        assert!(all_ok(&out));
        // 60 admitted from the burst at t=0; the remaining 60 pace out at
        // 1 per second → the last admission lands near t=60.
        assert!(
            clock.now() >= 55.0 && clock.now() <= 65.0,
            "rate limit must bind across slots, wall {}",
            clock.now()
        );
    }

    #[test]
    fn more_slots_than_requests() {
        let clock = VirtualClock::new();
        let engines = (0..8).map(|_| Scripted::new(1.0, clock.clone())).collect();
        let mut client =
            client_with(engines, clock.clone(), RetryPolicy { jitter: 0.0, ..Default::default() });
        let out = client.run_batch(&reqs(3), &|_| 0.0, None).unwrap();
        assert!(all_ok(&out));
        assert!((clock.now() - 1.0).abs() < 1e-9, "3 parallel 1s calls, got {}", clock.now());
        assert_eq!(out.stats.peak_in_flight, 3);
    }

    #[test]
    fn empty_batch() {
        let clock = VirtualClock::new();
        let mut client =
            client_with(vec![Scripted::new(1.0, clock.clone())], clock, RetryPolicy::default());
        let out = client.run_batch(&[], &|_| 0.0, None).unwrap();
        assert!(out.outcomes.is_empty());
        assert_eq!(out.stats.requests, 0);
    }

    #[test]
    fn deterministic_slot_assignment_across_runs() {
        // Two identical clients produce identical per-slot call counts and
        // identical outcomes: request i always rides slot i % concurrency.
        let run = || {
            let clock = VirtualClock::new();
            let engines = (0..3).map(|_| Scripted::new(0.25, clock.clone())).collect();
            let mut client = client_with(
                engines,
                clock,
                RetryPolicy { jitter: 0.0, ..Default::default() },
            );
            let out = client.run_batch(&reqs(10), &|_| 0.0, None).unwrap();
            out.outcomes
                .iter()
                .map(|o| (o.attempts, o.result.as_ref().unwrap().text.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn gate_releases_waiters_when_a_slot_exits_early() {
        // Slot 1 has one short request and exits; slots 0 and 2 still
        // drain their longer chains — the exiting slot must hand the
        // advance duty over instead of stranding the parked survivors.
        let clock = VirtualClock::new();
        let engines = (0..3).map(|_| Scripted::new(1.0, clock.clone())).collect();
        let mut client =
            client_with(engines, clock.clone(), RetryPolicy { jitter: 0.0, ..Default::default() });
        // 7 requests over 3 slots: slot 0 gets 3, slots 1 and 2 get 2.
        let out = client.run_batch(&reqs(7), &|_| 0.0, None).unwrap();
        assert!(all_ok(&out));
        assert!(
            (clock.now() - 3.0).abs() < 1e-9,
            "makespan = slot 0's 3 × 1s, got {}",
            clock.now()
        );
    }

    #[test]
    fn wall_clock_threads_overlap_physically() {
        // Real clock: 4 × 30ms requests on 4 slots should take ~1 wave of
        // wall time, far below the 120ms sequential sum.
        use crate::ratelimit::RealClock;
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        let engines: Vec<Box<dyn InferenceEngine>> =
            (0..4)
                .map(|_| {
                    Box::new(Scripted::new(0.03, clock.clone())) as Box<dyn InferenceEngine>
                })
                .collect();
        let mut client = PipelinedClient::new(
            engines,
            (0..4).map(|s| Rng::with_stream(5, s as u64)).collect(),
            RetryPolicy { jitter: 0.0, ..Default::default() },
            None,
            clock,
        );
        let t = std::time::Instant::now();
        let out = client.run_batch(&reqs(4), &|_| 0.0, None).unwrap();
        let elapsed = t.elapsed().as_secs_f64();
        assert!(all_ok(&out));
        assert!(elapsed < 0.10, "4 overlapped 30ms sleeps took {elapsed}s");
    }

    #[test]
    fn completion_queue_counts_match() {
        static POSTED: AtomicUsize = AtomicUsize::new(0);
        let q = CompletionQueue::new(5, 1);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for i in 0..5 {
                    q.push(
                        i,
                        RetryOutcome {
                            result: Err(ApiError::Auth("x".into())),
                            attempts: 1,
                            backoff_secs: 0.0,
                        },
                    );
                    POSTED.fetch_add(1, Ordering::SeqCst);
                }
                q.worker_done();
            });
            let got = q.poll_all().unwrap();
            assert_eq!(got.len(), 5);
        });
        assert_eq!(POSTED.load(Ordering::SeqCst), 5);
    }
}
