//! Deterministic "model behaviour" for the simulated providers.
//!
//! Given a prompt, the solver produces an *ideal* answer and a *plausible
//! wrong* answer; [`simulated::SimEngine`] picks between them with the
//! model's quality probability (seeded by `hash(prompt, model)` so
//! temperature-0 determinism and cache coherence hold). This is what makes
//! metric scores *differ measurably across models* — the property the
//! paper's model-comparison statistics need.
//!
//! The solver understands:
//! - the synthetic dataset families from [`crate::data::synth`] (QA,
//!   summarization, instruction),
//! - the structured judge prompts emitted by [`crate::metrics::judge`]
//!   (pointwise rubric grading, pairwise comparison, claim verification),
//! - and falls back to a deterministic pseudo-text response otherwise.

use crate::data::synth::{ENTITIES, TASKS};

/// What kind of prompt was recognised (exposed for tests/diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptKind {
    FactualQa,
    Summarization,
    Instruction,
    JudgePointwise,
    JudgePairwise,
    JudgeVerify,
    Freeform,
}

/// Solved prompt: ideal + degraded answers.
#[derive(Debug, Clone)]
pub struct Solution {
    pub kind: PromptKind,
    pub ideal: String,
    pub wrong: String,
}

/// FNV-1a 64-bit hash (stable across runs, used to seed behaviour).
pub fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Token-overlap F1 between two strings (used by the judge behaviours).
pub fn overlap_f1(a: &str, b: &str) -> f64 {
    let ta: Vec<String> = tokens(a);
    let tb: Vec<String> = tokens(b);
    if ta.is_empty() || tb.is_empty() {
        return if ta.is_empty() && tb.is_empty() { 1.0 } else { 0.0 };
    }
    let mut counts = std::collections::HashMap::new();
    for t in &ta {
        *counts.entry(t.clone()).or_insert(0i64) += 1;
    }
    let mut common = 0i64;
    for t in &tb {
        if let Some(c) = counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                common += 1;
            }
        }
    }
    if common == 0 {
        return 0.0;
    }
    let p = common as f64 / tb.len() as f64;
    let r = common as f64 / ta.len() as f64;
    2.0 * p * r / (p + r)
}

fn tokens(s: &str) -> Vec<String> {
    s.split(|c: char| !c.is_alphanumeric())
        .filter(|w| !w.is_empty())
        .map(|w| w.to_lowercase())
        .collect()
}

fn extract_section<'a>(prompt: &'a str, header: &str) -> Option<&'a str> {
    let start = prompt.find(header)? + header.len();
    let rest = &prompt[start..];
    let end = rest.find("\n###").unwrap_or(rest.len());
    Some(rest[..end].trim())
}

/// Solve a prompt into ideal/wrong answers.
pub fn solve(prompt: &str) -> Solution {
    // --- judge prompts (emitted by metrics::judge) -------------------------
    if prompt.contains("### SLLEVAL-JUDGE-POINTWISE") {
        let cand = extract_section(prompt, "### CANDIDATE\n").unwrap_or("");
        let reference = extract_section(prompt, "### REFERENCE\n").unwrap_or("");
        let f1 = overlap_f1(cand, reference);
        // Map overlap to a 1–5 rubric score.
        let score = 1 + (f1 * 4.0).round() as i64;
        return Solution {
            kind: PromptKind::JudgePointwise,
            ideal: format!(
                "Score: {score}\nExplanation: the candidate overlaps the reference \
                 with F1 {:.2}.",
                f1
            ),
            // Degraded judge: off-by-one score (still parseable).
            wrong: format!(
                "Score: {}\nExplanation: judged loosely.",
                (score - 1).max(1)
            ),
        };
    }
    if prompt.contains("### SLLEVAL-JUDGE-PAIRWISE") {
        let a = extract_section(prompt, "### RESPONSE-A\n").unwrap_or("");
        let b = extract_section(prompt, "### RESPONSE-B\n").unwrap_or("");
        let reference = extract_section(prompt, "### REFERENCE\n").unwrap_or("");
        let winner = if overlap_f1(a, reference) >= overlap_f1(b, reference) { "A" } else { "B" };
        let loser = if winner == "A" { "B" } else { "A" };
        return Solution {
            kind: PromptKind::JudgePairwise,
            ideal: format!("Verdict: {winner}\nExplanation: closer to the reference."),
            wrong: format!("Verdict: {loser}\nExplanation: style preference."),
        };
    }
    if prompt.contains("### SLLEVAL-JUDGE-VERIFY") {
        let claim = extract_section(prompt, "### CLAIM\n").unwrap_or("");
        let context = extract_section(prompt, "### CONTEXT\n").unwrap_or("");
        let supported = overlap_f1(claim, context) > 0.15
            || context.to_lowercase().contains(&claim.to_lowercase());
        let (ideal, wrong) = if supported {
            ("Verdict: SUPPORTED", "Verdict: UNSUPPORTED")
        } else {
            ("Verdict: UNSUPPORTED", "Verdict: SUPPORTED")
        };
        return Solution {
            kind: PromptKind::JudgeVerify,
            ideal: ideal.to_string(),
            wrong: wrong.to_string(),
        };
    }

    // --- synthetic dataset families ----------------------------------------
    // "…capital of <country>…" in any phrasing (the simulated model knows
    // the fact regardless of the paraphrase, like a real model would).
    if let Some(qpos) = prompt.rfind("capital of ") {
        let rest = &prompt[qpos + "capital of ".len()..];
        let country = rest
            .split(['?', '\n', '.', ','])
            .next()
            .unwrap_or("")
            .trim()
            .trim_end_matches(" please");
        if let Some((_, capital, _)) = ENTITIES.iter().find(|(c, _, _)| *c == country) {
            // Wrong answer: the capital of a different (hash-chosen) country.
            let mut idx = (fnv1a(country) as usize) % ENTITIES.len();
            while ENTITIES[idx].1 == *capital {
                idx = (idx + 1) % ENTITIES.len();
            }
            return Solution {
                kind: PromptKind::FactualQa,
                ideal: capital.to_string(),
                wrong: ENTITIES[idx].1.to_string(),
            };
        }
    }

    if let Some(body_start) = prompt.find("Summarize in one sentence:\n") {
        let body = prompt[body_start + "Summarize in one sentence:\n".len()..].trim();
        let sentences: Vec<&str> = body
            .split(". ")
            .map(|s| s.trim_end_matches('.'))
            .filter(|s| !s.is_empty())
            .collect();
        if !sentences.is_empty() {
            return Solution {
                kind: PromptKind::Summarization,
                ideal: sentences[0].to_string(),
                wrong: sentences[sentences.len() - 1].to_string(),
            };
        }
    }

    if let Some(inst_start) = prompt.find("Instruction: ") {
        let inst = prompt[inst_start + "Instruction: ".len()..]
            .split('\n')
            .next()
            .unwrap_or("")
            .trim();
        if let Some((_, answer)) = TASKS.iter().find(|(stem, _)| inst.starts_with(stem)) {
            return Solution {
                kind: PromptKind::Instruction,
                ideal: answer.to_string(),
                wrong: "i cannot help with that request in detail".to_string(),
            };
        }
    }

    // --- freeform fallback ---------------------------------------------------
    let h = fnv1a(prompt);
    let words = ["insight", "analysis", "context", "detail", "structure", "example"];
    let pick = |i: u64| words[((h >> (i * 8)) % words.len() as u64) as usize];
    Solution {
        kind: PromptKind::Freeform,
        ideal: format!(
            "a response offering {} and {} with supporting {}",
            pick(0),
            pick(1),
            pick(2)
        ),
        wrong: format!("a vague remark about {}", pick(3)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_capital_questions() {
        let s = solve("Answer the question concisely.\nQuestion: what is the capital of france?");
        assert_eq!(s.kind, PromptKind::FactualQa);
        assert_eq!(s.ideal, "paris");
        assert_ne!(s.wrong, "paris");
    }

    #[test]
    fn solves_summarization() {
        let s = solve("Summarize in one sentence:\nfirst fact. second fact. third fact.");
        assert_eq!(s.kind, PromptKind::Summarization);
        assert_eq!(s.ideal, "first fact");
        assert_eq!(s.wrong, "third fact");
    }

    #[test]
    fn solves_instruction() {
        let s = solve("Instruction: list three uses for neural networks\nResponse:");
        assert_eq!(s.kind, PromptKind::Instruction);
        assert!(s.ideal.contains("storage"));
    }

    #[test]
    fn judge_pointwise_scores_by_overlap() {
        let p = "### SLLEVAL-JUDGE-POINTWISE\nRubric: helpfulness\n\
                 ### CANDIDATE\nparis\n### REFERENCE\nparis\n### END";
        let s = solve(p);
        assert_eq!(s.kind, PromptKind::JudgePointwise);
        assert!(s.ideal.contains("Score: 5"), "{}", s.ideal);

        let p = "### SLLEVAL-JUDGE-POINTWISE\nRubric: helpfulness\n\
                 ### CANDIDATE\ncompletely unrelated words\n### REFERENCE\nparis\n### END";
        let s = solve(p);
        assert!(s.ideal.contains("Score: 1"), "{}", s.ideal);
    }

    #[test]
    fn judge_pairwise_picks_closer() {
        let p = "### SLLEVAL-JUDGE-PAIRWISE\n### RESPONSE-A\nparis\n\
                 ### RESPONSE-B\nwrong city\n### REFERENCE\nparis\n### END";
        let s = solve(p);
        assert!(s.ideal.contains("Verdict: A"));
    }

    #[test]
    fn judge_verify_checks_grounding() {
        let p = "### SLLEVAL-JUDGE-VERIFY\n### CLAIM\nthe capital is paris\n\
                 ### CONTEXT\nfrance is a country; its capital city is paris\n### END";
        assert!(solve(p).ideal.contains("SUPPORTED"));
        let p = "### SLLEVAL-JUDGE-VERIFY\n### CLAIM\nbananas are blue\n\
                 ### CONTEXT\nfrance is a country; its capital city is paris\n### END";
        assert!(solve(p).ideal.contains("UNSUPPORTED"));
    }

    #[test]
    fn freeform_is_deterministic() {
        let a = solve("an arbitrary prompt with no known structure");
        let b = solve("an arbitrary prompt with no known structure");
        assert_eq!(a.ideal, b.ideal);
        assert_eq!(a.kind, PromptKind::Freeform);
    }

    #[test]
    fn overlap_f1_bounds() {
        assert!((overlap_f1("a b c", "a b c") - 1.0).abs() < 1e-12);
        assert_eq!(overlap_f1("a b c", "x y z"), 0.0);
        let mid = overlap_f1("a b c d", "a b x y");
        assert!(mid > 0.0 && mid < 1.0);
    }
}
