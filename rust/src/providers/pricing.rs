//! Provider price book + model registry (paper Tables 6 & 7).
//!
//! Prices are USD per **million** tokens, matching the paper's cost
//! analysis: e.g. GPT-4o at $2.50/M input, $15.00/M output would give the
//! Table 6 row $10.00 input + $22.50 output for 10k examples × (400 in /
//! 150 out) tokens.

/// Per-model price + latency profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    pub provider: &'static str,
    pub model: &'static str,
    /// USD per 1M input tokens.
    pub input_per_m: f64,
    /// USD per 1M output tokens.
    pub output_per_m: f64,
    /// Median API latency in ms (lognormal median).
    pub latency_p50_ms: f64,
    /// Lognormal sigma controlling the tail (p99 ≈ p50·exp(2.33σ)).
    pub latency_sigma: f64,
    /// Answer-quality knob in [0,1]: probability the simulated model
    /// produces the ideal answer for a solvable prompt.
    pub quality: f64,
}

/// Table 7 model registry with Table 6-consistent prices.
pub const MODELS: &[ModelProfile] = &[
    // OpenAI
    ModelProfile { provider: "openai", model: "gpt-4o", input_per_m: 2.50, output_per_m: 15.00, latency_p50_ms: 320.0, latency_sigma: 0.45, quality: 0.90 },
    ModelProfile { provider: "openai", model: "gpt-4o-mini", input_per_m: 0.15, output_per_m: 0.60, latency_p50_ms: 220.0, latency_sigma: 0.40, quality: 0.78 },
    ModelProfile { provider: "openai", model: "gpt-4-turbo", input_per_m: 10.00, output_per_m: 30.00, latency_p50_ms: 550.0, latency_sigma: 0.50, quality: 0.88 },
    ModelProfile { provider: "openai", model: "gpt-3.5-turbo", input_per_m: 0.50, output_per_m: 1.50, latency_p50_ms: 180.0, latency_sigma: 0.40, quality: 0.66 },
    // Anthropic
    ModelProfile { provider: "anthropic", model: "claude-3-5-sonnet", input_per_m: 3.00, output_per_m: 15.00, latency_p50_ms: 350.0, latency_sigma: 0.45, quality: 0.91 },
    ModelProfile { provider: "anthropic", model: "claude-3-opus", input_per_m: 15.00, output_per_m: 75.00, latency_p50_ms: 700.0, latency_sigma: 0.50, quality: 0.92 },
    ModelProfile { provider: "anthropic", model: "claude-3-sonnet", input_per_m: 3.00, output_per_m: 15.00, latency_p50_ms: 380.0, latency_sigma: 0.45, quality: 0.82 },
    ModelProfile { provider: "anthropic", model: "claude-3-haiku", input_per_m: 0.25, output_per_m: 1.25, latency_p50_ms: 150.0, latency_sigma: 0.35, quality: 0.72 },
    // Google
    ModelProfile { provider: "google", model: "gemini-1.5-pro", input_per_m: 1.25, output_per_m: 5.00, latency_p50_ms: 400.0, latency_sigma: 0.48, quality: 0.86 },
    ModelProfile { provider: "google", model: "gemini-1.5-flash", input_per_m: 0.075, output_per_m: 0.30, latency_p50_ms: 160.0, latency_sigma: 0.38, quality: 0.74 },
    ModelProfile { provider: "google", model: "gemini-1.0-pro", input_per_m: 0.50, output_per_m: 1.50, latency_p50_ms: 300.0, latency_sigma: 0.45, quality: 0.70 },
];

/// Look up a model profile.
pub fn lookup(provider: &str, model: &str) -> Option<&'static ModelProfile> {
    MODELS.iter().find(|m| m.provider == provider && m.model == model)
}

/// Models offered by one provider (Table 7 row).
pub fn provider_models(provider: &str) -> Vec<&'static ModelProfile> {
    MODELS.iter().filter(|m| m.provider == provider).collect()
}

impl ModelProfile {
    /// Cost of one call in USD.
    pub fn cost(&self, input_tokens: usize, output_tokens: usize) -> f64 {
        input_tokens as f64 * self.input_per_m / 1e6
            + output_tokens as f64 * self.output_per_m / 1e6
    }

    /// Cost of a whole workload (Table 6 computation).
    pub fn workload_cost(&self, examples: usize, in_tokens: usize, out_tokens: usize) -> (f64, f64, f64) {
        let input = examples as f64 * in_tokens as f64 * self.input_per_m / 1e6;
        let output = examples as f64 * out_tokens as f64 * self.output_per_m / 1e6;
        (input, output, input + output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_table7() {
        assert_eq!(provider_models("openai").len(), 4);
        assert_eq!(provider_models("anthropic").len(), 4);
        assert_eq!(provider_models("google").len(), 3);
        assert!(lookup("openai", "gpt-4o").is_some());
        assert!(lookup("openai", "nonexistent").is_none());
    }

    #[test]
    fn table6_gpt4o_row() {
        // 10,000 examples × 400 input / 150 output tokens.
        let m = lookup("openai", "gpt-4o").unwrap();
        let (input, output, total) = m.workload_cost(10_000, 400, 150);
        assert!((input - 10.00).abs() < 1e-9, "input {input}");
        assert!((output - 22.50).abs() < 1e-9, "output {output}");
        assert!((total - 32.50).abs() < 1e-9);
    }

    #[test]
    fn table6_claude_haiku_row() {
        let m = lookup("anthropic", "claude-3-haiku").unwrap();
        let (input, output, total) = m.workload_cost(10_000, 400, 150);
        assert!((input - 1.00).abs() < 1e-9);
        assert!((output - 1.875).abs() < 1e-2, "output {output}");
        assert!((total - 2.88).abs() < 0.01);
    }

    #[test]
    fn table6_gemini_pro_row() {
        let m = lookup("google", "gemini-1.5-pro").unwrap();
        let (input, output, total) = m.workload_cost(10_000, 400, 150);
        assert!((input - 5.00).abs() < 1e-9);
        assert!((output - 7.50).abs() < 1e-9);
        assert!((total - 12.50).abs() < 1e-9);
    }

    #[test]
    fn mini_is_20x_cheaper_than_4o() {
        // §5.5: 1M examples GPT-4o ≈ $3,250 vs mini ≈ $150.
        let full = lookup("openai", "gpt-4o").unwrap().workload_cost(1_000_000, 400, 150).2;
        let mini = lookup("openai", "gpt-4o-mini").unwrap().workload_cost(1_000_000, 400, 150).2;
        assert!((full - 3250.0).abs() < 1.0, "full {full}");
        assert!((150.0 - mini).abs() < 1.0, "mini {mini}");
        assert!((full / mini - 21.7).abs() < 1.0);
    }

    #[test]
    fn per_call_cost() {
        let m = lookup("openai", "gpt-4o").unwrap();
        let c = m.cost(400, 150);
        assert!((c - (400.0 * 2.5 + 150.0 * 15.0) / 1e6).abs() < 1e-12);
    }
}
