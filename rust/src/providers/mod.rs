//! Inference-engine abstraction + simulated multi-provider LLM service.
//!
//! The paper evaluates through external APIs (OpenAI / Anthropic / Google).
//! This reproduction has no network, so [`simulated::SimEngine`] stands in:
//! it implements the same provider contract — per-model pricing and latency
//! distributions, server-side RPM/TPM enforcement with 429s, transient
//! 5xx errors, deterministic "model behaviour" with a quality knob so
//! different models produce measurably different metric scores (see
//! DESIGN.md §1 for why this preserves the paper's claims).

pub mod pipeline;
pub mod pricing;
pub mod retry;
pub mod simulated;
pub mod solver;
pub mod tokenizer;

use anyhow::Result;

/// One inference request (paper §3.3 / Listing 1).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f64,
}

impl InferenceRequest {
    pub fn new(prompt: impl Into<String>) -> Self {
        Self { prompt: prompt.into(), max_tokens: 1024, temperature: 0.0 }
    }
}

/// One inference response with usage accounting.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub text: String,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// API latency for this call in milliseconds (simulated or real).
    pub latency_ms: f64,
    /// Cost in USD at the provider's published per-token prices.
    pub cost_usd: f64,
}

/// API error taxonomy (paper §A.4).
#[derive(Debug, Clone)]
pub enum ApiError {
    RateLimited(String),
    Server { status: u16, message: String },
    Auth(String),
    InvalidRequest(String),
    ContentPolicy(String),
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::RateLimited(msg) => write!(f, "429 rate limited: {msg}"),
            ApiError::Server { status, message } => write!(f, "{status} server error: {message}"),
            ApiError::Auth(msg) => write!(f, "401 authentication failed: {msg}"),
            ApiError::InvalidRequest(msg) => write!(f, "400 invalid request: {msg}"),
            ApiError::ContentPolicy(msg) => write!(f, "content policy violation: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl ApiError {
    /// Recoverable errors trigger exponential-backoff retry (§A.4).
    pub fn recoverable(&self) -> bool {
        matches!(self, ApiError::RateLimited(_) | ApiError::Server { .. })
    }

    pub fn status(&self) -> u16 {
        match self {
            ApiError::RateLimited(_) => 429,
            ApiError::Server { status, .. } => *status,
            ApiError::Auth(_) => 401,
            ApiError::InvalidRequest(_) => 400,
            ApiError::ContentPolicy(_) => 400,
        }
    }
}

/// The provider abstraction (paper §3.3). One engine instance lives per
/// executor (Listing 1's `_ENGINE_CACHE`); engines must be `Send` so the
/// executor threads can own them.
pub trait InferenceEngine: Send {
    fn initialize(&mut self) -> Result<()>;
    fn infer(&mut self, request: &InferenceRequest) -> Result<InferenceResponse, ApiError>;

    /// Issue `request` without waiting out its delivery latency: returns
    /// the provider outcome plus the remaining wait (seconds) before the
    /// response is actually in hand. Engines that block for the full round
    /// trip inside `infer` resolve everything inline and return `0.0`;
    /// latency-simulating engines ([`simulated::SimEngine`]) return the
    /// simulated latency instead of sleeping it, so a pipelined client
    /// ([`pipeline::PipelinedClient`]) can overlap waits across in-flight
    /// slots. Invariant: `infer` ≡ `infer_deferred` followed by sleeping
    /// the returned wait on the engine's clock.
    fn infer_deferred(
        &mut self,
        request: &InferenceRequest,
    ) -> (Result<InferenceResponse, ApiError>, f64) {
        (self.infer(request), 0.0)
    }

    /// Sequential batch fallback. The throughput-bearing batch path is
    /// [`pipeline::PipelinedClient::run_batch`], which multiplexes up to
    /// `inference.concurrency` in-flight requests over slot engines; this
    /// default exists for engines used outside the pipelined hot path.
    fn infer_batch(
        &mut self,
        requests: &[InferenceRequest],
    ) -> Vec<Result<InferenceResponse, ApiError>> {
        requests.iter().map(|r| self.infer(r)).collect()
    }
    fn shutdown(&mut self) {}
    /// Provider + model identity (cache keys, tracking tags).
    fn model_id(&self) -> (String, String);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_recoverability() {
        assert!(ApiError::RateLimited("x".into()).recoverable());
        assert!(ApiError::Server { status: 503, message: "x".into() }.recoverable());
        assert!(!ApiError::Auth("x".into()).recoverable());
        assert!(!ApiError::InvalidRequest("x".into()).recoverable());
        assert!(!ApiError::ContentPolicy("x".into()).recoverable());
    }

    #[test]
    fn statuses() {
        assert_eq!(ApiError::RateLimited("x".into()).status(), 429);
        assert_eq!(ApiError::Auth("x".into()).status(), 401);
        assert_eq!(ApiError::Server { status: 500, message: "".into() }.status(), 500);
    }
}
