//! Simulated LLM provider service + engine.
//!
//! [`SimService`] is the "server side": one per provider endpoint, shared
//! across all executor engines. It enforces the provider's *global* RPM/TPM
//! budget with a sliding-window meter (returning 429s exactly like a real
//! endpoint when clients exceed their share), injects transient 5xx errors,
//! and draws per-call latency from the model's lognormal profile.
//!
//! [`SimEngine`] is the "client SDK" an executor owns (Listing 1's cached
//! engine): it submits requests to the shared service, sleeps out the
//! simulated latency on the caller's clock, and accounts tokens + cost.
//!
//! Everything is deterministic given the seeds: response text via the
//! solver keyed by `hash(prompt, model)`, latency/error draws from a
//! per-call hash — so identical configurations replay identically,
//! which the caching tests rely on.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::pricing::{lookup, ModelProfile};
use super::solver::{fnv1a, solve};
use super::tokenizer::estimate_tokens;
use super::{ApiError, InferenceEngine, InferenceRequest, InferenceResponse};
use crate::ratelimit::Clock;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Provider-endpoint behaviour knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct SimServiceConfig {
    /// Server-side global request budget per minute.
    pub global_rpm: f64,
    /// Server-side global token budget per minute.
    pub global_tpm: f64,
    /// Probability of a transient 5xx per call.
    pub server_error_rate: f64,
    /// Probability a judge-style response is emitted malformed
    /// (paper §5.6 reports 0.12% unparseable judge responses).
    pub unparseable_rate: f64,
    /// Scale factor on latency (1.0 = Table 3-calibrated profile).
    pub latency_scale: f64,
    /// When false, latency is accounted but not slept (simulation mode).
    pub sleep_latency: bool,
    /// Latency-skew injection (straggler testing, paper §6.1): fraction of
    /// prompts whose calls land in the heavy tail. The draw is keyed on
    /// prompt content (not call sequence), so a slow prompt stays slow
    /// across retries and speculative re-executions — the content-dependent
    /// skew the scheduler exists to absorb. 0.0 disables.
    pub tail_latency_rate: f64,
    /// Latency multiplier applied to tail calls.
    pub tail_latency_mult: f64,
    pub seed: u64,
}

impl Default for SimServiceConfig {
    fn default() -> Self {
        Self {
            global_rpm: 10_000.0,
            global_tpm: 2_000_000.0,
            server_error_rate: 0.0005,
            unparseable_rate: 0.0012,
            latency_scale: 1.0,
            sleep_latency: true,
            tail_latency_rate: 0.0,
            tail_latency_mult: 10.0,
            seed: 0,
        }
    }
}

impl SimServiceConfig {
    /// Wire encoding for serializable task plans: an out-of-process
    /// executor rebuilds its provider endpoint from these knobs, so the
    /// simulated responses (content-seeded, not call-seeded) are
    /// identical to the driver's.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("global_rpm", Json::num(self.global_rpm)),
            ("global_tpm", Json::num(self.global_tpm)),
            ("server_error_rate", Json::num(self.server_error_rate)),
            ("unparseable_rate", Json::num(self.unparseable_rate)),
            ("latency_scale", Json::num(self.latency_scale)),
            ("sleep_latency", Json::Bool(self.sleep_latency)),
            ("tail_latency_rate", Json::num(self.tail_latency_rate)),
            ("tail_latency_mult", Json::num(self.tail_latency_mult)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &crate::util::json::Json) -> Result<SimServiceConfig> {
        let d = SimServiceConfig::default();
        Ok(SimServiceConfig {
            global_rpm: v.f64_or("global_rpm", d.global_rpm),
            global_tpm: v.f64_or("global_tpm", d.global_tpm),
            server_error_rate: v.f64_or("server_error_rate", d.server_error_rate),
            unparseable_rate: v.f64_or("unparseable_rate", d.unparseable_rate),
            latency_scale: v.f64_or("latency_scale", d.latency_scale),
            sleep_latency: v.bool_or("sleep_latency", d.sleep_latency),
            tail_latency_rate: v.f64_or("tail_latency_rate", d.tail_latency_rate),
            tail_latency_mult: v.f64_or("tail_latency_mult", d.tail_latency_mult),
            seed: v.f64_or("seed", 0.0) as u64,
        })
    }
}

/// Sliding one-minute usage window (server-side metering).
#[derive(Debug, Default)]
struct UsageWindow {
    /// (timestamp, tokens) of admitted calls in the last 60 s.
    events: VecDeque<(f64, f64)>,
    requests: f64,
    tokens: f64,
}

impl UsageWindow {
    fn evict(&mut self, now: f64) {
        while let Some(&(t, tok)) = self.events.front() {
            if now - t >= 60.0 {
                self.events.pop_front();
                self.requests -= 1.0;
                self.tokens -= tok;
            } else {
                break;
            }
        }
    }

    fn admit(&mut self, now: f64, tokens: f64) {
        self.events.push_back((now, tokens));
        self.requests += 1.0;
        self.tokens += tokens;
    }
}

/// Server-side shared state.
struct ServiceState {
    window: UsageWindow,
    calls: u64,
    throttled: u64,
    errored: u64,
}

/// The simulated provider endpoint (shared, thread-safe).
pub struct SimService {
    pub provider: String,
    pub config: SimServiceConfig,
    clock: Arc<dyn Clock>,
    state: Mutex<ServiceState>,
}

/// Telemetry snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceStats {
    pub calls: u64,
    pub throttled: u64,
    pub errored: u64,
}

impl SimService {
    pub fn new(provider: &str, config: SimServiceConfig, clock: Arc<dyn Clock>) -> Arc<Self> {
        Arc::new(Self {
            provider: provider.to_string(),
            config,
            clock,
            state: Mutex::new(ServiceState {
                window: UsageWindow::default(),
                calls: 0,
                throttled: 0,
                errored: 0,
            }),
        })
    }

    pub fn stats(&self) -> ServiceStats {
        let s = self.state.lock().unwrap();
        ServiceStats { calls: s.calls, throttled: s.throttled, errored: s.errored }
    }

    /// Handle one API call. Returns the response text + latency, or an
    /// [`ApiError`] (429 when the global window is exhausted, 5xx on
    /// injected faults).
    fn handle(
        &self,
        model: &ModelProfile,
        request: &InferenceRequest,
        call_seq: u64,
    ) -> Result<(String, f64, usize), ApiError> {
        let now = self.clock.now();
        let in_tokens = estimate_tokens(&request.prompt);

        {
            let mut st = self.state.lock().unwrap();
            st.calls += 1;
            st.window.evict(now);
            if st.window.requests + 1.0 > self.config.global_rpm
                || st.window.tokens + in_tokens as f64 > self.config.global_tpm
            {
                st.throttled += 1;
                return Err(ApiError::RateLimited(format!(
                    "{} global limit exceeded ({} rpm)",
                    self.provider, self.config.global_rpm
                )));
            }
            st.window.admit(now, in_tokens as f64);
        }

        // Per-call deterministic draws: seed from (prompt, model, seq for
        // transient faults — retries of the same call get fresh draws).
        let fault_seed = fnv1a(&request.prompt)
            ^ fnv1a(model.model)
            ^ call_seq.wrapping_mul(0x9e3779b97f4a7c15)
            ^ self.config.seed;
        let mut fault_rng = Rng::new(fault_seed);
        if fault_rng.chance(self.config.server_error_rate) {
            self.state.lock().unwrap().errored += 1;
            let status = *fault_rng.choose(&[500u16, 502, 503]);
            return Err(ApiError::Server {
                status,
                message: "simulated transient upstream failure".into(),
            });
        }

        // Latency draw: lognormal with median latency_p50_ms.
        let mu = (model.latency_p50_ms * self.config.latency_scale).ln();
        let mut latency_ms = fault_rng.lognormal(mu, model.latency_sigma);
        if self.config.tail_latency_rate > 0.0 {
            // Content-keyed (no call_seq): the same prompt is slow on every
            // attempt, like a genuinely long/hard request.
            let skew_seed =
                fnv1a(&request.prompt) ^ fnv1a(model.model) ^ self.config.seed ^ 0x7461696c;
            let mut skew_rng = Rng::new(skew_seed);
            if skew_rng.chance(self.config.tail_latency_rate) {
                latency_ms *= self.config.tail_latency_mult.max(1.0);
            }
        }

        // Response content: solver + quality knob, seeded WITHOUT call_seq
        // so retried/replayed calls yield the same text (temperature 0).
        let content_seed = fnv1a(&request.prompt) ^ fnv1a(model.model) ^ self.config.seed;
        let mut content_rng = Rng::new(content_seed);
        let solution = solve(&request.prompt);
        let mut text = if request.temperature <= 0.0 {
            if content_rng.chance(model.quality) { solution.ideal } else { solution.wrong }
        } else {
            // Temperature > 0: mix in sampling noise (still seeded).
            let jitter = content_rng.f64() * request.temperature;
            if content_rng.chance((model.quality - jitter).clamp(0.0, 1.0)) {
                solution.ideal
            } else {
                solution.wrong
            }
        };
        // Judge-response corruption (unparseable fraction).
        if request.prompt.contains("SLLEVAL-JUDGE") && content_rng.chance(self.config.unparseable_rate)
        {
            text = "i would rate this response quite favorably overall".to_string();
        }
        // Respect max_tokens by truncating words.
        let max_words = request.max_tokens.max(1);
        let words: Vec<&str> = text.split_whitespace().collect();
        if words.len() > max_words {
            text = words[..max_words].join(" ");
        }

        Ok((text, latency_ms, in_tokens))
    }
}

/// Client-side engine bound to one (provider, model).
pub struct SimEngine {
    pub profile: &'static ModelProfile,
    service: Arc<SimService>,
    clock: Arc<dyn Clock>,
    initialized: bool,
    call_seq: u64,
    /// Cumulative usage for this engine.
    pub total_cost: f64,
    pub total_calls: u64,
}

impl SimEngine {
    pub fn new(service: Arc<SimService>, provider: &str, model: &str, clock: Arc<dyn Clock>) -> Result<Self> {
        let profile = lookup(provider, model)
            .ok_or_else(|| anyhow!("unknown model {provider}/{model} (see Table 7 registry)"))?;
        Ok(Self {
            profile,
            service,
            clock,
            initialized: false,
            call_seq: 0,
            total_cost: 0.0,
            total_calls: 0,
        })
    }
}

impl InferenceEngine for SimEngine {
    fn initialize(&mut self) -> Result<()> {
        self.initialized = true;
        Ok(())
    }

    fn infer(&mut self, request: &InferenceRequest) -> Result<InferenceResponse, ApiError> {
        let (result, wait_secs) = self.infer_deferred(request);
        if wait_secs > 0.0 {
            self.clock.sleep(wait_secs);
        }
        result
    }

    /// Issue the call without sleeping out its latency: the remaining wait
    /// is returned for the caller to overlap (pipelined client) or sleep
    /// (the blocking `infer` above). Errors return before any latency is
    /// incurred, exactly as before.
    fn infer_deferred(
        &mut self,
        request: &InferenceRequest,
    ) -> (Result<InferenceResponse, ApiError>, f64) {
        assert!(self.initialized, "engine used before initialize()");
        self.call_seq += 1;
        let (text, latency_ms, input_tokens) =
            match self.service.handle(self.profile, request, self.call_seq) {
                Ok(ok) => ok,
                Err(e) => return (Err(e), 0.0),
            };
        let wait_secs =
            if self.service.config.sleep_latency { latency_ms / 1000.0 } else { 0.0 };
        let output_tokens = estimate_tokens(&text);
        let cost = self.profile.cost(input_tokens, output_tokens);
        self.total_cost += cost;
        self.total_calls += 1;
        (
            Ok(InferenceResponse { text, input_tokens, output_tokens, latency_ms, cost_usd: cost }),
            wait_secs,
        )
    }

    fn shutdown(&mut self) {
        self.initialized = false;
    }

    fn model_id(&self) -> (String, String) {
        (self.profile.provider.to_string(), self.profile.model.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ratelimit::VirtualClock;

    fn engine(cfg: SimServiceConfig) -> (SimEngine, Arc<VirtualClock>) {
        let clock = VirtualClock::new();
        let svc = SimService::new("openai", cfg, clock.clone());
        let mut e = SimEngine::new(svc, "openai", "gpt-4o", clock.clone()).unwrap();
        e.initialize().unwrap();
        (e, clock)
    }

    fn no_fault_cfg() -> SimServiceConfig {
        SimServiceConfig { server_error_rate: 0.0, unparseable_rate: 0.0, ..Default::default() }
    }

    #[test]
    fn deterministic_responses() {
        let (mut e1, _) = engine(no_fault_cfg());
        let (mut e2, _) = engine(no_fault_cfg());
        let req = InferenceRequest::new("Question: what is the capital of france?");
        let r1 = e1.infer(&req).unwrap();
        let r2 = e2.infer(&req).unwrap();
        assert_eq!(r1.text, r2.text);
        assert_eq!(r1.input_tokens, r2.input_tokens);
    }

    #[test]
    fn quality_knob_separates_models() {
        // Over many distinct QA prompts, gpt-4o must answer correctly more
        // often than gpt-3.5-turbo.
        let clock = VirtualClock::new();
        let svc = SimService::new("openai", no_fault_cfg(), clock.clone());
        let mut strong = SimEngine::new(svc.clone(), "openai", "gpt-4o", clock.clone()).unwrap();
        let mut weak = SimEngine::new(svc, "openai", "gpt-3.5-turbo", clock.clone()).unwrap();
        strong.initialize().unwrap();
        weak.initialize().unwrap();

        let df = crate::data::synth::generate(
            300,
            9,
            crate::data::synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut strong_correct = 0;
        let mut weak_correct = 0;
        for row in df.iter_rows() {
            let req = InferenceRequest::new(row.str("prompt"));
            let reference = row.str("reference");
            if strong.infer(&req).unwrap().text == reference {
                strong_correct += 1;
            }
            if weak.infer(&req).unwrap().text == reference {
                weak_correct += 1;
            }
        }
        assert!(
            strong_correct > weak_correct + 20,
            "strong {strong_correct} vs weak {weak_correct}"
        );
    }

    #[test]
    fn global_rate_limit_throttles() {
        let cfg = SimServiceConfig { global_rpm: 10.0, ..no_fault_cfg() };
        let (mut e, _clock) = engine(cfg);
        let req = InferenceRequest::new("hello");
        let mut throttled = 0;
        for _ in 0..20 {
            match e.infer(&req) {
                Err(ApiError::RateLimited(_)) => throttled += 1,
                Err(other) => panic!("unexpected error {other}"),
                Ok(_) => {}
            }
        }
        assert_eq!(throttled, 10);
    }

    #[test]
    fn window_slides_with_time() {
        let cfg = SimServiceConfig { global_rpm: 5.0, sleep_latency: false, ..no_fault_cfg() };
        let (mut e, clock) = engine(cfg);
        let req = InferenceRequest::new("hi");
        for _ in 0..5 {
            e.infer(&req).unwrap();
        }
        assert!(matches!(e.infer(&req), Err(ApiError::RateLimited(_))));
        clock.advance(61.0);
        assert!(e.infer(&req).is_ok());
    }

    #[test]
    fn latency_profile_plausible() {
        let cfg = SimServiceConfig { sleep_latency: false, ..no_fault_cfg() };
        let (mut e, _) = engine(cfg);
        let mut lats: Vec<f64> = Vec::new();
        for i in 0..500 {
            let req = InferenceRequest::new(format!("prompt variant {i}"));
            lats.push(e.infer(&req).unwrap().latency_ms);
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = lats[250];
        // Median should be near the profile's 320ms.
        assert!((250.0..420.0).contains(&p50), "p50 {p50}");
        assert!(lats[494] > p50 * 1.5, "p99 {} p50 {p50}", lats[494]);
    }

    #[test]
    fn cost_accounting_matches_pricebook() {
        let (mut e, _) = engine(no_fault_cfg());
        let req = InferenceRequest::new("Question: what is the capital of japan?");
        let r = e.infer(&req).unwrap();
        let expected = e.profile.cost(r.input_tokens, r.output_tokens);
        assert!((r.cost_usd - expected).abs() < 1e-12);
        assert!((e.total_cost - expected).abs() < 1e-12);
    }

    #[test]
    fn error_injection_rate() {
        let cfg = SimServiceConfig {
            server_error_rate: 0.2,
            sleep_latency: false,
            ..Default::default()
        };
        let (mut e, _) = engine(cfg);
        let mut errors = 0;
        for i in 0..1000 {
            let req = InferenceRequest::new(format!("p{i}"));
            if let Err(ApiError::Server { .. }) = e.infer(&req) {
                errors += 1;
            }
        }
        assert!((120..280).contains(&errors), "errors {errors}");
    }

    #[test]
    fn retry_gets_fresh_fault_draw_same_text() {
        // A transient 5xx on one attempt must not change the response text
        // of a later successful attempt (content seed excludes call_seq).
        let cfg = SimServiceConfig {
            server_error_rate: 0.5,
            sleep_latency: false,
            ..Default::default()
        };
        let (mut e, _) = engine(cfg);
        let req = InferenceRequest::new("Question: what is the capital of kenya?");
        let mut texts = std::collections::BTreeSet::new();
        for _ in 0..50 {
            if let Ok(r) = e.infer(&req) {
                texts.insert(r.text);
            }
        }
        assert_eq!(texts.len(), 1, "all successes must agree: {texts:?}");
    }

    #[test]
    fn tail_latency_skew_injection() {
        let base_cfg = SimServiceConfig { sleep_latency: false, ..no_fault_cfg() };
        let skew_cfg = SimServiceConfig {
            tail_latency_rate: 0.2,
            tail_latency_mult: 25.0,
            ..base_cfg.clone()
        };
        let (mut base, _) = engine(base_cfg);
        let (mut skew, _) = engine(skew_cfg);
        let mut n_slow = 0;
        for i in 0..300 {
            let req = InferenceRequest::new(format!("tail probe {i}"));
            let a = base.infer(&req).unwrap().latency_ms;
            let b = skew.infer(&req).unwrap().latency_ms;
            // Same per-call base draw: the skewed engine either matches it
            // exactly or multiplies it by exactly tail_latency_mult.
            let exact = (b - a).abs() < 1e-9 || (b - 25.0 * a).abs() < 1e-6;
            assert!(exact, "prompt {i}: base {a} skewed {b}");
            if b > a * 10.0 {
                n_slow += 1;
            }
        }
        assert!((30..100).contains(&n_slow), "tail fraction {n_slow}/300");
    }

    #[test]
    fn max_tokens_truncates() {
        let (mut e, _) = engine(no_fault_cfg());
        let mut req = InferenceRequest::new("Instruction: list three uses for neural networks\nResponse:");
        req.max_tokens = 3;
        let r = e.infer(&req).unwrap();
        assert!(r.text.split_whitespace().count() <= 3);
    }

    #[test]
    fn unknown_model_rejected() {
        let clock = VirtualClock::new();
        let svc = SimService::new("openai", SimServiceConfig::default(), clock.clone());
        assert!(SimEngine::new(svc, "openai", "gpt-99", clock).is_err());
    }
}
