//! Retry with exponential backoff (paper §A.4).
//!
//! Recoverable errors (429, 5xx) retry up to `max_retries` times with
//! delay `retry_delay * 2^attempt` (+ deterministic jitter); non-recoverable
//! errors (401, 400, content policy) surface immediately and the example is
//! marked failed.

use super::{ApiError, InferenceEngine, InferenceRequest, InferenceResponse};
use crate::ratelimit::Clock;
use crate::util::rng::Rng;

/// Backoff policy.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: usize,
    /// Base delay in seconds.
    pub base_delay: f64,
    /// Cap on a single backoff sleep.
    pub max_delay: f64,
    /// Jitter fraction in [0, 1): delay *= 1 + U(-j, j).
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 3, base_delay: 1.0, max_delay: 30.0, jitter: 0.1 }
    }
}

impl RetryPolicy {
    pub fn delay_for_attempt(&self, attempt: usize, rng: &mut Rng) -> f64 {
        let base = (self.base_delay * 2f64.powi(attempt as i32)).min(self.max_delay);
        let j = if self.jitter > 0.0 { 1.0 + rng.range_f64(-self.jitter, self.jitter) } else { 1.0 };
        base * j
    }
}

/// Outcome of a retried call: response + attempts used + backoff slept.
#[derive(Debug)]
pub struct RetryOutcome {
    pub result: Result<InferenceResponse, ApiError>,
    pub attempts: usize,
    pub backoff_secs: f64,
}

/// Call `engine.infer` with retries under `policy`, sleeping on `clock`.
pub fn infer_with_retry(
    engine: &mut dyn InferenceEngine,
    request: &InferenceRequest,
    policy: &RetryPolicy,
    clock: &dyn Clock,
    rng: &mut Rng,
) -> RetryOutcome {
    let mut backoff_secs = 0.0;
    for attempt in 0..=policy.max_retries {
        match engine.infer(request) {
            Ok(resp) => {
                return RetryOutcome { result: Ok(resp), attempts: attempt + 1, backoff_secs }
            }
            Err(e) if e.recoverable() && attempt < policy.max_retries => {
                let delay = policy.delay_for_attempt(attempt, rng);
                clock.sleep(delay);
                backoff_secs += delay;
            }
            Err(e) => {
                return RetryOutcome { result: Err(e), attempts: attempt + 1, backoff_secs }
            }
        }
    }
    unreachable!("loop always returns");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ratelimit::VirtualClock;
    use anyhow::Result;

    /// Scripted engine: errors for the first `fail_n` calls, then succeeds.
    struct Flaky {
        fail_n: usize,
        calls: usize,
        error: fn() -> ApiError,
    }

    impl InferenceEngine for Flaky {
        fn initialize(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(&mut self, _r: &InferenceRequest) -> Result<InferenceResponse, ApiError> {
            self.calls += 1;
            if self.calls <= self.fail_n {
                Err((self.error)())
            } else {
                Ok(InferenceResponse {
                    text: "ok".into(),
                    input_tokens: 1,
                    output_tokens: 1,
                    latency_ms: 1.0,
                    cost_usd: 0.0,
                })
            }
        }

        fn model_id(&self) -> (String, String) {
            ("test".into(), "flaky".into())
        }
    }

    fn run(fail_n: usize, error: fn() -> ApiError, max_retries: usize) -> (RetryOutcome, f64) {
        let clock = VirtualClock::new();
        let mut engine = Flaky { fail_n, calls: 0, error };
        let policy = RetryPolicy { max_retries, jitter: 0.0, ..Default::default() };
        let mut rng = Rng::new(0);
        let out = infer_with_retry(&mut engine, &InferenceRequest::new("x"), &policy, clock.as_ref(), &mut rng);
        let t = clock.now();
        (out, t)
    }

    #[test]
    fn succeeds_first_try() {
        let (out, t) = run(0, || ApiError::RateLimited("".into()), 3);
        assert!(out.result.is_ok());
        assert_eq!(out.attempts, 1);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn retries_recoverable_with_exponential_backoff() {
        let (out, t) = run(2, || ApiError::Server { status: 503, message: "".into() }, 3);
        assert!(out.result.is_ok());
        assert_eq!(out.attempts, 3);
        // Slept 1s + 2s.
        assert!((t - 3.0).abs() < 1e-9, "slept {t}");
        assert!((out.backoff_secs - 3.0).abs() < 1e-9);
    }

    #[test]
    fn gives_up_after_max_retries() {
        let (out, t) = run(10, || ApiError::RateLimited("".into()), 3);
        assert!(matches!(out.result, Err(ApiError::RateLimited(_))));
        assert_eq!(out.attempts, 4); // initial + 3 retries
        assert!((t - 7.0).abs() < 1e-9, "slept {t}"); // 1+2+4
    }

    #[test]
    fn non_recoverable_fails_fast() {
        let (out, t) = run(10, || ApiError::Auth("bad key".into()), 3);
        assert!(matches!(out.result, Err(ApiError::Auth(_))));
        assert_eq!(out.attempts, 1);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn delay_capped() {
        let policy = RetryPolicy { max_retries: 10, base_delay: 1.0, max_delay: 5.0, jitter: 0.0 };
        let mut rng = Rng::new(0);
        assert_eq!(policy.delay_for_attempt(10, &mut rng), 5.0);
    }

    #[test]
    fn jitter_bounded() {
        let policy = RetryPolicy { jitter: 0.2, ..Default::default() };
        let mut rng = Rng::new(1);
        for attempt in 0..4 {
            let base = (policy.base_delay * 2f64.powi(attempt)).min(policy.max_delay);
            let d = policy.delay_for_attempt(attempt as usize, &mut rng);
            assert!(d >= base * 0.8 - 1e-12 && d <= base * 1.2 + 1e-12);
        }
    }
}
