//! Run checkpointing: crash-safe persistence of completed scheduler tasks
//! so an interrupted evaluation can resume without repaying API cost.
//!
//! The paper's cost argument (§3.2) is that cached responses make metric
//! iteration free — but a crashed *first* run still used to throw away
//! every completed task. This module makes paid work durable at task
//! granularity: as the scheduler finishes a task, its row results are
//! spilled to a run directory together with a manifest record, both
//! published with the same atomic first-writer-wins discipline as the
//! Delta transaction log ([`crate::util::fsx`]).
//!
//! Layout (one run directory, one subdirectory per checkpointed stage):
//!
//! ```text
//! <run_dir>/
//!   <stage>/meta.json                      fingerprint binding the stage
//!                                          to its exact inputs
//!   <stage>/tasks/<start>-<end>.json       manifest record per completed
//!                                          task range (exclusive publish)
//!   <stage>/data/<start>-<end>.jsonl       row results, one JSON per row
//! ```
//!
//! Stages are content-addressed: the stage name embeds a hash of the exact
//! inputs (prompts, model, sampling parameters), so a resumed run restores
//! a stage only when its inputs are byte-identical — streaming chunks,
//! pairwise A/B inference, and judge passes all get distinct stages for
//! free, and resuming against a changed dataset silently (and correctly)
//! re-executes instead of stitching mismatched rows.
//!
//! Crash-safety protocol per completed task:
//!
//! 1. write the row data file atomically (temp + rename);
//! 2. publish the manifest record pointing at it with an exclusive claim.
//!
//! A crash between the steps leaves an unreferenced data file — garbage,
//! never a dangling pointer. A crash mid-write leaves only hidden temp
//! files, which loading ignores. Records whose data file is missing or has
//! the wrong row count are skipped on restore (that range simply
//! re-executes).

use crate::util::fsx::{self, Publish};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

const TASKS_DIR: &str = "tasks";
const DATA_DIR: &str = "data";

/// One completed-task record in a stage manifest.
#[derive(Debug, Clone)]
pub struct TaskManifest {
    /// Row range covered by the spilled results (post-split, exact).
    pub start: usize,
    pub end: usize,
    /// Attempt number that won the task.
    pub attempt: usize,
    /// Executor that produced the winning attempt.
    pub executor_id: usize,
    /// Data file (relative to the stage's `data/` directory).
    pub rows_file: String,
    /// Unix timestamp of the checkpoint write.
    pub recorded_at: f64,
}

impl TaskManifest {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("start", Json::num(self.start as f64)),
            ("end", Json::num(self.end as f64)),
            ("status", Json::str("done")),
            ("attempt", Json::num(self.attempt as f64)),
            ("executor_id", Json::num(self.executor_id as f64)),
            ("rows_file", Json::str(&self.rows_file)),
            ("recorded_at", Json::num(self.recorded_at)),
        ])
    }

    fn from_json(v: &Json) -> Result<TaskManifest> {
        Ok(TaskManifest {
            start: v.get("start")?.as_usize()?,
            end: v.get("end")?.as_usize()?,
            attempt: v.usize_or("attempt", 1),
            executor_id: v.usize_or("executor_id", 0),
            rows_file: v.get("rows_file")?.as_str()?.to_string(),
            recorded_at: v.f64_or("recorded_at", 0.0),
        })
    }
}

/// Handle on a run directory holding per-stage checkpoints.
pub struct RunCheckpoint {
    root: PathBuf,
    resume: bool,
}

impl RunCheckpoint {
    /// Start a fresh run directory. Refuses a non-empty existing directory:
    /// continuing one requires the explicit `--resume` intent (otherwise a
    /// stale manifest could silently shadow freshly computed results).
    pub fn create(root: &Path) -> Result<RunCheckpoint> {
        if root.exists() {
            let occupied = std::fs::read_dir(root)
                .with_context(|| format!("inspecting checkpoint dir {root:?}"))?
                .next()
                .is_some();
            if occupied {
                bail!(
                    "checkpoint directory {root:?} already holds a run; \
                     resume it with --resume or choose a fresh directory"
                );
            }
        }
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating checkpoint dir {root:?}"))?;
        Ok(RunCheckpoint { root: root.to_path_buf(), resume: false })
    }

    /// Reopen an interrupted run's directory for resumption.
    pub fn resume(root: &Path) -> Result<RunCheckpoint> {
        if !root.is_dir() {
            bail!("cannot resume: checkpoint directory {root:?} does not exist");
        }
        Ok(RunCheckpoint { root: root.to_path_buf(), resume: true })
    }

    pub fn is_resume(&self) -> bool {
        self.resume
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Enumerate the stages already present in this run directory (any
    /// subdirectory with a `meta.json`), with their recorded row counts.
    pub fn stages(&self) -> Result<Vec<(String, StageCheckpoint)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)
            .with_context(|| format!("listing checkpoint dir {:?}", self.root))?
        {
            let path = entry?.path();
            if !path.is_dir() {
                continue;
            }
            let meta_path = path.join("meta.json");
            let Ok(text) = std::fs::read_to_string(&meta_path) else { continue };
            let meta = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("corrupt stage meta {meta_path:?}: {e}"))?;
            let total_rows = meta.usize_or("total_rows", 0);
            let name = path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            out.push((name, StageCheckpoint { dir: path, total_rows }));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }

    /// Compact every stage in the run directory: adjacent per-task
    /// manifest records coalesce into one record (+ one data file) per
    /// contiguous span — a completed stage ends up with a single record.
    /// Resume reads compacted and uncompacted stages identically.
    pub fn compact(&self) -> Result<Vec<StageCompaction>> {
        let mut report = Vec::new();
        for (name, stage) in self.stages()? {
            let (records_before, records_after, coalesced_runs) = stage
                .compact()
                .with_context(|| format!("compacting checkpoint stage '{name}'"))?;
            report.push(StageCompaction {
                stage: name,
                records_before,
                records_after,
                coalesced_runs,
            });
        }
        Ok(report)
    }

    /// Open (creating on first use) one stage's checkpoint store.
    /// `fingerprint` binds the stage to its exact inputs; reopening an
    /// existing stage with a different fingerprint is an error rather than
    /// a silent mix of incompatible results.
    pub fn stage(
        &self,
        name: &str,
        fingerprint: &Json,
        total_rows: usize,
    ) -> Result<StageCheckpoint> {
        let dir = self.root.join(name);
        std::fs::create_dir_all(dir.join(TASKS_DIR))?;
        std::fs::create_dir_all(dir.join(DATA_DIR))?;
        let meta = Json::obj(vec![
            ("fingerprint", fingerprint.clone()),
            ("total_rows", Json::num(total_rows as f64)),
        ]);
        let meta_path = dir.join("meta.json");
        if meta_path.exists() {
            let existing = Json::parse(&std::fs::read_to_string(&meta_path)?)
                .map_err(|e| anyhow::anyhow!("corrupt stage meta {meta_path:?}: {e}"))?;
            if existing != meta {
                bail!(
                    "checkpoint stage '{name}' in {:?} was written with different \
                     inputs (fingerprint mismatch); refusing to mix runs",
                    self.root
                );
            }
        } else {
            fsx::write_atomic(&meta_path, meta.to_pretty().as_bytes())?;
        }
        Ok(StageCheckpoint { dir, total_rows })
    }
}

/// Outcome of compacting one stage.
#[derive(Debug, Clone)]
pub struct StageCompaction {
    pub stage: String,
    pub records_before: usize,
    pub records_after: usize,
    /// Contiguous multi-record spans that were coalesced.
    pub coalesced_runs: usize,
}

/// Checkpoint store for one scheduler stage.
pub struct StageCheckpoint {
    dir: PathBuf,
    total_rows: usize,
}

impl StageCheckpoint {
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The stage's declared row count (from `meta.json`).
    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    /// The stage's recorded fingerprint object (the `"fingerprint"` key
    /// of `meta.json`: content kind + sha256 of the stage's exact
    /// inputs) — introspection for `slleval checkpoint ls` and the eval
    /// service's registry. `Json::Null` if the meta predates
    /// fingerprinting.
    pub fn fingerprint(&self) -> Result<Json> {
        let meta_path = self.dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading checkpoint stage meta {meta_path:?}"))?;
        let meta = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("corrupt stage meta {meta_path:?}: {e}"))?;
        Ok(meta.opt("fingerprint").cloned().unwrap_or(Json::Null))
    }

    /// Reopen an existing stage directly by directory — the worker-side
    /// spill path for out-of-process executors
    /// ([`crate::sched::backend::ProcessBackend`]): the driver creates the
    /// stage (fingerprint-bound) and ships its path in the task plan; each
    /// worker reopens it and records its own completed tasks. Concurrent
    /// writers are already safe — data files are written atomically and
    /// manifest records publish with an exclusive first-writer-wins claim.
    pub fn open(dir: &Path) -> Result<StageCheckpoint> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("opening checkpoint stage {dir:?}"))?;
        let meta = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("corrupt stage meta {meta_path:?}: {e}"))?;
        let total_rows = meta.usize_or("total_rows", 0);
        Ok(StageCheckpoint { dir: dir.to_path_buf(), total_rows })
    }

    /// Crash-safely record one completed task: `lines` are the task's rows
    /// already encoded as single-line JSON. Racing twins of the same range
    /// are benign — the first record published wins and later ones are
    /// dropped (their rows are identical task outputs).
    pub fn record_task(
        &self,
        start: usize,
        end: usize,
        attempt: usize,
        executor_id: usize,
        lines: &[String],
    ) -> Result<()> {
        if lines.len() != end - start {
            bail!(
                "checkpoint record for [{start}, {end}) has {} rows, expected {}",
                lines.len(),
                end - start
            );
        }
        let manifest_path = self.dir.join(TASKS_DIR).join(format!("{start:08}-{end:08}.json"));
        let rows_file = format!("{start:08}-{end:08}.jsonl");
        if manifest_path.exists() {
            // Already recorded (a re-run of the same stage, or a resume
            // re-executing a range whose spill was lost). Skip only when
            // the spilled data is actually healthy — right row count and
            // every row parseable, mirroring what `restore` will demand —
            // otherwise fall through and repair it, or the range would be
            // re-paid on every future resume.
            let healthy = std::fs::read_to_string(self.dir.join(DATA_DIR).join(&rows_file))
                .map(|t| {
                    let lines: Vec<&str> =
                        t.lines().filter(|l| !l.trim().is_empty()).collect();
                    lines.len() == end - start
                        && lines.iter().all(|l| Json::parse(l).is_ok())
                })
                .unwrap_or(false);
            if healthy {
                return Ok(());
            }
        }
        let mut body = String::new();
        for line in lines {
            body.push_str(line);
            body.push('\n');
        }
        // Data first, then the manifest pointer: a crash in between leaves
        // an unreferenced data file, never a pointer to missing data.
        fsx::write_atomic(&self.dir.join(DATA_DIR).join(&rows_file), body.as_bytes())?;
        let record = TaskManifest {
            start,
            end,
            attempt,
            executor_id,
            rows_file,
            recorded_at: crate::util::unix_ts(),
        };
        // `Conflict` means a racing writer already recorded this range —
        // benign (its rows are the same task's output).
        let _: Publish =
            fsx::publish_exclusive(&manifest_path, record.to_json().to_pretty().as_bytes())?;
        Ok(())
    }

    /// Load and validate the manifest: records sorted by range start,
    /// ranges disjoint and in-bounds. A record **fully contained** in
    /// another is a benign leftover of an interrupted [`Self::compact`]
    /// (the coalesced container published before its constituents were
    /// removed) and is skipped; *partial* overlap still means the
    /// directory holds records from incompatible runs — an error, not a
    /// guess.
    pub fn manifest(&self) -> Result<Vec<TaskManifest>> {
        let mut records = Vec::new();
        for entry in std::fs::read_dir(self.dir.join(TASKS_DIR))? {
            let path = entry?.path();
            let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
            let Some(name) = name else { continue };
            if name.starts_with('.') || !name.ends_with(".json") {
                continue; // temp litter from a crash mid-publish
            }
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading manifest record {path:?}"))?;
            let v = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("corrupt manifest record {path:?}: {e}"))?;
            records.push(TaskManifest::from_json(&v)?);
        }
        // Widest-first within a start row, so a coalesced container is
        // kept and its constituents are recognized as contained.
        records.sort_by_key(|r| (r.start, std::cmp::Reverse(r.end)));
        let mut kept: Vec<TaskManifest> = Vec::new();
        let mut cursor = 0usize;
        for r in records {
            if r.end <= r.start || r.end > self.total_rows {
                bail!(
                    "manifest record [{}, {}) out of bounds for a {}-row stage",
                    r.start,
                    r.end,
                    self.total_rows
                );
            }
            if r.end <= cursor {
                continue; // fully contained in a kept record (compaction leftover)
            }
            if r.start < cursor {
                bail!(
                    "manifest records overlap at row {} (range [{}, {})); \
                     the checkpoint directory mixes incompatible runs",
                    r.start,
                    r.start,
                    r.end
                );
            }
            cursor = r.end;
            kept.push(r);
        }
        Ok(kept)
    }

    /// Coalesce adjacent manifest records into one record + one data file
    /// per contiguous span (ROADMAP "checkpoint GC / compaction"): a
    /// resumed-many-times run accumulates one record per task, and a
    /// completed stage compacts down to a single record.
    ///
    /// Crash-safe at every step, with no re-pay window: the coalesced
    /// data file is written first, then the coalesced record is published
    /// — from that instant the constituents are *contained* records,
    /// which [`Self::manifest`] skips — and only then are the
    /// constituents and their data files removed. An interruption leaves
    /// either the original records or a valid container + ignorable
    /// litter, which the next `compact` sweeps. Spans whose data files
    /// are missing or unhealthy are left untouched (restore would skip
    /// them anyway, so compacting them would launder corruption).
    ///
    /// Returns `(records_before, records_after, coalesced_runs)`.
    pub fn compact(&self) -> Result<(usize, usize, usize)> {
        let records = self.manifest()?;
        let records_before = records.len();
        let mut records_after = 0usize;
        let mut coalesced_runs = 0usize;

        let mut i = 0usize;
        while i < records.len() {
            // Extend the contiguous run starting at record i.
            let mut j = i + 1;
            while j < records.len() && records[j].start == records[j - 1].end {
                j += 1;
            }
            if j - i >= 2 && self.coalesce_run(&records[i..j])? {
                coalesced_runs += 1;
                records_after += 1;
            } else {
                // Single record, or a span left untouched because a
                // constituent's data file was unhealthy.
                records_after += j - i;
            }
            i = j;
        }

        self.sweep_contained()?;
        Ok((records_before, records_after, coalesced_runs))
    }

    /// Coalesce one contiguous run of ≥ 2 records. Returns `false` (and
    /// leaves the run untouched) when any constituent's data file is
    /// unhealthy.
    fn coalesce_run(&self, run: &[TaskManifest]) -> Result<bool> {
        let (start, end) = (run[0].start, run[run.len() - 1].end);
        // 1. Gather + validate every constituent's rows.
        let mut body = String::new();
        for r in run {
            let path = self.dir.join(DATA_DIR).join(&r.rows_file);
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!(
                        "warning: not compacting rows [{start}, {end}): data file {path:?} \
                         unreadable ({e})"
                    );
                    return Ok(false);
                }
            };
            let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
            if lines.len() != r.end - r.start {
                eprintln!(
                    "warning: not compacting rows [{start}, {end}): data file {path:?} holds \
                     {} rows, expected {}",
                    lines.len(),
                    r.end - r.start
                );
                return Ok(false);
            }
            for line in lines {
                body.push_str(line);
                body.push('\n');
            }
        }

        // 2. Publish the coalesced data file, then its manifest record.
        //    From here the constituents are contained records — skipped by
        //    `manifest`, so a crash at any point leaves a valid stage.
        let rows_file = format!("{start:08}-{end:08}.jsonl");
        fsx::write_atomic(&self.dir.join(DATA_DIR).join(&rows_file), body.as_bytes())?;
        let record = TaskManifest {
            start,
            end,
            attempt: 1,
            executor_id: 0,
            rows_file,
            recorded_at: crate::util::unix_ts(),
        };
        fsx::write_atomic(
            &self.dir.join(TASKS_DIR).join(format!("{start:08}-{end:08}.json")),
            record.to_json().to_pretty().as_bytes(),
        )?;

        // 3. Remove the constituents (records first, then data files).
        for r in run {
            let _ = std::fs::remove_file(
                self.dir.join(TASKS_DIR).join(format!("{:08}-{:08}.json", r.start, r.end)),
            );
        }
        for r in run {
            if r.rows_file != format!("{start:08}-{end:08}.jsonl") {
                let _ = std::fs::remove_file(self.dir.join(DATA_DIR).join(&r.rows_file));
            }
        }
        Ok(true)
    }

    /// Remove record files fully contained in a kept record (litter from
    /// an interrupted compaction), along with their data files.
    fn sweep_contained(&self) -> Result<()> {
        let kept = self.manifest()?;
        let kept_spans: Vec<(usize, usize, &str)> =
            kept.iter().map(|r| (r.start, r.end, r.rows_file.as_str())).collect();
        for entry in std::fs::read_dir(self.dir.join(TASKS_DIR))? {
            let path = entry?.path();
            let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
            let Some(name) = name else { continue };
            if name.starts_with('.') || !name.ends_with(".json") {
                continue;
            }
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            let Ok(v) = Json::parse(&text) else { continue };
            let Ok(r) = TaskManifest::from_json(&v) else { continue };
            let contained = kept_spans.iter().any(|&(s, e, file)| {
                s <= r.start && r.end <= e && (s, e) != (r.start, r.end) && file != r.rows_file
            });
            if contained {
                let _ = std::fs::remove_file(&path);
                let _ = std::fs::remove_file(self.dir.join(DATA_DIR).join(&r.rows_file));
            }
        }
        Ok(())
    }

    /// Record the row ranges deliberately skipped by adaptive early
    /// stopping (the run's `rows_saved`), so `--resume` and `rescore`
    /// can tell "saved on purpose" from "missing". Overwrites atomically
    /// — the settled boundary is a deterministic function of the config
    /// and the evaluated prefix, so a resumed run rewrites identical
    /// content.
    pub fn record_skipped(&self, ranges: &[(usize, usize)]) -> Result<()> {
        for &(start, end) in ranges {
            if start >= end || end > self.total_rows {
                bail!(
                    "skipped range [{start}, {end}) out of bounds for a {}-row stage",
                    self.total_rows
                );
            }
        }
        let items: Vec<Json> = ranges
            .iter()
            .map(|&(s, e)| Json::arr(vec![Json::num(s as f64), Json::num(e as f64)]))
            .collect();
        let doc = Json::obj(vec![("skipped", Json::arr(items))]);
        fsx::write_atomic(&self.dir.join("skipped.json"), doc.to_pretty().as_bytes())
    }

    /// The deliberately-skipped ranges recorded by
    /// [`Self::record_skipped`]; empty when the stage ran (or is still
    /// running) to completion.
    pub fn skipped(&self) -> Result<Vec<(usize, usize)>> {
        let path = self.dir.join("skipped.json");
        if !path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading skipped manifest {path:?}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("corrupt skipped manifest {path:?}: {e}"))?;
        let mut out = Vec::new();
        for item in doc.get("skipped")?.as_arr()? {
            let pair = item.as_arr()?;
            if pair.len() != 2 {
                bail!("corrupt skipped manifest {path:?}: range is not a [start, end] pair");
            }
            out.push((pair[0].as_usize()?, pair[1].as_usize()?));
        }
        Ok(out)
    }

    /// Fraction of the stage's rows already covered by the manifest.
    pub fn coverage(&self) -> Result<f64> {
        if self.total_rows == 0 {
            return Ok(1.0);
        }
        let covered: usize = self.manifest()?.iter().map(|r| r.end - r.start).sum();
        Ok(covered as f64 / self.total_rows as f64)
    }

    /// Restore completed ranges, decoding each spilled row with `decode`.
    /// Records whose data file is missing, truncated, or undecodable are
    /// skipped with a warning — those ranges simply re-execute.
    pub fn restore<T>(
        &self,
        decode: &dyn Fn(&Json) -> Result<T>,
    ) -> Result<Vec<(usize, usize, Vec<T>)>> {
        let mut restored = Vec::new();
        'records: for record in self.manifest()? {
            let path = self.dir.join(DATA_DIR).join(&record.rows_file);
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!(
                        "warning: checkpoint data file {path:?} unreadable ({e}); \
                         re-executing rows [{}, {})",
                        record.start, record.end
                    );
                    continue;
                }
            };
            let mut rows = Vec::with_capacity(record.end - record.start);
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let parsed = Json::parse(line)
                    .map_err(anyhow::Error::msg)
                    .and_then(|v| decode(&v));
                match parsed {
                    Ok(row) => rows.push(row),
                    Err(e) => {
                        eprintln!(
                            "warning: corrupt checkpoint row in {path:?} ({e:#}); \
                             re-executing rows [{}, {})",
                            record.start, record.end
                        );
                        continue 'records;
                    }
                }
            }
            if rows.len() != record.end - record.start {
                eprintln!(
                    "warning: checkpoint data file {path:?} holds {} rows, expected {}; \
                     re-executing rows [{}, {})",
                    rows.len(),
                    record.end - record.start,
                    record.start,
                    record.end
                );
                continue;
            }
            restored.push((record.start, record.end, rows));
        }
        Ok(restored)
    }
}

/// Hash helper for stage fingerprints: SHA-256 over length-prefixed parts,
/// so concatenation ambiguity cannot alias two different input sets.
pub fn fingerprint_sha256<S: AsRef<str>>(parts: impl IntoIterator<Item = S>) -> String {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    for part in parts {
        let bytes = part.as_ref().as_bytes();
        h.update((bytes.len() as u64).to_le_bytes());
        h.update(bytes);
    }
    format!("{:x}", h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-ckpt-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn enc(v: f64) -> String {
        Json::obj(vec![("v", Json::num(v))]).to_string()
    }

    fn dec(j: &Json) -> Result<f64> {
        Ok(j.get("v")?.as_f64()?)
    }

    #[test]
    fn record_and_restore_round_trip() {
        let run = RunCheckpoint::create(&tmp_dir("roundtrip")).unwrap();
        let fp = Json::obj(vec![("sha", Json::str("abc"))]);
        let stage = run.stage("infer-abc", &fp, 10).unwrap();
        stage.record_task(0, 4, 1, 0, &[enc(0.0), enc(1.0), enc(2.0), enc(3.0)]).unwrap();
        stage.record_task(7, 10, 2, 3, &[enc(7.0), enc(8.0), enc(9.0)]).unwrap();

        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 2);
        assert_eq!((manifest[0].start, manifest[0].end), (0, 4));
        assert_eq!(manifest[1].attempt, 2);
        assert!((stage.coverage().unwrap() - 0.7).abs() < 1e-12);

        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(restored[0].2, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(restored[1].2, vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn stage_introspection_surfaces_fingerprint_and_rows() {
        let dir = tmp_dir("introspect");
        let run = RunCheckpoint::create(&dir).unwrap();
        let fp = Json::obj(vec![("kind", Json::str("infer")), ("sha256", Json::str("feedbeef"))]);
        let stage = run.stage("infer-feedbeef", &fp, 8).unwrap();
        stage.record_task(0, 5, 1, 0, &[enc(0.0), enc(1.0), enc(2.0), enc(3.0), enc(4.0)])
            .unwrap();

        // Reopen via the run-level listing, as `slleval checkpoint ls`
        // does, and check every printed field is reachable.
        let reopened = RunCheckpoint::resume(&dir).unwrap();
        let stages = reopened.stages().unwrap();
        assert_eq!(stages.len(), 1);
        let (name, stage) = &stages[0];
        assert_eq!(name, "infer-feedbeef");
        assert_eq!(stage.total_rows(), 8);
        let fingerprint = stage.fingerprint().unwrap();
        assert_eq!(fingerprint.str_or("kind", "?"), "infer");
        assert_eq!(fingerprint.str_or("sha256", "?"), "feedbeef");
        let manifest = stage.manifest().unwrap();
        let spilled: usize = manifest.iter().map(|r| r.end - r.start).sum();
        assert_eq!(spilled, 5);
    }

    #[test]
    fn duplicate_range_record_is_benign_first_wins() {
        let run = RunCheckpoint::create(&tmp_dir("dup")).unwrap();
        let stage = run.stage("s", &Json::Null, 4).unwrap();
        stage.record_task(0, 2, 1, 0, &[enc(1.0), enc(2.0)]).unwrap();
        // A speculative twin finishing later re-records the same range.
        stage.record_task(0, 2, 1, 1, &[enc(1.0), enc(2.0)]).unwrap();
        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 1);
        assert_eq!(manifest[0].executor_id, 0, "first record wins");
    }

    #[test]
    fn truncated_data_file_is_skipped() {
        let run = RunCheckpoint::create(&tmp_dir("truncated")).unwrap();
        let stage = run.stage("s", &Json::Null, 6).unwrap();
        stage.record_task(0, 3, 1, 0, &[enc(0.0), enc(1.0), enc(2.0)]).unwrap();
        stage.record_task(3, 6, 1, 0, &[enc(3.0), enc(4.0), enc(5.0)]).unwrap();
        // Corrupt the second data file (simulated torn write).
        std::fs::write(stage.dir().join("data").join("00000003-00000006.jsonl"), "{\"v\":3}\n")
            .unwrap();
        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].0, 0);
    }

    #[test]
    fn fingerprint_mismatch_refuses_stage() {
        let dir = tmp_dir("fp");
        let run = RunCheckpoint::create(&dir).unwrap();
        run.stage("s", &Json::str("inputs-v1"), 5).unwrap();
        let reopened = RunCheckpoint::resume(&dir).unwrap();
        assert!(reopened.stage("s", &Json::str("inputs-v2"), 5).is_err());
        assert!(reopened.stage("s", &Json::str("inputs-v1"), 5).is_ok());
    }

    #[test]
    fn create_refuses_occupied_dir_resume_accepts() {
        let dir = tmp_dir("occupied");
        {
            let run = RunCheckpoint::create(&dir).unwrap();
            run.stage("s", &Json::Null, 3).unwrap();
        }
        assert!(RunCheckpoint::create(&dir).is_err());
        let resumed = RunCheckpoint::resume(&dir).unwrap();
        assert!(resumed.is_resume());
        assert!(RunCheckpoint::resume(&tmp_dir("missing")).is_err());
    }

    #[test]
    fn overlapping_records_error() {
        let run = RunCheckpoint::create(&tmp_dir("overlap")).unwrap();
        let stage = run.stage("s", &Json::Null, 10).unwrap();
        stage.record_task(0, 5, 1, 0, &(0..5).map(|i| enc(i as f64)).collect::<Vec<_>>()).unwrap();
        stage.record_task(3, 8, 1, 0, &(3..8).map(|i| enc(i as f64)).collect::<Vec<_>>()).unwrap();
        assert!(stage.manifest().is_err());
    }

    #[test]
    fn compact_coalesces_adjacent_records_and_restores_identically() {
        let run = RunCheckpoint::create(&tmp_dir("compact")).unwrap();
        let stage = run.stage("s", &Json::Null, 12).unwrap();
        // Three adjacent records [0,6), a gap at [6,7), and a tail [7,12).
        stage.record_task(0, 2, 1, 0, &[enc(0.0), enc(1.0)]).unwrap();
        stage.record_task(2, 4, 1, 1, &[enc(2.0), enc(3.0)]).unwrap();
        stage.record_task(4, 6, 2, 0, &[enc(4.0), enc(5.0)]).unwrap();
        let tail: Vec<String> = (7..12).map(|i| enc(i as f64)).collect();
        stage.record_task(7, 12, 1, 2, &tail).unwrap();
        let before = stage.restore(&dec).unwrap();

        let (records_before, records_after, runs) = stage.compact().unwrap();
        assert_eq!((records_before, records_after, runs), (4, 2, 1));
        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 2);
        assert_eq!((manifest[0].start, manifest[0].end), (0, 6));
        assert_eq!((manifest[1].start, manifest[1].end), (7, 12));

        // Restore reads the compacted stage identically: same covered
        // rows, same values, in range order.
        let after = stage.restore(&dec).unwrap();
        let rows_of = |r: &[(usize, usize, Vec<f64>)]| {
            r.iter().flat_map(|(_, _, rows)| rows.clone()).collect::<Vec<f64>>()
        };
        assert_eq!(rows_of(&before), rows_of(&after));
        assert_eq!(stage.coverage().unwrap(), 11.0 / 12.0);

        // Old data files are gone; exactly one file per kept record.
        let data_files = std::fs::read_dir(stage.dir().join("data")).unwrap().count();
        assert_eq!(data_files, 2);

        // Compacting again is a no-op.
        assert_eq!(stage.compact().unwrap(), (2, 2, 0));
    }

    #[test]
    fn interrupted_compaction_leftovers_are_skipped_and_swept() {
        let run = RunCheckpoint::create(&tmp_dir("compact-interrupt")).unwrap();
        let stage = run.stage("s", &Json::Null, 6).unwrap();
        stage.record_task(0, 3, 1, 0, &[enc(0.0), enc(1.0), enc(2.0)]).unwrap();
        stage.record_task(3, 6, 1, 1, &[enc(3.0), enc(4.0), enc(5.0)]).unwrap();
        // Simulate a compaction that crashed right after publishing the
        // container: write the coalesced record + data file by hand while
        // the constituents are still present.
        let body = (0..6).map(|i| enc(i as f64) + "\n").collect::<String>();
        std::fs::write(stage.dir().join("data").join("00000000-00000006.jsonl"), body).unwrap();
        let container = TaskManifest {
            start: 0,
            end: 6,
            attempt: 1,
            executor_id: 0,
            rows_file: "00000000-00000006.jsonl".into(),
            recorded_at: 0.0,
        };
        std::fs::write(
            stage.dir().join("tasks").join("00000000-00000006.json"),
            container.to_json().to_pretty(),
        )
        .unwrap();

        // The contained constituents are benign: manifest keeps only the
        // container and restore sees every row exactly once.
        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 1);
        assert_eq!((manifest[0].start, manifest[0].end), (0, 6));
        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].2, (0..6).map(|i| i as f64).collect::<Vec<_>>());

        // The next compact sweeps the litter.
        stage.compact().unwrap();
        let records = std::fs::read_dir(stage.dir().join("tasks")).unwrap().count();
        assert_eq!(records, 1, "constituent records must be swept");
        let data_files = std::fs::read_dir(stage.dir().join("data")).unwrap().count();
        assert_eq!(data_files, 1, "constituent data files must be swept");
    }

    #[test]
    fn run_compact_covers_all_stages() {
        let dir = tmp_dir("compact-run");
        {
            let run = RunCheckpoint::create(&dir).unwrap();
            let s1 = run.stage("infer-aaaa", &Json::str("a"), 4).unwrap();
            s1.record_task(0, 2, 1, 0, &[enc(0.0), enc(1.0)]).unwrap();
            s1.record_task(2, 4, 1, 0, &[enc(2.0), enc(3.0)]).unwrap();
            let s2 = run.stage("judge-bbbb", &Json::str("b"), 3).unwrap();
            s2.record_task(0, 3, 1, 0, &[enc(0.0), enc(1.0), enc(2.0)]).unwrap();
        }
        let run = RunCheckpoint::resume(&dir).unwrap();
        let report = run.compact().unwrap();
        assert_eq!(report.len(), 2);
        let infer = report.iter().find(|s| s.stage == "infer-aaaa").unwrap();
        assert_eq!((infer.records_before, infer.records_after), (2, 1));
        let judge = report.iter().find(|s| s.stage == "judge-bbbb").unwrap();
        assert_eq!((judge.records_before, judge.records_after), (1, 1));
        assert_eq!(judge.coalesced_runs, 0);

        // Restore through the normal resume path still works.
        let stage = run.stage("infer-aaaa", &Json::str("a"), 4).unwrap();
        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].2, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn skipped_ranges_round_trip_and_default_empty() {
        let run = RunCheckpoint::create(&tmp_dir("skipped")).unwrap();
        let stage = run.stage("s", &Json::Null, 100).unwrap();
        assert!(stage.skipped().unwrap().is_empty(), "no manifest means nothing skipped");
        stage.record_skipped(&[(40, 100)]).unwrap();
        assert_eq!(stage.skipped().unwrap(), vec![(40, 100)]);
        // A resumed run replays the same deterministic stop decision and
        // rewrites identical content — benign.
        stage.record_skipped(&[(40, 100)]).unwrap();
        assert_eq!(stage.skipped().unwrap(), vec![(40, 100)]);
        assert!(stage.record_skipped(&[(90, 101)]).is_err(), "out of bounds");
        assert!(stage.record_skipped(&[(50, 50)]).is_err(), "empty range");
    }

    #[test]
    fn fingerprint_hash_is_length_prefixed() {
        assert_ne!(
            fingerprint_sha256(["ab", "c"]),
            fingerprint_sha256(["a", "bc"]),
            "length prefixing must disambiguate concatenation"
        );
        assert_eq!(fingerprint_sha256(["x", "y"]), fingerprint_sha256(["x", "y"]));
    }
}
