//! Run checkpointing: crash-safe persistence of completed scheduler tasks
//! so an interrupted evaluation can resume without repaying API cost.
//!
//! The paper's cost argument (§3.2) is that cached responses make metric
//! iteration free — but a crashed *first* run still used to throw away
//! every completed task. This module makes paid work durable at task
//! granularity: as the scheduler finishes a task, its row results are
//! spilled to a run directory together with a manifest record, both
//! published with the same atomic first-writer-wins discipline as the
//! deltalite transaction log ([`crate::util::fsx`]).
//!
//! Layout (one run directory, one subdirectory per checkpointed stage):
//!
//! ```text
//! <run_dir>/
//!   <stage>/meta.json                      fingerprint binding the stage
//!                                          to its exact inputs
//!   <stage>/tasks/<start>-<end>.json       manifest record per completed
//!                                          task range (exclusive publish)
//!   <stage>/data/<start>-<end>.jsonl       row results, one JSON per row
//! ```
//!
//! Stages are content-addressed: the stage name embeds a hash of the exact
//! inputs (prompts, model, sampling parameters), so a resumed run restores
//! a stage only when its inputs are byte-identical — streaming chunks,
//! pairwise A/B inference, and judge passes all get distinct stages for
//! free, and resuming against a changed dataset silently (and correctly)
//! re-executes instead of stitching mismatched rows.
//!
//! Crash-safety protocol per completed task:
//!
//! 1. write the row data file atomically (temp + rename);
//! 2. publish the manifest record pointing at it with an exclusive claim.
//!
//! A crash between the steps leaves an unreferenced data file — garbage,
//! never a dangling pointer. A crash mid-write leaves only hidden temp
//! files, which loading ignores. Records whose data file is missing or has
//! the wrong row count are skipped on restore (that range simply
//! re-executes).

use crate::util::fsx::{self, Publish};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

const TASKS_DIR: &str = "tasks";
const DATA_DIR: &str = "data";

/// One completed-task record in a stage manifest.
#[derive(Debug, Clone)]
pub struct TaskManifest {
    /// Row range covered by the spilled results (post-split, exact).
    pub start: usize,
    pub end: usize,
    /// Attempt number that won the task.
    pub attempt: usize,
    /// Executor that produced the winning attempt.
    pub executor_id: usize,
    /// Data file (relative to the stage's `data/` directory).
    pub rows_file: String,
    /// Unix timestamp of the checkpoint write.
    pub recorded_at: f64,
}

impl TaskManifest {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("start", Json::num(self.start as f64)),
            ("end", Json::num(self.end as f64)),
            ("status", Json::str("done")),
            ("attempt", Json::num(self.attempt as f64)),
            ("executor_id", Json::num(self.executor_id as f64)),
            ("rows_file", Json::str(&self.rows_file)),
            ("recorded_at", Json::num(self.recorded_at)),
        ])
    }

    fn from_json(v: &Json) -> Result<TaskManifest> {
        Ok(TaskManifest {
            start: v.get("start")?.as_usize()?,
            end: v.get("end")?.as_usize()?,
            attempt: v.usize_or("attempt", 1),
            executor_id: v.usize_or("executor_id", 0),
            rows_file: v.get("rows_file")?.as_str()?.to_string(),
            recorded_at: v.f64_or("recorded_at", 0.0),
        })
    }
}

/// Handle on a run directory holding per-stage checkpoints.
pub struct RunCheckpoint {
    root: PathBuf,
    resume: bool,
}

impl RunCheckpoint {
    /// Start a fresh run directory. Refuses a non-empty existing directory:
    /// continuing one requires the explicit `--resume` intent (otherwise a
    /// stale manifest could silently shadow freshly computed results).
    pub fn create(root: &Path) -> Result<RunCheckpoint> {
        if root.exists() {
            let occupied = std::fs::read_dir(root)
                .with_context(|| format!("inspecting checkpoint dir {root:?}"))?
                .next()
                .is_some();
            if occupied {
                bail!(
                    "checkpoint directory {root:?} already holds a run; \
                     resume it with --resume or choose a fresh directory"
                );
            }
        }
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating checkpoint dir {root:?}"))?;
        Ok(RunCheckpoint { root: root.to_path_buf(), resume: false })
    }

    /// Reopen an interrupted run's directory for resumption.
    pub fn resume(root: &Path) -> Result<RunCheckpoint> {
        if !root.is_dir() {
            bail!("cannot resume: checkpoint directory {root:?} does not exist");
        }
        Ok(RunCheckpoint { root: root.to_path_buf(), resume: true })
    }

    pub fn is_resume(&self) -> bool {
        self.resume
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Open (creating on first use) one stage's checkpoint store.
    /// `fingerprint` binds the stage to its exact inputs; reopening an
    /// existing stage with a different fingerprint is an error rather than
    /// a silent mix of incompatible results.
    pub fn stage(
        &self,
        name: &str,
        fingerprint: &Json,
        total_rows: usize,
    ) -> Result<StageCheckpoint> {
        let dir = self.root.join(name);
        std::fs::create_dir_all(dir.join(TASKS_DIR))?;
        std::fs::create_dir_all(dir.join(DATA_DIR))?;
        let meta = Json::obj(vec![
            ("fingerprint", fingerprint.clone()),
            ("total_rows", Json::num(total_rows as f64)),
        ]);
        let meta_path = dir.join("meta.json");
        if meta_path.exists() {
            let existing = Json::parse(&std::fs::read_to_string(&meta_path)?)
                .map_err(|e| anyhow::anyhow!("corrupt stage meta {meta_path:?}: {e}"))?;
            if existing != meta {
                bail!(
                    "checkpoint stage '{name}' in {:?} was written with different \
                     inputs (fingerprint mismatch); refusing to mix runs",
                    self.root
                );
            }
        } else {
            fsx::write_atomic(&meta_path, meta.to_pretty().as_bytes())?;
        }
        Ok(StageCheckpoint { dir, total_rows })
    }
}

/// Checkpoint store for one scheduler stage.
pub struct StageCheckpoint {
    dir: PathBuf,
    total_rows: usize,
}

impl StageCheckpoint {
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Crash-safely record one completed task: `lines` are the task's rows
    /// already encoded as single-line JSON. Racing twins of the same range
    /// are benign — the first record published wins and later ones are
    /// dropped (their rows are identical task outputs).
    pub fn record_task(
        &self,
        start: usize,
        end: usize,
        attempt: usize,
        executor_id: usize,
        lines: &[String],
    ) -> Result<()> {
        if lines.len() != end - start {
            bail!(
                "checkpoint record for [{start}, {end}) has {} rows, expected {}",
                lines.len(),
                end - start
            );
        }
        let manifest_path = self.dir.join(TASKS_DIR).join(format!("{start:08}-{end:08}.json"));
        let rows_file = format!("{start:08}-{end:08}.jsonl");
        if manifest_path.exists() {
            // Already recorded (a re-run of the same stage, or a resume
            // re-executing a range whose spill was lost). Skip only when
            // the spilled data is actually healthy — right row count and
            // every row parseable, mirroring what `restore` will demand —
            // otherwise fall through and repair it, or the range would be
            // re-paid on every future resume.
            let healthy = std::fs::read_to_string(self.dir.join(DATA_DIR).join(&rows_file))
                .map(|t| {
                    let lines: Vec<&str> =
                        t.lines().filter(|l| !l.trim().is_empty()).collect();
                    lines.len() == end - start
                        && lines.iter().all(|l| Json::parse(l).is_ok())
                })
                .unwrap_or(false);
            if healthy {
                return Ok(());
            }
        }
        let mut body = String::new();
        for line in lines {
            body.push_str(line);
            body.push('\n');
        }
        // Data first, then the manifest pointer: a crash in between leaves
        // an unreferenced data file, never a pointer to missing data.
        fsx::write_atomic(&self.dir.join(DATA_DIR).join(&rows_file), body.as_bytes())?;
        let record = TaskManifest {
            start,
            end,
            attempt,
            executor_id,
            rows_file,
            recorded_at: crate::util::unix_ts(),
        };
        // `Conflict` means a racing writer already recorded this range —
        // benign (its rows are the same task's output).
        let _: Publish =
            fsx::publish_exclusive(&manifest_path, record.to_json().to_pretty().as_bytes())?;
        Ok(())
    }

    /// Load and validate the manifest: records sorted by range start,
    /// ranges strictly disjoint and in-bounds. Overlap means the directory
    /// holds records from incompatible runs — an error, not a guess.
    pub fn manifest(&self) -> Result<Vec<TaskManifest>> {
        let mut records = Vec::new();
        for entry in std::fs::read_dir(self.dir.join(TASKS_DIR))? {
            let path = entry?.path();
            let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
            let Some(name) = name else { continue };
            if name.starts_with('.') || !name.ends_with(".json") {
                continue; // temp litter from a crash mid-publish
            }
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading manifest record {path:?}"))?;
            let v = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("corrupt manifest record {path:?}: {e}"))?;
            records.push(TaskManifest::from_json(&v)?);
        }
        records.sort_by_key(|r| (r.start, r.end));
        let mut cursor = 0usize;
        for r in &records {
            if r.end <= r.start || r.end > self.total_rows {
                bail!(
                    "manifest record [{}, {}) out of bounds for a {}-row stage",
                    r.start,
                    r.end,
                    self.total_rows
                );
            }
            if r.start < cursor {
                bail!(
                    "manifest records overlap at row {} (range [{}, {})); \
                     the checkpoint directory mixes incompatible runs",
                    r.start,
                    r.start,
                    r.end
                );
            }
            cursor = r.end;
        }
        Ok(records)
    }

    /// Fraction of the stage's rows already covered by the manifest.
    pub fn coverage(&self) -> Result<f64> {
        if self.total_rows == 0 {
            return Ok(1.0);
        }
        let covered: usize = self.manifest()?.iter().map(|r| r.end - r.start).sum();
        Ok(covered as f64 / self.total_rows as f64)
    }

    /// Restore completed ranges, decoding each spilled row with `decode`.
    /// Records whose data file is missing, truncated, or undecodable are
    /// skipped with a warning — those ranges simply re-execute.
    pub fn restore<T>(
        &self,
        decode: &dyn Fn(&Json) -> Result<T>,
    ) -> Result<Vec<(usize, usize, Vec<T>)>> {
        let mut restored = Vec::new();
        'records: for record in self.manifest()? {
            let path = self.dir.join(DATA_DIR).join(&record.rows_file);
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!(
                        "warning: checkpoint data file {path:?} unreadable ({e}); \
                         re-executing rows [{}, {})",
                        record.start, record.end
                    );
                    continue;
                }
            };
            let mut rows = Vec::with_capacity(record.end - record.start);
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let parsed = Json::parse(line)
                    .map_err(anyhow::Error::msg)
                    .and_then(|v| decode(&v));
                match parsed {
                    Ok(row) => rows.push(row),
                    Err(e) => {
                        eprintln!(
                            "warning: corrupt checkpoint row in {path:?} ({e:#}); \
                             re-executing rows [{}, {})",
                            record.start, record.end
                        );
                        continue 'records;
                    }
                }
            }
            if rows.len() != record.end - record.start {
                eprintln!(
                    "warning: checkpoint data file {path:?} holds {} rows, expected {}; \
                     re-executing rows [{}, {})",
                    rows.len(),
                    record.end - record.start,
                    record.start,
                    record.end
                );
                continue;
            }
            restored.push((record.start, record.end, rows));
        }
        Ok(restored)
    }
}

/// Hash helper for stage fingerprints: SHA-256 over length-prefixed parts,
/// so concatenation ambiguity cannot alias two different input sets.
pub fn fingerprint_sha256<S: AsRef<str>>(parts: impl IntoIterator<Item = S>) -> String {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    for part in parts {
        let bytes = part.as_ref().as_bytes();
        h.update((bytes.len() as u64).to_le_bytes());
        h.update(bytes);
    }
    format!("{:x}", h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-ckpt-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn enc(v: f64) -> String {
        Json::obj(vec![("v", Json::num(v))]).to_string()
    }

    fn dec(j: &Json) -> Result<f64> {
        Ok(j.get("v")?.as_f64()?)
    }

    #[test]
    fn record_and_restore_round_trip() {
        let run = RunCheckpoint::create(&tmp_dir("roundtrip")).unwrap();
        let fp = Json::obj(vec![("sha", Json::str("abc"))]);
        let stage = run.stage("infer-abc", &fp, 10).unwrap();
        stage.record_task(0, 4, 1, 0, &[enc(0.0), enc(1.0), enc(2.0), enc(3.0)]).unwrap();
        stage.record_task(7, 10, 2, 3, &[enc(7.0), enc(8.0), enc(9.0)]).unwrap();

        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 2);
        assert_eq!((manifest[0].start, manifest[0].end), (0, 4));
        assert_eq!(manifest[1].attempt, 2);
        assert!((stage.coverage().unwrap() - 0.7).abs() < 1e-12);

        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(restored[0].2, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(restored[1].2, vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn duplicate_range_record_is_benign_first_wins() {
        let run = RunCheckpoint::create(&tmp_dir("dup")).unwrap();
        let stage = run.stage("s", &Json::Null, 4).unwrap();
        stage.record_task(0, 2, 1, 0, &[enc(1.0), enc(2.0)]).unwrap();
        // A speculative twin finishing later re-records the same range.
        stage.record_task(0, 2, 1, 1, &[enc(1.0), enc(2.0)]).unwrap();
        let manifest = stage.manifest().unwrap();
        assert_eq!(manifest.len(), 1);
        assert_eq!(manifest[0].executor_id, 0, "first record wins");
    }

    #[test]
    fn truncated_data_file_is_skipped() {
        let run = RunCheckpoint::create(&tmp_dir("truncated")).unwrap();
        let stage = run.stage("s", &Json::Null, 6).unwrap();
        stage.record_task(0, 3, 1, 0, &[enc(0.0), enc(1.0), enc(2.0)]).unwrap();
        stage.record_task(3, 6, 1, 0, &[enc(3.0), enc(4.0), enc(5.0)]).unwrap();
        // Corrupt the second data file (simulated torn write).
        std::fs::write(stage.dir().join("data").join("00000003-00000006.jsonl"), "{\"v\":3}\n")
            .unwrap();
        let restored = stage.restore(&dec).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].0, 0);
    }

    #[test]
    fn fingerprint_mismatch_refuses_stage() {
        let dir = tmp_dir("fp");
        let run = RunCheckpoint::create(&dir).unwrap();
        run.stage("s", &Json::str("inputs-v1"), 5).unwrap();
        let reopened = RunCheckpoint::resume(&dir).unwrap();
        assert!(reopened.stage("s", &Json::str("inputs-v2"), 5).is_err());
        assert!(reopened.stage("s", &Json::str("inputs-v1"), 5).is_ok());
    }

    #[test]
    fn create_refuses_occupied_dir_resume_accepts() {
        let dir = tmp_dir("occupied");
        {
            let run = RunCheckpoint::create(&dir).unwrap();
            run.stage("s", &Json::Null, 3).unwrap();
        }
        assert!(RunCheckpoint::create(&dir).is_err());
        let resumed = RunCheckpoint::resume(&dir).unwrap();
        assert!(resumed.is_resume());
        assert!(RunCheckpoint::resume(&tmp_dir("missing")).is_err());
    }

    #[test]
    fn overlapping_records_error() {
        let run = RunCheckpoint::create(&tmp_dir("overlap")).unwrap();
        let stage = run.stage("s", &Json::Null, 10).unwrap();
        stage.record_task(0, 5, 1, 0, &(0..5).map(|i| enc(i as f64)).collect::<Vec<_>>()).unwrap();
        stage.record_task(3, 8, 1, 0, &(3..8).map(|i| enc(i as f64)).collect::<Vec<_>>()).unwrap();
        assert!(stage.manifest().is_err());
    }

    #[test]
    fn fingerprint_hash_is_length_prefixed() {
        assert_ne!(
            fingerprint_sha256(["ab", "c"]),
            fingerprint_sha256(["a", "bc"]),
            "length prefixing must disambiguate concatenation"
        );
        assert_eq!(fingerprint_sha256(["x", "y"]), fingerprint_sha256(["x", "y"]));
    }
}
