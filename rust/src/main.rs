//! `slleval` — the Spark-LLM-Eval launcher.
//!
//! ```text
//! slleval generate  --n 10000 --seed 42 --out data.jsonl
//! slleval run       --config task.json [--data data.jsonl | --n 1000]
//!                   [--cache-dir .slleval-cache] [--track runs/] [--fast]
//!                   [--checkpoint run_dir | --resume run_dir] [--concurrency 8]
//!                   [--backend thread|process|remote] [--hosts host1:7433,host2:7433]
//! slleval compare   --config task.json --model-b gpt-4o-mini [--provider-b openai]
//!                   [--checkpoint run_dir | --resume run_dir]
//! slleval replay    --config task.json --cache-dir .slleval-cache
//! slleval rescore   --config task.json [--cache-dir .slleval-cache]
//!                   [--checkpoint run_dir] [--allow-missing] [--out result.json]
//! slleval tables    [--table fig2|tab3|tab4|tab5|tab6|typei|all]
//! slleval sim       --executors 8 --n 10000 [--rpm 10000]
//! slleval checkpoint compact <run_dir>
//! slleval checkpoint ls <run_dir>
//! slleval cache ls <dir> [--json] [--keys]
//! slleval cache optimize <dir> [--target-bytes N]
//! slleval cache vacuum <dir> [--dry-run] [--retain-hours N]
//! slleval lint      [--baseline lint-baseline.json] [--json]
//! slleval serve     --listen 127.0.0.1:7464 [--config serve.json]
//!                   [--cache-dir .slleval-cache] [--fast]
//!                   [--max-body-bytes N] [--latency-scale F]
//! slleval serve-worker --listen 0.0.0.0:7433 [--max-workers 8]
//! ```
//!
//! `serve` starts the resident eval service (see `crate::serve` and
//! DESIGN.md "Eval service"): submit EvalTask JSON with
//! `POST /runs`, watch `GET /runs/{id}` / `GET /runs/{id}/partial`,
//! fetch `GET /runs/{id}/result`, cancel with `POST /runs/{id}/cancel`.
//! All runs share the daemon's response cache and warm executor fleets.
//!
//! `--concurrency N` (or `inference.concurrency` in the task JSON) makes
//! each executor multiplex N in-flight provider requests through the
//! pipelined batch client, overlapping round-trip latency; 1 (default)
//! is the sequential path.
//!
//! `--backend process` (or `executor.backend` in the task JSON) runs
//! each executor as a crash-isolated `slleval worker` child process over
//! a length-prefixed JSON pipe protocol: a killed executor (OOM,
//! segfault, `kill -9`) costs only its in-flight tasks — the driver
//! retries them on the survivors — instead of the whole run. The default
//! `thread` backend is the in-process scheduler, bit for bit.
//!
//! `--backend remote --hosts host1:7433,host2:7433` places executors
//! round-robin on `slleval serve-worker` daemons over TCP (the same
//! frame protocol). A dead host costs only its in-flight tasks: every
//! executor on it is settled at once and the work retried on surviving
//! hosts. Remote workers upload checkpoint spills to the driver, so
//! `--resume` needs no shared filesystem.
//!
//! `--checkpoint <run_dir>` spills every completed scheduler task to
//! `run_dir` crash-safely; after an interruption (crash, Ctrl-C, cost
//! budget), `--resume <run_dir>` reloads the manifest and re-executes only
//! the incomplete ranges — completed work is never re-paid.
//!
//! `rescore` replaces the inference stage with cache/checkpoint lookups:
//! it recomputes any metric set over a previous run's responses with zero
//! inference API calls (the paper's "iterate on metric definitions
//! without re-running inference").

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use spark_llm_eval::config::{CachePolicy, EvalTask, ServeConfig};
use spark_llm_eval::coordinator::{compare_results, EvalRunner};
use spark_llm_eval::data::{io as dio, synth, DataFrame};
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::report;
use spark_llm_eval::report::tables;
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};
use spark_llm_eval::sim::{simulate, SimParams};
use spark_llm_eval::tracking::TrackingStore;
use spark_llm_eval::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(args),
        Some("run") => cmd_run(args),
        Some("compare") => cmd_compare(args),
        Some("replay") => cmd_replay(args),
        Some("rescore") => cmd_rescore(args),
        Some("tables") => cmd_tables(args),
        Some("sim") => cmd_sim(args),
        Some("checkpoint") => cmd_checkpoint(args),
        Some("cache") => cmd_cache(args),
        Some("lint") => cmd_lint(args),
        // Hidden: the process-backend executor entry point. Spawned by
        // the driver with stdin/stdout pipes — never invoked by hand.
        Some("worker") => spark_llm_eval::coordinator::worker_main(),
        // The remote-backend host daemon: accepts executor connections
        // from `--backend remote` drivers.
        Some("serve-worker") => cmd_serve_worker(args),
        // Eval-as-a-service: the resident HTTP driver daemon.
        Some("serve") => cmd_serve(args),
        Some(other) => bail!(
            "unknown subcommand '{other}' (try: generate, run, compare, replay, rescore, tables, sim, checkpoint, cache, lint, serve, serve-worker)"
        ),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("slleval — distributed, statistically rigorous LLM evaluation");
    println!(
        "subcommands: generate | run | compare | replay | rescore | tables | sim | checkpoint \
         | lint | serve | serve-worker"
    );
    println!("  rescore: recompute metrics from a cache/checkpoint, zero inference calls");
    println!("  checkpoint compact <run_dir>: coalesce per-task manifest records per stage");
    println!("  checkpoint ls <run_dir>: list each stage's fingerprint and spilled coverage");
    println!("  cache ls <dir> [--json] [--keys]: inspect a Delta cache table");
    println!("  cache optimize <dir> [--target-bytes N]: range-cluster small data files");
    println!("  cache vacuum <dir> [--dry-run] [--retain-hours N]: reclaim dead data files");
    println!("  lint [--baseline <file>] [--json]: static analysis of this repo's invariants");
    println!(
        "  serve --listen <addr> [--cache-dir d] [--fast]: resident HTTP eval driver \
         (POST /runs, GET /runs/{{id}}, /partial, /result, /cancel)"
    );
    println!(
        "  serve-worker --listen <addr> [--max-workers N]: host daemon for --backend remote"
    );
    println!("see README.md for full usage");
}

/// `slleval lint` — run the project-invariant static analysis pass
/// (determinism, panic-safety, wire-protocol drift, config/doc drift)
/// over this repository's own sources. Exits non-zero on any
/// unsuppressed violation; the same pass gates `cargo test -q` via
/// `tests/lint_gate.rs`.
fn cmd_lint(args: &Args) -> Result<()> {
    use spark_llm_eval::analysis;
    let root = analysis::find_repo_root()?;
    let baseline = args.get("baseline").map(PathBuf::from);
    let out = analysis::run(&root, baseline.as_deref())?;
    if args.has_flag("json") {
        println!("{}", out.to_json().to_pretty());
    } else {
        for d in &out.violations {
            println!("{}", d.render());
        }
        println!(
            "lint: {} violation(s), {} suppressed, {} files scanned",
            out.violations.len(),
            out.suppressed.len(),
            out.files_scanned
        );
    }
    if !out.clean() {
        bail!("lint found {} violation(s)", out.violations.len());
    }
    Ok(())
}

fn load_or_generate_data(args: &Args) -> Result<DataFrame> {
    if let Some(path) = args.get("data") {
        dio::read_jsonl(Path::new(path)).context("loading --data")
    } else {
        let n = args.get_usize("n", 1000);
        let seed = args.get_usize("seed", 42) as u64;
        Ok(synth::generate_default(n, seed))
    }
}

fn load_task(args: &Args) -> Result<EvalTask> {
    let mut task = match args.get("config") {
        Some(path) => EvalTask::from_file(Path::new(path))?,
        None => {
            let mut task = EvalTask::default();
            if let Some(m) = args.get("model") {
                task.model.model_name = m.to_string();
            }
            if let Some(p) = args.get("provider") {
                task.model.provider = p.to_string();
            }
            task.executors = args.get_usize("executors", task.executors);
            task
        }
    };
    // CLI checkpoint flags override the task file: --resume implies the
    // directory holds an interrupted run, --checkpoint starts a fresh one.
    if let Some(dir) = args.get("resume") {
        task.checkpoint.dir = Some(dir.to_string());
        task.checkpoint.resume = true;
    } else if let Some(dir) = args.get("checkpoint") {
        task.checkpoint.dir = Some(dir.to_string());
        task.checkpoint.resume = false;
    }
    // In-executor concurrency: how many provider requests each executor
    // keeps in flight (1 = the sequential pre-pipeline path).
    task.inference.concurrency = args.get_usize("concurrency", task.inference.concurrency);
    // Executor backend: in-process threads (default), crash-isolated
    // `slleval worker` processes, or remote serve-worker hosts.
    if let Some(backend) = args.get("backend") {
        task.backend = spark_llm_eval::config::BackendKind::from_str(backend)?;
    }
    // Remote host list: comma-separated `host:port` serve-worker
    // addresses; executors are placed on them round-robin.
    if let Some(hosts) = args.get("hosts") {
        task.hosts = hosts
            .split(',')
            .map(str::trim)
            .filter(|h| !h.is_empty())
            .map(String::from)
            .collect();
    }
    task.validate()?;
    Ok(task)
}

/// Build a runner: `--fast` uses a virtual clock and skips latency sleeps
/// (simulation mode); otherwise wall-clock with simulated latencies.
fn build_runner(args: &Args, policy: CachePolicy) -> Result<EvalRunner> {
    let mut runner = if args.has_flag("fast") {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
        r
    } else {
        EvalRunner::new()
    };
    if let Some(dir) = args.get("cache-dir") {
        runner.open_cache(Path::new(dir), policy)?;
    }
    // Load the PJRT runtime when artifacts exist (semantic metrics).
    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    if artifacts.join("manifest.json").exists() {
        runner.runtime = Some(SemanticRuntime::load(&artifacts)?);
    }
    Ok(runner)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000);
    let seed = args.get_usize("seed", 42) as u64;
    let out = args.get_or("out", "data.jsonl");
    let df = synth::generate_default(n, seed);
    dio::write_jsonl(&df, Path::new(out))?;
    println!("wrote {n} examples to {out}");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let task = load_task(args)?;
    let df = load_or_generate_data(args)?;
    let mut runner = build_runner(args, task.inference.cache_policy)?;
    if let Some(dir) = &task.checkpoint.dir {
        runner.attach_checkpoint(Path::new(dir), task.checkpoint.resume)?;
        if task.checkpoint.resume {
            println!("resuming interrupted run from {dir}");
        }
    }
    let result = runner.evaluate(&df, &task)?;
    let restored = result.inference.sched.restored_rows;
    if restored > 0 {
        println!(
            "resume: {restored} of {} rows restored from checkpoint (not re-executed)",
            result.inference.examples
        );
    }
    println!("{}", report::eval_summary(&result));

    if let Some(track_dir) = args.get("track") {
        let store = TrackingStore::open(Path::new(track_dir))?;
        let mut run = store.start_run(&task.task_id)?;
        run.log_evaluation(&task, &result)?;
        let run_id = run.run_id.clone();
        run.finish()?;
        println!("tracked as run {run_id} in {track_dir}");
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, result.to_json().to_pretty())?;
        println!("result JSON written to {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let task_a = load_task(args)?;
    let mut task_b = task_a.clone();
    task_b.model.model_name = args
        .get("model-b")
        .context("--model-b is required for compare")?
        .to_string();
    if let Some(p) = args.get("provider-b") {
        task_b.model.provider = p.to_string();
    }
    task_b.task_id = format!("{}-vs-{}", task_a.task_id, task_b.model.model_name);

    let df = load_or_generate_data(args)?;
    let mut runner = build_runner(args, task_a.inference.cache_policy)?;
    if let Some(dir) = &task_a.checkpoint.dir {
        runner.attach_checkpoint(Path::new(dir), task_a.checkpoint.resume)?;
    }
    let ra = runner.evaluate(&df, &task_a)?;
    let rb = runner.evaluate(&df, &task_b)?;
    println!("{}", report::eval_summary(&ra));
    println!("{}", report::eval_summary(&rb));
    let cmp = compare_results(&ra, &rb, &task_a)?;
    println!("{}", report::comparison_summary(&cmp));
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let mut task = load_task(args)?;
    task.inference.cache_policy = CachePolicy::Replay;
    let cache_dir = args.get("cache-dir").context("--cache-dir is required for replay")?;
    let df = load_or_generate_data(args)?;
    let mut runner = build_runner(args, CachePolicy::Replay)?;
    runner.open_cache(Path::new(cache_dir), CachePolicy::Replay)?;
    let result = runner.evaluate(&df, &task)?;
    println!("{}", report::eval_summary(&result));
    // Report the run's actual traffic — judge/RAG metrics can miss the
    // cache under replay (they then score None rather than spending).
    let judge = &result.metric_calls;
    println!(
        "replay complete: {} inference cache hits, {} judge cache hits, {} API calls, ${:.4}",
        result.inference.cache_hits,
        judge.cache_hits,
        result.inference.api_calls + judge.api_calls,
        result.inference.total_cost_usd + judge.cost_usd,
    );
    if judge.failed > 0 {
        println!(
            "warning: {} judge/RAG calls missed the replay cache and scored None \
             (warm them with `slleval run` or `slleval rescore` under an enabled cache)",
            judge.failed
        );
    }
    Ok(())
}

fn cmd_rescore(args: &Args) -> Result<()> {
    let task = load_task(args)?;
    if args.get("cache-dir").is_none() && task.checkpoint.dir.is_none() {
        bail!("rescore needs a response source: --cache-dir and/or --checkpoint <run_dir>");
    }
    let df = load_or_generate_data(args)?;
    // Response rehydration never calls a provider regardless of policy;
    // the policy only governs *metric-stage* judge calls. Keep Replay /
    // ReadOnly as configured (guaranteed-zero-spend rescoring); upgrade
    // non-readable policies so the cache can serve responses at all.
    let policy = match task.inference.cache_policy {
        CachePolicy::Replay => CachePolicy::Replay,
        CachePolicy::ReadOnly => CachePolicy::ReadOnly,
        _ => CachePolicy::Enabled,
    };
    let mut runner = build_runner(args, policy)?;
    // `--checkpoint` here means "read this run directory", so it always
    // attaches in resume mode (rescore never starts a fresh checkpoint).
    if let Some(dir) = &task.checkpoint.dir {
        runner.attach_checkpoint(Path::new(dir), true)?;
    }
    let result = runner.rescore(&df, &task, args.has_flag("allow-missing"))?;
    println!("{}", report::eval_summary(&result));
    let judge = &result.metric_calls;
    println!(
        "rescore complete: {} responses rehydrated ({} from checkpoint, {} from cache), \
         0 inference API calls",
        result.inference.examples,
        result.inference.sched.restored_rows,
        result.inference.cache_hits,
    );
    if judge.total() > 0 {
        println!(
            "metric stage: {} judge API calls (${:.4}), {} judge cache hits, {} failed",
            judge.api_calls, judge.cost_usd, judge.cache_hits, judge.failed
        );
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, result.to_json().to_pretty())?;
        println!("result JSON written to {out}");
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let which = args.get_or("table", "all");
    let fast = args.has_flag("fast");
    let run = |name: &str| which == "all" || which == name;
    if run("fig2") {
        println!("{}", tables::figure2(if fast { 5_000 } else { 10_000 }).1);
    }
    if run("tab3") {
        println!("{}", tables::table3().1);
    }
    if run("tab4") {
        println!("{}", tables::table4(50_000).1);
    }
    if run("tab5") {
        let (datasets, iters) = if fast { (200, 400) } else { (1000, 1000) };
        println!("{}", tables::table5(datasets, iters).1);
    }
    if run("tab6") {
        println!("{}", tables::table6().1);
    }
    if run("typei") {
        let n = if fast { 1000 } else { 10_000 };
        println!("{}", tables::type_i_error(n, 100).1);
    }
    Ok(())
}

fn cmd_checkpoint(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("compact") => {
            let dir = args
                .positional
                .get(1)
                .context("usage: slleval checkpoint compact <run_dir>")?;
            let run = spark_llm_eval::checkpoint::RunCheckpoint::resume(Path::new(dir))?;
            let report = run.compact()?;
            if report.is_empty() {
                println!("no checkpoint stages found in {dir}");
                return Ok(());
            }
            for stage in &report {
                println!(
                    "{}: {} -> {} manifest records ({} run(s) coalesced)",
                    stage.stage, stage.records_before, stage.records_after, stage.coalesced_runs
                );
            }
            println!("compacted {} stage(s) in {dir}", report.len());
            Ok(())
        }
        Some("ls") => {
            let dir =
                args.positional.get(1).context("usage: slleval checkpoint ls <run_dir>")?;
            let run = spark_llm_eval::checkpoint::RunCheckpoint::resume(Path::new(dir))?;
            let stages = run.stages()?;
            if stages.is_empty() {
                println!("no checkpoint stages found in {dir}");
                return Ok(());
            }
            for (name, stage) in &stages {
                let fingerprint = stage.fingerprint()?;
                let kind = fingerprint.str_or("kind", "?").to_string();
                let sha = fingerprint.str_or("sha256", "-").to_string();
                let sha_short = &sha[..sha.len().min(16)];
                let manifest = stage.manifest()?;
                let spilled: usize = manifest.iter().map(|r| r.end - r.start).sum();
                println!(
                    "{name}: kind {kind} fingerprint {sha_short} | {} manifest record(s), \
                     {spilled}/{} rows spilled ({:.1}% coverage)",
                    manifest.len(),
                    stage.total_rows(),
                    stage.coverage()? * 100.0
                );
            }
            Ok(())
        }
        _ => bail!("usage: slleval checkpoint <compact|ls> <run_dir>"),
    }
}

/// `slleval cache <ls|optimize|vacuum> <dir>` — inspect and maintain a
/// Delta cache table (any table written by [`spark_llm_eval::storage`],
/// not just response caches). Opening the table migrates a legacy
/// deltalite `_log/` directory in place first, so these commands also
/// serve as the migration entry point for old caches.
fn cmd_cache(args: &Args) -> Result<()> {
    use spark_llm_eval::storage::{self, DeltaTable};
    use spark_llm_eval::util::json::Json;

    let usage = "usage: slleval cache <ls|optimize|vacuum> <dir>";
    let sub = args.positional.first().map(String::as_str).context(usage)?;
    let dir = args.positional.get(1).context(usage)?;
    let table = DeltaTable::open(Path::new(dir))?;
    match sub {
        "ls" => {
            let state = table.state(None)?;
            let as_json = args.has_flag("json");
            let Some(state) = state else {
                if as_json {
                    println!("{}", Json::obj(vec![("version", Json::Null)]));
                } else {
                    println!("{dir}: empty table (no commits)");
                }
                return Ok(());
            };
            let with_stats = state.files.iter().filter(|f| f.stats.is_some()).count();
            let coverage = if state.files.is_empty() {
                1.0
            } else {
                with_stats as f64 / state.files.len() as f64
            };
            // Row count from per-file stats when complete, else a scan.
            let rows = match state.num_records() {
                Some(n) => n as usize,
                None => table.snapshot(None)?.len(),
            };
            let mut last_optimize = None;
            let mut last_vacuum = None;
            for (_, op, ts) in table.history()? {
                match op.as_str() {
                    "OPTIMIZE" => last_optimize = Some(ts),
                    "VACUUM END" => last_vacuum = Some(ts),
                    _ => {}
                }
            }
            if as_json {
                let mut fields = vec![
                    ("version", Json::num(state.version as f64)),
                    ("files", Json::num(state.files.len() as f64)),
                    ("bytes", Json::num(state.live_bytes() as f64)),
                    ("rows", Json::num(rows as f64)),
                    ("tombstones", Json::num(state.tombstones.len() as f64)),
                    ("stats_coverage", Json::num(coverage)),
                    ("last_optimize", last_optimize.map(Json::num).unwrap_or(Json::Null)),
                    ("last_vacuum", last_vacuum.map(Json::num).unwrap_or(Json::Null)),
                ];
                if args.has_flag("keys") {
                    let key_col = &table.effective_stats_columns(state.metadata.as_ref())[0];
                    let keys: Vec<Json> = table
                        .snapshot_by_key(key_col, None)?
                        .into_keys()
                        .map(|k| Json::str(k))
                        .collect();
                    fields.push(("keys", Json::arr(keys)));
                }
                println!("{}", Json::obj(fields));
            } else {
                let fmt_ts = |ts: Option<f64>| match ts {
                    Some(t) => format!("{t:.0}s"),
                    None => "never".to_string(),
                };
                println!(
                    "{dir}: version {} | {} live file(s), {} bytes, {} row(s) | \
                     stats coverage {:.0}% | {} tombstone(s) | last optimize {} | last vacuum {}",
                    state.version,
                    state.files.len(),
                    state.live_bytes(),
                    rows,
                    coverage * 100.0,
                    state.tombstones.len(),
                    fmt_ts(last_optimize),
                    fmt_ts(last_vacuum),
                );
            }
            Ok(())
        }
        "optimize" => {
            let target =
                args.get_usize("target-bytes", storage::maintain::DEFAULT_TARGET_BYTES as usize)
                    as u64;
            // Racing appends conflict the whole rewrite; retry afresh.
            let mut outcome = None;
            for _ in 0..8 {
                match storage::optimize(&table, target) {
                    Ok(o) => {
                        outcome = Some(o);
                        break;
                    }
                    Err(e) if storage::is_commit_conflict(&e) => continue,
                    Err(e) => return Err(e),
                }
            }
            let outcome = outcome.context("optimize kept losing commit races; try again")?;
            match outcome.version {
                Some(v) => println!(
                    "optimized {dir} at version {v}: {}",
                    outcome.metrics.to_json().to_pretty()
                ),
                None => println!("{dir}: nothing to optimize"),
            }
            Ok(())
        }
        "vacuum" => {
            let retain_hours =
                args.get_f64("retain-hours", storage::DEFAULT_RETAIN_HOURS);
            if retain_hours < 0.0 {
                bail!("--retain-hours must be >= 0");
            }
            let dry_run = args.has_flag("dry-run");
            let retain_ms = (retain_hours * 3_600_000.0) as u64;
            let outcome = storage::vacuum(&table, retain_ms, dry_run)?;
            if dry_run {
                for (path, size) in &outcome.to_delete {
                    println!("would delete {path} ({size} bytes)");
                }
                println!(
                    "{dir}: dry run — {} file(s) eligible, {}",
                    outcome.to_delete.len(),
                    outcome.start_metrics()
                );
            } else {
                println!(
                    "vacuumed {dir}: {} file(s) deleted, {} bytes reclaimed | start {} | end {}",
                    outcome.deleted_files,
                    outcome.reclaimed_bytes,
                    outcome.start_metrics(),
                    outcome.end_metrics(),
                );
            }
            Ok(())
        }
        other => bail!("unknown cache subcommand '{other}' ({usage})"),
    }
}

fn cmd_serve_worker(args: &Args) -> Result<()> {
    let listen = args
        .get("listen")
        .context("--listen <host:port> is required for serve-worker (port 0 picks a free port)")?;
    // 0 = unbounded; otherwise surplus connections are refused with an
    // init_error frame and the driver's spawn fails fast.
    let max_workers = args.get_usize("max-workers", 0);
    spark_llm_eval::coordinator::serve_worker_main(listen, max_workers)
}

/// `slleval serve` — the resident eval-service daemon (`crate::serve`).
/// Config comes from `--config serve.json` (a [`ServeConfig`] object),
/// with every field individually overridable on the command line.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(Path::new(path))?,
        None => ServeConfig::default(),
    };
    if let Some(listen) = args.get("listen") {
        cfg.listen = listen.to_string();
    }
    if let Some(dir) = args.get("cache-dir") {
        cfg.cache_dir = Some(dir.to_string());
    }
    if let Some(policy) = args.get("cache-policy") {
        cfg.cache_policy = CachePolicy::from_str(policy)?;
    }
    if args.has_flag("fast") {
        cfg.fast = true;
    }
    cfg.max_body_bytes = args.get_usize("max-body-bytes", cfg.max_body_bytes);
    cfg.latency_scale = args.get_f64("latency-scale", cfg.latency_scale);
    cfg.validate()?;
    spark_llm_eval::serve::serve_main(&cfg)
}

fn cmd_sim(args: &Args) -> Result<()> {
    let p = SimParams {
        n_examples: args.get_usize("n", 10_000),
        executors: args.get_usize("executors", 8),
        concurrency: args.get_usize("concurrency", 8),
        global_rpm: args.get_f64("rpm", 10_000.0),
        global_tpm: args.get_f64("tpm", 2_000_000.0),
        cache_hit_rate: args.get_f64("hit-rate", 0.0),
        ..Default::default()
    };
    let out = simulate(&p, spark_llm_eval::providers::pricing::lookup("openai", "gpt-4o"));
    println!(
        "{} examples, {} executors -> {:.0} examples/min, total {:.1}s",
        p.n_examples, p.executors, out.throughput_per_min, out.total_secs
    );
    println!(
        "latency p50 {:.0}ms p99 {:.0}ms | api calls {} | cost ${:.2} | rate-wait {:.0}%",
        out.latency_p50_ms,
        out.latency_p99_ms,
        out.api_calls,
        out.cost_usd,
        out.rate_wait_frac * 100.0
    );
    Ok(())
}
