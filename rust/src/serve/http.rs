//! Minimal HTTP/1.1 support for the eval-service daemon: a strict
//! request reader and a response writer over plain `Read`/`Write`
//! halves of a socket.
//!
//! Hand-rolled for the same reason `sched/wire.rs` is: the crate is
//! no-async and dependency-free by design, and the service only needs
//! the subset curl and stock HTTP clients actually speak — request
//! line + headers + `Content-Length` bodies, sequential keep-alive,
//! and `Expect: 100-continue` (curl sends it for bodies over ~1 KiB).
//! The parser follows wire.rs discipline: malformed or oversized input
//! becomes an error value, never a panic or an unbounded buffer —
//! chunked transfer encoding is rejected outright, and both the header
//! section and the body are capped.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Upper bound on the request line + header section, bytes.
pub const MAX_HEAD_BYTES: usize = 64 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Method verbatim (routing rejects unknown ones with 405).
    pub method: String,
    /// Request path with any query string stripped.
    pub path: String,
    /// Header names lowercased; the last occurrence of a name wins.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// Peer asked for `Connection: close` (HTTP/1.1 defaults to
    /// keep-alive, so this is opt-out).
    pub close: bool,
}

/// Why a request could not be read off the connection.
#[derive(Debug)]
pub enum RequestError {
    /// Peer closed the connection cleanly between requests.
    Closed,
    /// Syntactically invalid request; answer 400 and close (the frame
    /// boundary is unknown, so the connection cannot be reused).
    Malformed(String),
    /// Declared body exceeds the configured cap; answer 413 and close.
    TooLarge(usize),
    /// Socket error or read timeout.
    Io(std::io::Error),
}

/// Read one request. `r` and `w` are the two halves of the same
/// connection — the writer is needed mid-parse to honor
/// `Expect: 100-continue` before the peer will send its body.
pub fn read_request(
    r: &mut dyn BufRead,
    w: &mut dyn Write,
    max_body: usize,
) -> Result<Request, RequestError> {
    let mut head_budget = MAX_HEAD_BYTES;
    let line = match read_line(r, &mut head_budget)? {
        Some(line) => line,
        None => return Err(RequestError::Closed),
    };
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
        _ => return Err(RequestError::Malformed(format!("bad request line: {line:?}"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(RequestError::Malformed(format!("unsupported version: {version}")));
    }
    let path = match target.split_once('?') {
        Some((p, _query)) => p.to_string(),
        None => target,
    };

    let mut headers = BTreeMap::new();
    loop {
        let line = match read_line(r, &mut head_budget)? {
            Some(line) => line,
            None => return Err(RequestError::Malformed("eof inside header section".into())),
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = match line.split_once(':') {
            Some((n, v)) => (n.trim().to_ascii_lowercase(), v.trim().to_string()),
            None => return Err(RequestError::Malformed(format!("bad header line: {line:?}"))),
        };
        headers.insert(name, value);
    }

    if let Some(te) = headers.get("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(RequestError::Malformed(format!(
                "transfer-encoding {te:?} not supported (use content-length)"
            )));
        }
    }

    let content_length = match headers.get("content-length") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| RequestError::Malformed(format!("bad content-length: {v:?}")))?,
        None => 0,
    };
    if content_length > max_body {
        return Err(RequestError::TooLarge(max_body));
    }

    // curl (and others) withhold bodies over ~1 KiB until the server
    // acknowledges the Expect header with an interim 100 response.
    if let Some(expect) = headers.get("expect") {
        if expect.to_ascii_lowercase().contains("100-continue") {
            w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").map_err(RequestError::Io)?;
            w.flush().map_err(RequestError::Io)?;
        }
    }

    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        r.read_exact(&mut body).map_err(RequestError::Io)?;
    }

    let close = headers.get("connection").is_some_and(|c| c.eq_ignore_ascii_case("close"));
    Ok(Request { method, path, headers, body, close })
}

/// Read one CRLF- (or LF-) terminated line, charging its bytes against
/// the shared head budget. `Ok(None)` is clean EOF before any byte.
fn read_line(r: &mut dyn BufRead, budget: &mut usize) -> Result<Option<String>, RequestError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf().map_err(RequestError::Io)?;
        if chunk.is_empty() {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(RequestError::Malformed("eof mid-line in header section".into()));
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if pos > *budget {
                    return Err(RequestError::Malformed("header section too large".into()));
                }
                *budget -= pos;
                buf.extend_from_slice(&chunk[..pos]);
                r.consume(pos + 1);
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                let line = String::from_utf8(buf)
                    .map_err(|_| RequestError::Malformed("non-utf8 bytes in header".into()))?;
                return Ok(Some(line));
            }
            None => {
                let len = chunk.len();
                if len > *budget {
                    return Err(RequestError::Malformed("header section too large".into()));
                }
                buf.extend_from_slice(chunk);
                r.consume(len);
                *budget -= len;
            }
        }
    }
}

/// Write a JSON response (pretty-printed: the primary client is a
/// human behind curl).
pub fn write_response(w: &mut dyn Write, status: u16, body: &Json) -> std::io::Result<()> {
    let mut text = body.to_pretty();
    text.push('\n');
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        status,
        reason(status),
        text.len()
    )?;
    w.write_all(text.as_bytes())?;
    w.flush()
}

/// Canonical reason phrase for the handful of statuses the API uses.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Request, RequestError> {
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut w = Vec::new();
        read_request(&mut r, &mut w, 1024 * 1024)
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /runs/run-000001?verbose=1 HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/runs/run-000001");
        assert!(req.body.is_empty());
        assert!(!req.close);
    }

    #[test]
    fn parses_post_with_content_length_body() {
        let req = parse(
            "POST /runs HTTP/1.1\r\nContent-Length: 9\r\nConnection: close\r\n\r\n{\"a\": 1}x",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"a\": 1}x");
        assert!(req.close);
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let raw = "POST /runs HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nhi";
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut w = Vec::new();
        let req = read_request(&mut r, &mut w, 1024).unwrap();
        assert_eq!(req.body, b"hi");
        assert_eq!(String::from_utf8(w).unwrap(), "HTTP/1.1 100 Continue\r\n\r\n");
    }

    #[test]
    fn clean_eof_is_closed() {
        assert!(matches!(parse(""), Err(RequestError::Closed)));
    }

    #[test]
    fn garbage_request_line_is_malformed() {
        assert!(matches!(parse("this is not http\r\n\r\n"), Err(RequestError::Malformed(_))));
        assert!(matches!(parse("GET /x SPDY/9\r\n\r\n"), Err(RequestError::Malformed(_))));
    }

    #[test]
    fn chunked_encoding_is_rejected() {
        let err = parse("POST /runs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        assert!(matches!(err, Err(RequestError::Malformed(_))));
    }

    #[test]
    fn oversized_body_is_too_large() {
        let mut r = Cursor::new(b"POST /runs HTTP/1.1\r\nContent-Length: 99\r\n\r\n".to_vec());
        let mut w = Vec::new();
        assert!(matches!(read_request(&mut r, &mut w, 10), Err(RequestError::TooLarge(10))));
    }

    #[test]
    fn oversized_header_section_is_malformed() {
        let raw = format!("GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(matches!(parse(&raw), Err(RequestError::Malformed(_))));
    }

    #[test]
    fn keep_alive_reads_sequential_requests() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /runs HTTP/1.1\r\n\r\n";
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut w = Vec::new();
        let a = read_request(&mut r, &mut w, 1024).unwrap();
        let b = read_request(&mut r, &mut w, 1024).unwrap();
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/healthz", "/runs"));
        assert!(matches!(read_request(&mut r, &mut w, 1024), Err(RequestError::Closed)));
    }

    #[test]
    fn response_writer_emits_framed_json() {
        let mut w = Vec::new();
        write_response(&mut w, 201, &Json::obj(vec![("id", Json::str("run-000001"))])).unwrap();
        let text = String::from_utf8(w).unwrap();
        assert!(text.starts_with("HTTP/1.1 201 Created\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        let len: usize = text
            .lines()
            .find(|l| l.starts_with("Content-Length:"))
            .and_then(|l| l.split(':').nth(1))
            .and_then(|v| v.trim().parse().ok())
            .unwrap();
        assert_eq!(body.len(), len);
        assert!(body.contains("run-000001"));
    }
}
