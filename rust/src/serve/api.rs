//! HTTP API routing for the eval service.
//!
//! Pure function from a parsed [`Request`] to `(status, body)` so every
//! route — including error paths — is unit-testable without a socket.
//! Invalid submissions are client errors (400), never daemon errors:
//! body parsing and task validation all happen here, behind the
//! connection handler's panic barrier.
//!
//! | method | path                 | effect                                   |
//! |--------|----------------------|------------------------------------------|
//! | GET    | `/healthz`           | liveness probe                           |
//! | POST   | `/runs`              | submit `{"task":…, "data":…}` → 201 + id |
//! | GET    | `/runs`              | list all runs (submission order)         |
//! | GET    | `/runs/{id}`         | state + progress + scheduler snapshot    |
//! | GET    | `/runs/{id}/partial` | settled metric estimates with CIs        |
//! | GET    | `/runs/{id}/result`  | final result (409 until `done`)          |
//! | POST   | `/runs/{id}/cancel`  | cooperative abort                        |

use super::http::Request;
use super::registry::{DataSpec, RunRegistry, RunState};
use crate::config::EvalTask;
use crate::util::json::Json;

/// Route one request against the registry.
pub fn handle(registry: &RunRegistry, req: &Request) -> (u16, Json) {
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => (200, Json::obj(vec![("status", Json::str("ok"))])),
        ("POST", ["runs"]) => submit(registry, &req.body),
        ("GET", ["runs"]) => (200, registry.list_json()),
        ("GET", ["runs", id]) => match registry.status_json(id) {
            Some(status) => (200, status),
            None => unknown_run(id),
        },
        ("GET", ["runs", id, "partial"]) => match registry.partial_json(id) {
            Some(partial) => (200, partial),
            None => unknown_run(id),
        },
        ("GET", ["runs", id, "result"]) => result(registry, id),
        ("POST", ["runs", id, "cancel"]) => match registry.cancel(id) {
            Some(state) => (
                200,
                Json::obj(vec![("id", Json::str(*id)), ("state", Json::str(state.as_str()))]),
            ),
            None => unknown_run(id),
        },
        // Known path shapes with the wrong verb are 405; everything
        // else (including unknown sub-resources of a run) is 404.
        (_, ["healthz"] | ["runs"] | ["runs", _] | ["runs", _, "partial" | "result" | "cancel"]) => {
            (405, error_json("method not allowed"))
        }
        _ => (404, error_json(&format!("no such route: {}", req.path))),
    }
}

/// `POST /runs`: the body is either `{"task": <EvalTask>, "data":
/// {"n":…, "seed":…} | {"path":…}}` or a bare EvalTask object (then
/// the default synthetic corpus is evaluated).
fn submit(registry: &RunRegistry, body: &[u8]) -> (u16, Json) {
    let text = match std::str::from_utf8(body) {
        Ok(text) => text,
        Err(_) => return (400, error_json("request body is not utf-8")),
    };
    let value = match Json::parse(text) {
        Ok(value) => value,
        Err(e) => return (400, error_json(&format!("invalid JSON body: {e}"))),
    };
    let (task_value, data_value) = match value.opt("task") {
        Some(task) => (task, value.opt("data")),
        None => (&value, None),
    };
    let task = match EvalTask::from_json(task_value) {
        Ok(task) => task,
        Err(e) => return (400, error_json(&format!("invalid task: {e:#}"))),
    };
    let data = match parse_data(data_value) {
        Ok(data) => data,
        Err(message) => return (400, error_json(&message)),
    };
    let id = registry.submit(task, data);
    (201, Json::obj(vec![("id", Json::str(id)), ("state", Json::str("queued"))]))
}

fn parse_data(value: Option<&Json>) -> Result<DataSpec, String> {
    let mut spec = DataSpec::default();
    let Some(value) = value else { return Ok(spec) };
    spec.n = value.usize_or("n", spec.n);
    spec.seed = value.f64_or("seed", spec.seed as f64) as u64;
    spec.path = value.opt("path").and_then(|p| p.as_str().ok()).map(String::from);
    if spec.n == 0 && spec.path.is_none() {
        return Err("data.n must be >= 1 (or set data.path)".into());
    }
    Ok(spec)
}

fn result(registry: &RunRegistry, id: &str) -> (u16, Json) {
    match registry.result_json(id) {
        None => unknown_run(id),
        Some((RunState::Done, Some(result))) => (200, result),
        Some((state, _)) => (
            409,
            Json::obj(vec![
                (
                    "error",
                    Json::str(format!("run is {}, result not available", state.as_str())),
                ),
                ("state", Json::str(state.as_str())),
            ]),
        ),
    }
}

fn unknown_run(id: &str) -> (u16, Json) {
    (404, error_json(&format!("no such run: {id}")))
}

fn error_json(message: &str) -> Json {
    Json::obj(vec![("error", Json::str(message))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            headers: BTreeMap::new(),
            body: body.as_bytes().to_vec(),
            close: false,
        }
    }

    fn submit_body() -> String {
        let task = EvalTask::default().to_json().to_string();
        format!("{{\"task\": {task}, \"data\": {{\"n\": 50, \"seed\": 3}}}}")
    }

    #[test]
    fn healthz_and_unknown_routes() {
        let reg = RunRegistry::new();
        let (status, body) = handle(&reg, &req("GET", "/healthz", ""));
        assert_eq!(status, 200);
        assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
        let (status, _) = handle(&reg, &req("GET", "/nope", ""));
        assert_eq!(status, 404);
        let (status, _) = handle(&reg, &req("DELETE", "/runs", ""));
        assert_eq!(status, 405);
    }

    #[test]
    fn submit_then_status_then_cancel() {
        let reg = RunRegistry::new();
        let (status, body) = handle(&reg, &req("POST", "/runs", &submit_body()));
        assert_eq!(status, 201, "{body:?}");
        let id = body.get("id").unwrap().as_str().unwrap().to_string();
        let (status, body) = handle(&reg, &req("GET", &format!("/runs/{id}"), ""));
        assert_eq!(status, 200);
        assert_eq!(body.get("state").unwrap().as_str().unwrap(), "queued");
        let (status, body) = handle(&reg, &req("POST", &format!("/runs/{id}/cancel"), ""));
        assert_eq!(status, 200);
        assert_eq!(body.get("state").unwrap().as_str().unwrap(), "cancelled");
    }

    #[test]
    fn bare_task_body_uses_default_data() {
        let reg = RunRegistry::new();
        let body = EvalTask::default().to_json().to_string();
        let (status, _) = handle(&reg, &req("POST", "/runs", &body));
        assert_eq!(status, 201);
    }

    #[test]
    fn malformed_bodies_are_client_errors() {
        let reg = RunRegistry::new();
        for body in ["{not json", "{\"task\": {\"no_task_id\": 1}}", "\u{1}\u{2}"] {
            let (status, resp) = handle(&reg, &req("POST", "/runs", body));
            assert_eq!(status, 400, "{body:?} → {resp:?}");
            assert!(resp.get("error").is_ok());
        }
        let zero_rows = format!(
            "{{\"task\": {}, \"data\": {{\"n\": 0}}}}",
            EvalTask::default().to_json()
        );
        let (status, _) = handle(&reg, &req("POST", "/runs", &zero_rows));
        assert_eq!(status, 400);
    }

    #[test]
    fn result_is_conflict_until_done() {
        let reg = RunRegistry::new();
        let (_, body) = handle(&reg, &req("POST", "/runs", &submit_body()));
        let id = body.get("id").unwrap().as_str().unwrap().to_string();
        let (status, body) = handle(&reg, &req("GET", &format!("/runs/{id}/result"), ""));
        assert_eq!(status, 409);
        assert_eq!(body.get("state").unwrap().as_str().unwrap(), "queued");
        // finish() only settles claimed (running) entries.
        reg.finish(&id, Json::obj(vec![("task_id", Json::str("t"))]));
        let (status, _) = handle(&reg, &req("GET", &format!("/runs/{id}/result"), ""));
        assert_eq!(status, 409);
        let stop = std::sync::atomic::AtomicBool::new(false);
        assert!(reg.claim_next(&stop).is_some());
        reg.finish(&id, Json::obj(vec![("task_id", Json::str("t"))]));
        let (status, _) = handle(&reg, &req("GET", &format!("/runs/{id}/result"), ""));
        assert_eq!(status, 200);
    }

    #[test]
    fn unknown_run_paths_are_404() {
        let reg = RunRegistry::new();
        for path in
            ["/runs/run-000009", "/runs/run-000009/partial", "/runs/run-000009/result"]
        {
            let (status, _) = handle(&reg, &req("GET", path, ""));
            assert_eq!(status, 404, "{path}");
        }
        let (status, _) = handle(&reg, &req("POST", "/runs/run-000009/cancel", ""));
        assert_eq!(status, 404);
    }
}
