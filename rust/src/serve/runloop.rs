//! The daemon's single run-loop thread.
//!
//! One thread claims queued runs and drives them through one
//! daemon-lifetime [`EvalRunner`] — which is exactly what makes fleets
//! and the response cache shared resources: the runner's persistent
//! process fleet survives between `evaluate` calls (re-armed with
//! `plan` frames per stage, see `sched/backend.rs`), and its cache
//! handle is opened once at daemon start, so a tenant resubmitting a
//! task pays zero inference and near-zero setup.
//!
//! Runs execute strictly sequentially: the scheduler already fans each
//! run out across executors, and serial execution is what keeps every
//! run bit-identical to its one-shot `slleval run` counterpart (no
//! cross-run contention on executor seeds or rate-limit state).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::registry::{ClaimedRun, DataSpec, RunRegistry};
use crate::coordinator::{EvalRunner, InferenceStats, MetricStopState, MetricValue, RunObserver};
use crate::data::{synth, DataFrame};
use crate::engine::Progress;
use crate::util::json::Json;

/// Spawn the run-loop thread. It exits once `stop` is set (claiming
/// wakes at least every 100ms to check).
pub fn spawn(
    registry: Arc<RunRegistry>,
    runner: EvalRunner,
    stop: Arc<AtomicBool>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name("slleval-serve-runloop".into())
        .spawn(move || run_loop(&registry, runner, &stop))
        .context("spawning serve run loop")
}

fn run_loop(registry: &Arc<RunRegistry>, mut runner: EvalRunner, stop: &AtomicBool) {
    while let Some(claim) = registry.claim_next(stop) {
        execute(registry, &mut runner, claim);
    }
}

/// Drive one claimed run to a terminal state. A panic anywhere in the
/// pipeline settles the run as `failed` and leaves the daemon serving —
/// the run loop is the serve-side analogue of the executor-side "UDF
/// panics become task errors" rule.
fn execute(registry: &Arc<RunRegistry>, runner: &mut EvalRunner, claim: ClaimedRun) {
    let id = claim.id.clone();
    let outcome = catch_unwind(AssertUnwindSafe(|| drive(registry, runner, &claim)));
    // Detach per-run plumbing whatever happened, so a stale abort flag
    // or observer can never leak into the next tenant's run.
    runner.abort = None;
    runner.progress = None;
    runner.observer = None;
    match outcome {
        Ok(Ok(result)) => registry.finish(&id, result),
        Ok(Err(e)) => registry.fail(&id, &format!("{e:#}")),
        Err(payload) => registry.fail(&id, &format!("run panicked: {}", panic_text(&payload))),
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn drive(registry: &Arc<RunRegistry>, runner: &mut EvalRunner, claim: &ClaimedRun) -> Result<Json> {
    let df = load_frame(&claim.data)?;
    let progress = Arc::new(Progress::new(df.len()));
    registry.set_progress(&claim.id, Arc::clone(&progress));
    runner.progress = Some(progress);
    runner.abort = Some(Arc::clone(&claim.abort));
    runner.observer = Some(Arc::new(RegistryObserver {
        registry: Arc::clone(registry),
        id: claim.id.clone(),
    }));
    let result = runner.evaluate(&df, &claim.task)?;
    Ok(result.to_json())
}

fn load_frame(data: &DataSpec) -> Result<DataFrame> {
    match &data.path {
        Some(path) => crate::data::io::read_jsonl(Path::new(path))
            .with_context(|| format!("loading data file {path}")),
        None => Ok(synth::generate_default(data.n, data.seed)),
    }
}

/// Bridges [`RunObserver`] callbacks (fired synchronously from the
/// run's driving thread) into registry snapshots that the HTTP threads
/// serve from `/runs/{id}` and `/runs/{id}/partial`.
struct RegistryObserver {
    registry: Arc<RunRegistry>,
    id: String,
}

impl RunObserver for RegistryObserver {
    fn inference_done(&self, stats: &InferenceStats) {
        let snapshot = Json::obj(vec![
            ("inference", stats.to_json()),
            ("scheduler", stats.sched.to_json()),
        ]);
        self.registry.record_inference(&self.id, snapshot);
    }

    fn metric_done(&self, index: usize, total: usize, value: &MetricValue) {
        self.registry.record_metric(&self.id, index, total, value.to_json());
    }

    fn wave_done(&self, wave: usize, rows: usize, stopping: &[MetricStopState]) {
        // Fired from inside the inference stage (the scheduler's gate
        // consult), so /partial shows live stopped/certified state while
        // waves are still running.
        let snapshot = Json::obj(vec![
            ("wave", Json::num(wave as f64)),
            ("rows", Json::num(rows as f64)),
            ("metrics", Json::arr(stopping.iter().map(|s| s.to_json()).collect())),
        ]);
        self.registry.record_stopping(&self.id, snapshot);
    }
}
