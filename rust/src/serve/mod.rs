//! `slleval serve` — eval-as-a-service (DESIGN.md "Eval service").
//!
//! A resident HTTP/1.1 driver over `std::net::TcpListener` that
//! accepts EvalTask submissions, executes them sequentially on a
//! background run loop through one daemon-lifetime [`EvalRunner`]
//! (shared response cache, persistent executor fleets), and exposes
//! the run lifecycle plus live partial results as a small JSON API:
//!
//! | endpoint                  | effect                                  |
//! |---------------------------|-----------------------------------------|
//! | `POST /runs`              | submit `{"task": …, "data": …}` → id    |
//! | `GET  /runs`              | list runs                               |
//! | `GET  /runs/{id}`         | state machine + progress + sched stats  |
//! | `GET  /runs/{id}/partial` | per-metric estimates with bootstrap CIs |
//! | `GET  /runs/{id}/result`  | final result (409 until done)           |
//! | `POST /runs/{id}/cancel`  | cooperative abort                       |
//! | `GET  /healthz`           | liveness                                |
//!
//! Threading model (no async, same discipline as `sched/remote.rs`):
//! one accept thread, one handler thread per connection (sequential
//! keep-alive per connection), one run-loop thread owning the runner.
//! A panic in a handler answers 500 and closes that connection; a
//! panic inside a run settles it `failed`; the daemon keeps serving
//! either way.

pub mod api;
pub mod http;
pub mod registry;
mod runloop;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

pub use registry::{DataSpec, RunRegistry, RunState};

use crate::config::ServeConfig;
use crate::coordinator::EvalRunner;
use crate::providers::simulated::SimServiceConfig;
use crate::ratelimit::VirtualClock;
use crate::util::json::Json;

/// Per-connection socket read timeout: an idle keep-alive connection
/// is reaped after this long so handler threads cannot pile up.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// A running eval-service daemon.
pub struct ServeDaemon {
    registry: Arc<RunRegistry>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    runloop: Option<JoinHandle<()>>,
}

impl ServeDaemon {
    /// Build the daemon's runner from config and start serving.
    pub fn start(cfg: &ServeConfig) -> Result<ServeDaemon> {
        Self::start_with_runner(cfg, build_runner(cfg)?)
    }

    /// Start with a caller-built runner (tests inject fault-free fast
    /// runners this way). Binding port 0 picks a free port; the real
    /// address is [`ServeDaemon::addr`].
    pub fn start_with_runner(cfg: &ServeConfig, runner: EvalRunner) -> Result<ServeDaemon> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding serve listener on {}", cfg.listen))?;
        let addr = listener.local_addr().context("resolving serve listener address")?;
        let registry = Arc::new(RunRegistry::new());
        let stop = Arc::new(AtomicBool::new(false));
        let runloop = runloop::spawn(Arc::clone(&registry), runner, Arc::clone(&stop))?;
        let accept = {
            let registry = Arc::clone(&registry);
            let stop = Arc::clone(&stop);
            let max_body = cfg.max_body_bytes;
            std::thread::Builder::new()
                .name("slleval-serve-accept".into())
                .spawn(move || accept_loop(&listener, &registry, &stop, max_body))
                .context("spawning serve accept loop")?
        };
        Ok(ServeDaemon { registry, addr, stop, accept: Some(accept), runloop: Some(runloop) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Arc<RunRegistry> {
        &self.registry
    }

    /// Serve until the process exits (the CLI path).
    pub fn join(mut self) {
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.runloop.take() {
            let _ = handle.join();
        }
    }

    /// Cooperative shutdown (tests): cancel every non-terminal run,
    /// stop accepting, and join both daemon threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for id in self.registry.ids() {
            self.registry.cancel(&id);
        }
        // Unblock the accept loop: it only re-checks `stop` when a
        // connection arrives, so hand it one.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.runloop.take() {
            let _ = handle.join();
        }
    }
}

/// Build the daemon's single long-lived runner: fast mode runs under
/// the virtual clock with latency accounted but not slept (CI and
/// tests); live mode sleeps simulated latencies scaled by
/// `latency_scale`. Either way the shared response cache is opened
/// once, here, for the daemon's lifetime.
fn build_runner(cfg: &ServeConfig) -> Result<EvalRunner> {
    let mut runner = if cfg.fast {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig { sleep_latency: false, ..Default::default() };
        r
    } else {
        let mut r = EvalRunner::new();
        r.service_config =
            SimServiceConfig { latency_scale: cfg.latency_scale, ..Default::default() };
        r
    };
    if let Some(dir) = &cfg.cache_dir {
        runner
            .open_cache(Path::new(dir), cfg.cache_policy)
            .with_context(|| format!("opening shared response cache at {dir}"))?;
    }
    Ok(runner)
}

/// CLI entry: start the daemon and serve until killed. The "serving
/// on" line is the startup handshake scripts poll for (same idiom as
/// `serve-worker`'s "listening on").
pub fn serve_main(cfg: &ServeConfig) -> Result<()> {
    let daemon = ServeDaemon::start(cfg)?;
    println!("serving on {}", daemon.addr());
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    daemon.join();
    Ok(())
}

fn accept_loop(
    listener: &TcpListener,
    registry: &Arc<RunRegistry>,
    stop: &Arc<AtomicBool>,
    max_body: usize,
) {
    let mut conn_seq = 0u64;
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let Ok(stream) = stream else { continue };
        conn_seq += 1;
        let registry = Arc::clone(registry);
        let spawned = std::thread::Builder::new()
            .name(format!("slleval-serve-conn-{conn_seq}"))
            .spawn(move || handle_connection(stream, &registry, max_body));
        // Thread exhaustion drops the connection, never the daemon.
        drop(spawned);
    }
}

/// Serve one connection: sequential keep-alive requests until the peer
/// closes, asks to close, times out, or sends an unframeable request.
fn handle_connection(stream: TcpStream, registry: &Arc<RunRegistry>, max_body: usize) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader, &mut writer, max_body) {
            Ok(req) => req,
            Err(http::RequestError::Closed) | Err(http::RequestError::Io(_)) => return,
            Err(http::RequestError::Malformed(message)) => {
                // The frame boundary is unknown: answer 400, close.
                let body = Json::obj(vec![("error", Json::str(message))]);
                let _ = http::write_response(&mut writer, 400, &body);
                return;
            }
            Err(http::RequestError::TooLarge(cap)) => {
                let body = Json::obj(vec![(
                    "error",
                    Json::str(format!("request body exceeds {cap} byte cap")),
                )]);
                let _ = http::write_response(&mut writer, 413, &body);
                return;
            }
        };
        let close = req.close;
        // Panic barrier: a handler panic becomes a 500 on this
        // connection; the daemon and every other connection live on.
        let (status, body) = match catch_unwind(AssertUnwindSafe(|| api::handle(registry, &req))) {
            Ok(response) => response,
            Err(_) => {
                (500, Json::obj(vec![("error", Json::str("internal error: handler panicked"))]))
            }
        };
        if http::write_response(&mut writer, status, &body).is_err() {
            return;
        }
        if close {
            return;
        }
    }
}
