//! Run lifecycle registry for the eval service.
//!
//! Every submitted EvalTask becomes a [`RunEntry`] that moves through
//! the state machine `queued → running → done | failed | cancelled`.
//! HTTP connection threads write submissions and cancellations; the
//! single run-loop thread claims queued runs and reports progress,
//! per-metric partial estimates (each carrying its bootstrap CI), and
//! the final result JSON. All shared state lives behind one mutex, and
//! every lock recovers from poisoning — a panicking request handler
//! must never take the registry (and with it the daemon) down.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::config::EvalTask;
use crate::engine::Progress;
use crate::util::json::Json;

/// Run lifecycle states. `Done`, `Failed`, and `Cancelled` are
/// terminal; `Cancelled` covers both a queued run cancelled before it
/// started and a running run settled by the scheduler's abort flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl RunState {
    pub fn as_str(self) -> &'static str {
        match self {
            RunState::Queued => "queued",
            RunState::Running => "running",
            RunState::Done => "done",
            RunState::Failed => "failed",
            RunState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, RunState::Done | RunState::Failed | RunState::Cancelled)
    }
}

/// Where a run's input frame comes from. The service is a driver, so
/// data is resolved driver-side when the run is claimed, not at
/// submission time.
#[derive(Debug, Clone)]
pub struct DataSpec {
    /// Synthetic corpus size (ignored when `path` is set).
    pub n: usize,
    /// Synthetic corpus seed.
    pub seed: u64,
    /// Driver-local JSONL file to evaluate instead of synthetic data.
    pub path: Option<String>,
}

impl Default for DataSpec {
    fn default() -> Self {
        Self { n: 1000, seed: 42, path: None }
    }
}

struct RunEntry {
    task: EvalTask,
    data: DataSpec,
    state: RunState,
    error: Option<String>,
    /// The scheduler-facing cooperative abort flag; `cancel` on a
    /// running entry sets it and the run loop settles the state.
    abort: Arc<AtomicBool>,
    /// Stage-2 row progress, installed by the run loop once the input
    /// frame is built (total row count is only known then).
    progress: Option<Arc<Progress>>,
    metrics_total: usize,
    /// Settled metric estimates in task order, each a full MetricValue
    /// JSON (point value + bootstrap CI) — the `/partial` payload.
    partial: Vec<Json>,
    /// Latest adaptive-stopping look: wave number, rows seen, and the
    /// per-metric stopped/certified state. Absent on runs without a
    /// `stopping` block, so their `/partial` payload is unchanged.
    stopping: Option<Json>,
    /// Stage-2 snapshot: inference accounting + scheduler stats.
    inference: Option<Json>,
    result: Option<Json>,
}

/// Everything the run loop needs to execute a claimed run.
pub struct ClaimedRun {
    pub id: String,
    pub task: EvalTask,
    pub data: DataSpec,
    pub abort: Arc<AtomicBool>,
}

struct Inner {
    runs: BTreeMap<String, RunEntry>,
    queue: VecDeque<String>,
    next_id: u64,
}

/// Shared run registry: one per daemon.
pub struct RunRegistry {
    inner: Mutex<Inner>,
    wake: Condvar,
}

impl Default for RunRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl RunRegistry {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { runs: BTreeMap::new(), queue: VecDeque::new(), next_id: 0 }),
            wake: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register a new run as `queued` and return its id. Ids are a
    /// zero-padded submission counter, so `GET /runs` (a BTreeMap walk)
    /// lists runs in submission order.
    pub fn submit(&self, task: EvalTask, data: DataSpec) -> String {
        let mut g = self.lock();
        g.next_id += 1;
        let id = format!("run-{:06}", g.next_id);
        let metrics_total = task.metrics.len();
        g.runs.insert(
            id.clone(),
            RunEntry {
                task,
                data,
                state: RunState::Queued,
                error: None,
                abort: Arc::new(AtomicBool::new(false)),
                progress: None,
                metrics_total,
                partial: Vec::new(),
                stopping: None,
                inference: None,
                result: None,
            },
        );
        g.queue.push_back(id.clone());
        self.wake.notify_all();
        id
    }

    /// Block until a queued run is available, claim it, and mark it
    /// `running`. Returns `None` once `stop` is set (daemon shutdown).
    /// Runs cancelled while still queued are skipped, satisfying
    /// "cancel stops new work" without the run loop ever seeing them.
    pub fn claim_next(&self, stop: &AtomicBool) -> Option<ClaimedRun> {
        let mut g = self.lock();
        loop {
            if stop.load(Ordering::Relaxed) {
                return None;
            }
            while let Some(id) = g.queue.pop_front() {
                let Some(entry) = g.runs.get_mut(&id) else { continue };
                if entry.state != RunState::Queued {
                    continue;
                }
                entry.state = RunState::Running;
                return Some(ClaimedRun {
                    id,
                    task: entry.task.clone(),
                    data: entry.data.clone(),
                    abort: entry.abort.clone(),
                });
            }
            // Timed wait so shutdown is noticed even without a notify.
            let (g2, _timeout) = self
                .wake
                .wait_timeout(g, Duration::from_millis(100))
                .unwrap_or_else(|p| p.into_inner());
            g = g2;
        }
    }

    /// Install the row-progress handle once the run's frame is built.
    pub fn set_progress(&self, id: &str, progress: Arc<Progress>) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            entry.progress = Some(progress);
        }
    }

    /// Record the stage-2 snapshot (inference + scheduler accounting).
    pub fn record_inference(&self, id: &str, snapshot: Json) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            entry.inference = Some(snapshot);
        }
    }

    /// Record the latest adaptive-stopping look (replaces the previous
    /// one — `/partial` serves live state, not the look history).
    pub fn record_stopping(&self, id: &str, snapshot: Json) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            entry.stopping = Some(snapshot);
        }
    }

    /// Record one settled metric estimate (stage 3+4 for that metric).
    pub fn record_metric(&self, id: &str, index: usize, total: usize, value: Json) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            entry.metrics_total = total;
            entry.partial.truncate(index);
            entry.partial.push(value);
        }
    }

    /// Settle a running run as `done` with its final result JSON.
    /// Only claimed (`running`) entries settle — a run cancelled while
    /// still queued can never be finished by a stale caller.
    pub fn finish(&self, id: &str, result: Json) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            if entry.state == RunState::Running {
                entry.state = RunState::Done;
                entry.result = Some(result);
            }
        }
    }

    /// Settle a running run that returned an error: `cancelled` when
    /// its abort flag was raised (the error is the scheduler's abort
    /// report), `failed` otherwise.
    pub fn fail(&self, id: &str, error: &str) {
        if let Some(entry) = self.lock().runs.get_mut(id) {
            if entry.state == RunState::Running {
                entry.state = if entry.abort.load(Ordering::Relaxed) {
                    RunState::Cancelled
                } else {
                    RunState::Failed
                };
                entry.error = Some(error.to_string());
            }
        }
    }

    /// Cooperative cancel. Queued runs settle immediately; running runs
    /// get their abort flag raised and settle when the scheduler or the
    /// between-metrics check observes it; terminal runs are untouched.
    /// Returns the state after the call, or `None` for an unknown id.
    pub fn cancel(&self, id: &str) -> Option<RunState> {
        let mut g = self.lock();
        let entry = g.runs.get_mut(id)?;
        match entry.state {
            RunState::Queued => {
                entry.state = RunState::Cancelled;
                entry.error = Some("cancelled before start".into());
            }
            RunState::Running => {
                entry.abort.store(true, Ordering::Relaxed);
            }
            _ => {}
        }
        Some(entry.state)
    }

    /// All run ids, submission order.
    pub fn ids(&self) -> Vec<String> {
        self.lock().runs.keys().cloned().collect()
    }

    /// `GET /runs`: one summary line per run, submission order.
    pub fn list_json(&self) -> Json {
        let g = self.lock();
        let runs = g
            .runs
            .iter()
            .map(|(id, e)| {
                Json::obj(vec![
                    ("id", Json::str(id.clone())),
                    ("task_id", Json::str(e.task.task_id.clone())),
                    ("state", Json::str(e.state.as_str())),
                ])
            })
            .collect();
        Json::obj(vec![("runs", Json::arr(runs))])
    }

    /// `GET /runs/{id}`: state machine position, row/metric progress,
    /// and the stage-2 scheduler snapshot once inference settled.
    pub fn status_json(&self, id: &str) -> Option<Json> {
        let g = self.lock();
        let e = g.runs.get(id)?;
        let rows = e.progress.as_ref().map(|p| p.fraction()).unwrap_or(0.0);
        Some(Json::obj(vec![
            ("id", Json::str(id)),
            ("task_id", Json::str(e.task.task_id.clone())),
            ("state", Json::str(e.state.as_str())),
            ("error", e.error.clone().map(Json::str).unwrap_or(Json::Null)),
            (
                "progress",
                Json::obj(vec![
                    ("rows_fraction", Json::num(rows)),
                    ("metrics_done", Json::num(e.partial.len() as f64)),
                    ("metrics_total", Json::num(e.metrics_total as f64)),
                ]),
            ),
            ("inference", e.inference.clone().unwrap_or(Json::Null)),
        ]))
    }

    /// `GET /runs/{id}/partial`: the metric estimates settled so far,
    /// plus (stopping-enabled runs only) the latest wave's per-metric
    /// stopped/certified state.
    pub fn partial_json(&self, id: &str) -> Option<Json> {
        let g = self.lock();
        let e = g.runs.get(id)?;
        let mut fields = vec![
            ("id", Json::str(id)),
            ("state", Json::str(e.state.as_str())),
            ("metrics_done", Json::num(e.partial.len() as f64)),
            ("metrics_total", Json::num(e.metrics_total as f64)),
            ("metrics", Json::arr(e.partial.clone())),
        ];
        if let Some(stopping) = &e.stopping {
            fields.push(("stopping", stopping.clone()));
        }
        Some(Json::obj(fields))
    }

    /// `GET /runs/{id}/result`: the final result once `done`.
    pub fn result_json(&self, id: &str) -> Option<(RunState, Option<Json>)> {
        let g = self.lock();
        let e = g.runs.get(id)?;
        Some((e.state, e.result.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvalTask;

    fn registry_with_one() -> (RunRegistry, String) {
        let reg = RunRegistry::new();
        let id = reg.submit(EvalTask::default(), DataSpec::default());
        (reg, id)
    }

    #[test]
    fn ids_are_sequential_and_listed_in_order() {
        let reg = RunRegistry::new();
        let a = reg.submit(EvalTask::default(), DataSpec::default());
        let b = reg.submit(EvalTask::default(), DataSpec::default());
        assert_eq!((a.as_str(), b.as_str()), ("run-000001", "run-000002"));
        let list = reg.list_json();
        let runs = match list.get("runs").unwrap() {
            Json::Arr(items) => items.clone(),
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("id").unwrap().as_str().unwrap(), "run-000001");
        assert_eq!(runs[0].get("state").unwrap().as_str().unwrap(), "queued");
    }

    #[test]
    fn claim_marks_running_and_finish_marks_done() {
        let (reg, id) = registry_with_one();
        let stop = AtomicBool::new(false);
        let claim = reg.claim_next(&stop).unwrap();
        assert_eq!(claim.id, id);
        let status = reg.status_json(&id).unwrap();
        assert_eq!(status.get("state").unwrap().as_str().unwrap(), "running");
        reg.finish(&id, Json::obj(vec![("ok", Json::Bool(true))]));
        let (state, result) = reg.result_json(&id).unwrap();
        assert_eq!(state, RunState::Done);
        assert!(result.is_some());
    }

    #[test]
    fn claim_next_returns_none_on_stop() {
        let reg = RunRegistry::new();
        let stop = AtomicBool::new(true);
        assert!(reg.claim_next(&stop).is_none());
    }

    #[test]
    fn cancel_while_queued_is_immediate_and_skipped_by_claim() {
        let (reg, id) = registry_with_one();
        assert_eq!(reg.cancel(&id), Some(RunState::Cancelled));
        let stop = AtomicBool::new(true);
        // The cancelled entry must not be claimable.
        assert!(reg.claim_next(&stop).is_none());
        let status = reg.status_json(&id).unwrap();
        assert_eq!(status.get("state").unwrap().as_str().unwrap(), "cancelled");
    }

    #[test]
    fn cancel_while_running_raises_abort_then_fail_settles_cancelled() {
        let (reg, id) = registry_with_one();
        let stop = AtomicBool::new(false);
        let claim = reg.claim_next(&stop).unwrap();
        assert!(!claim.abort.load(Ordering::Relaxed));
        assert_eq!(reg.cancel(&id), Some(RunState::Running));
        assert!(claim.abort.load(Ordering::Relaxed));
        reg.fail(&id, "run aborted with 12/100 rows complete");
        let (state, result) = reg.result_json(&id).unwrap();
        assert_eq!(state, RunState::Cancelled);
        assert!(result.is_none());
    }

    #[test]
    fn fail_without_abort_is_failed_and_terminal_states_stick() {
        let (reg, id) = registry_with_one();
        let stop = AtomicBool::new(false);
        reg.claim_next(&stop).unwrap();
        reg.fail(&id, "boom");
        assert_eq!(reg.cancel(&id), Some(RunState::Failed));
        reg.finish(&id, Json::Null);
        let (state, result) = reg.result_json(&id).unwrap();
        assert_eq!(state, RunState::Failed);
        assert!(result.is_none());
        let status = reg.status_json(&id).unwrap();
        assert_eq!(status.get("error").unwrap().as_str().unwrap(), "boom");
    }

    #[test]
    fn partial_metrics_accumulate_in_order() {
        let (reg, id) = registry_with_one();
        reg.record_metric(&id, 0, 2, Json::obj(vec![("name", Json::str("exact_match"))]));
        let p = reg.partial_json(&id).unwrap();
        assert_eq!(p.get("metrics_done").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(p.get("metrics_total").unwrap().as_f64().unwrap(), 2.0);
        reg.record_metric(&id, 1, 2, Json::obj(vec![("name", Json::str("token_f1"))]));
        let p = reg.partial_json(&id).unwrap();
        let metrics = match p.get("metrics").unwrap() {
            Json::Arr(items) => items.clone(),
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(metrics.len(), 2);
        assert_eq!(metrics[1].get("name").unwrap().as_str().unwrap(), "token_f1");
    }

    #[test]
    fn stopping_snapshot_replaces_and_only_appears_when_recorded() {
        let (reg, id) = registry_with_one();
        // No stopping recorded → payload has no "stopping" key at all.
        let p = reg.partial_json(&id).unwrap();
        assert!(p.get("stopping").is_none());
        reg.record_stopping(&id, Json::obj(vec![("wave", Json::num(0.0))]));
        reg.record_stopping(&id, Json::obj(vec![("wave", Json::num(2.0))]));
        let p = reg.partial_json(&id).unwrap();
        let s = p.get("stopping").unwrap();
        // Latest look wins — /partial is live state, not a history.
        assert_eq!(s.get("wave").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn unknown_ids_are_none() {
        let reg = RunRegistry::new();
        assert!(reg.status_json("run-000009").is_none());
        assert!(reg.partial_json("run-000009").is_none());
        assert!(reg.result_json("run-000009").is_none());
        assert!(reg.cancel("run-000009").is_none());
    }
}
