//! Hierarchical evaluation configuration (paper §3.4, §A.2).
//!
//! An [`EvalTask`] is the complete, serializable specification of one
//! evaluation: model, inference behaviour (batching / rate limits /
//! caching), metrics, statistics, and data binding. Round-trips through
//! JSON so a run's exact configuration can be stored alongside its results
//! (reproducibility) and hashed into cache keys.

use crate::util::json::{Json, JsonError};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

pub use crate::sched::backend::BackendKind;
pub use crate::sched::SchedulerConfig;

/// Cache behaviour (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Lookup before inference, cache new responses.
    Enabled,
    /// Lookup only; never write (shared cache storage).
    ReadOnly,
    /// Cache warming: skip lookup, always infer and write.
    WriteOnly,
    /// Strict cache mode: error on miss. Zero-API-cost metric iteration.
    Replay,
    /// No caching.
    Disabled,
}

impl CachePolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            CachePolicy::Enabled => "enabled",
            CachePolicy::ReadOnly => "read_only",
            CachePolicy::WriteOnly => "write_only",
            CachePolicy::Replay => "replay",
            CachePolicy::Disabled => "disabled",
        }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "enabled" => CachePolicy::Enabled,
            "read_only" => CachePolicy::ReadOnly,
            "write_only" => CachePolicy::WriteOnly,
            "replay" => CachePolicy::Replay,
            "disabled" => CachePolicy::Disabled,
            other => bail!("unknown cache policy: {other}"),
        })
    }

    pub fn reads(self) -> bool {
        matches!(self, CachePolicy::Enabled | CachePolicy::ReadOnly | CachePolicy::Replay)
    }

    pub fn writes(self) -> bool {
        matches!(self, CachePolicy::Enabled | CachePolicy::WriteOnly)
    }
}

/// Which model to evaluate (paper §3.3, Table 7).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub provider: String,
    pub model_name: String,
    /// Sampling temperature; 0.0 = deterministic (paper default).
    pub temperature: f64,
    /// Maximum response length in tokens.
    pub max_tokens: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            provider: "openai".into(),
            model_name: "gpt-4o".into(),
            temperature: 0.0,
            max_tokens: 1024,
        }
    }
}

/// Inference-stage behaviour (paper §3.1–§3.2, §A.2, §A.4).
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceConfig {
    /// Examples per executor batch (Pandas-UDF batch equivalent).
    pub batch_size: usize,
    /// In-flight provider requests multiplexed per executor (the paper's
    /// §3.1 in-executor concurrency): each executor pipelines up to this
    /// many requests through its slot engines, overlapping round-trip
    /// latency. `1` (the default) reproduces the pre-pipeline sequential
    /// hot path bit for bit.
    pub concurrency: usize,
    /// Global requests-per-minute budget split across executors.
    pub rate_limit_rpm: f64,
    /// Global tokens-per-minute budget split across executors.
    pub rate_limit_tpm: f64,
    pub cache_policy: CachePolicy,
    /// Stats-based data skipping for cache lookups: consult per-file
    /// min/max `prompt_hash` stats from the Delta log and decompress only
    /// files whose range can contain the key. Results are bit-identical
    /// either way; off forces a full file probe (diagnostics).
    pub cache_skipping: bool,
    /// Retry attempts for recoverable errors (429/5xx).
    pub max_retries: usize,
    /// Base delay (seconds) for exponential backoff.
    pub retry_delay: f64,
    /// Adaptive rate-limit redistribution between executors (§6.1
    /// limitations — implemented here as an extension).
    pub adaptive_rate_limits: bool,
    /// Hard provider-spend ceiling (USD) for one inference stage: once
    /// cumulative cost crosses it the run aborts between batches. With
    /// checkpointing enabled, everything completed up to the abort stays
    /// resumable via `--resume`. `None` = unlimited.
    pub max_cost_usd: Option<f64>,
}

impl Default for InferenceConfig {
    fn default() -> Self {
        Self {
            batch_size: 50,
            concurrency: 1,
            rate_limit_rpm: 10_000.0,
            rate_limit_tpm: 2_000_000.0,
            cache_policy: CachePolicy::Enabled,
            cache_skipping: true,
            max_retries: 3,
            retry_delay: 1.0,
            adaptive_rate_limits: false,
            max_cost_usd: None,
        }
    }
}

/// One metric to compute (paper §4.1). Resolved against the
/// [`crate::metrics::MetricRegistry`] at load time: the registry is the
/// single source of truth for names, families, and scales.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricConfig {
    /// Registry name, e.g. "exact_match", "bertscore", "faithfulness".
    pub name: String,
    /// Family: "lexical" | "semantic" | "llm_judge" | "rag" | "custom".
    pub metric_type: String,
    /// Metric-specific parameters (rubric, normalization flags, ...).
    pub params: BTreeMap<String, Json>,
}

impl MetricConfig {
    pub fn new(name: &str, metric_type: &str) -> Self {
        Self { name: name.into(), metric_type: metric_type.into(), params: BTreeMap::new() }
    }

    pub fn with_param(mut self, key: &str, value: Json) -> Self {
        self.params.insert(key.into(), value);
        self
    }

    pub fn param_str(&self, key: &str) -> Option<&str> {
        self.params.get(key).and_then(|v| v.as_str().ok())
    }

    pub fn param_bool(&self, key: &str, default: bool) -> bool {
        self.params.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.params.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }
}

/// CI method selection (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CiMethod {
    Percentile,
    Bca,
    Analytic,
}

impl CiMethod {
    pub fn as_str(self) -> &'static str {
        match self {
            CiMethod::Percentile => "percentile",
            CiMethod::Bca => "bca",
            CiMethod::Analytic => "analytic",
        }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "percentile" => CiMethod::Percentile,
            "bca" => CiMethod::Bca,
            "analytic" | "analytical" => CiMethod::Analytic,
            other => bail!("unknown ci method: {other}"),
        })
    }
}

/// Statistical parameters (paper §4.2–§4.4).
#[derive(Debug, Clone, PartialEq)]
pub struct StatisticsConfig {
    pub confidence_level: f64,
    pub bootstrap_iterations: usize,
    pub ci_method: CiMethod,
    /// Significance threshold for comparisons.
    pub alpha: f64,
    /// Permutation count for the bootstrap permutation test.
    pub permutations: usize,
    /// Seed for all stochastic statistics (bootstrap, permutation).
    pub seed: u64,
    /// Offload bootstrap resampling to the XLA artifact when shapes fit.
    pub use_device_bootstrap: bool,
}

impl Default for StatisticsConfig {
    fn default() -> Self {
        Self {
            confidence_level: 0.95,
            bootstrap_iterations: 1000,
            ci_method: CiMethod::Bca,
            alpha: 0.05,
            permutations: 1000,
            seed: 42,
            use_device_bootstrap: false,
        }
    }
}

/// Adaptive early-stopping configuration (Cer-Eval-style certifiable
/// cost-efficient evaluation): the runner issues inference and
/// pure-metric work in waves and stops once every metric's CI half-width
/// meets `ci_half_width` at level `alpha` under the sequential
/// correction. Absent from the task JSON = disabled = the classic
/// all-at-once run, bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct StoppingConfig {
    /// Target CI half-width ("± margin"): a metric is certified once its
    /// bootstrap/analytic CI half-width is at or below this.
    pub ci_half_width: f64,
    /// Total Type-I error budget for the certification across all waves.
    pub alpha: f64,
    /// Rows added per wave after the first look.
    pub wave_size: usize,
    /// Rows the first wave must cover before any stopping decision
    /// (guards against certifying on tiny-n degenerate CIs).
    pub min_rows: usize,
    /// Sequential correction: `true` (default) spends the alpha budget
    /// geometrically over looks (look k tests at alpha·2^-(k+1), union
    /// bound keeps the total ≤ alpha); `false` naively tests each look
    /// at full alpha (anytime validity is then NOT guaranteed).
    pub spend_alpha: bool,
}

impl Default for StoppingConfig {
    fn default() -> Self {
        Self {
            ci_half_width: 0.05,
            alpha: 0.05,
            wave_size: 200,
            min_rows: 50,
            spend_alpha: true,
        }
    }
}

impl StoppingConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.ci_half_width > 0.0) || !self.ci_half_width.is_finite() {
            bail!("stopping.ci_half_width must be a positive number");
        }
        if !(self.alpha > 0.0 && self.alpha < 1.0) {
            bail!("stopping.alpha must be in (0, 1)");
        }
        if self.wave_size == 0 {
            bail!("stopping.wave_size must be >= 1");
        }
        if self.min_rows < 2 {
            bail!("stopping.min_rows must be >= 2 (a CI needs n >= 2)");
        }
        Ok(())
    }

    /// The per-look significance level: look `k` (0-based) tests at
    /// `alpha · 2^-(k+1)` when spending, so the union bound over every
    /// look stays within the total `alpha` budget. Certifying at a
    /// stricter level implies certification at level `alpha`, so the
    /// scheme is conservative, never anti-conservative.
    pub fn look_alpha(&self, look: usize) -> f64 {
        if self.spend_alpha {
            // Floor keeps very deep looks from underflowing to a level
            // no CI method can meaningfully produce.
            (self.alpha * 0.5f64.powi(look.min(50) as i32 + 1)).max(1e-12)
        } else {
            self.alpha
        }
    }
}

/// Run-durability configuration: where (and whether) to checkpoint
/// completed scheduler tasks, and whether this run resumes an interrupted
/// one (see [`crate::checkpoint`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckpointConfig {
    /// Run directory for crash-safe task checkpoints. `None` disables
    /// checkpointing entirely.
    pub dir: Option<String>,
    /// Resume from `dir` instead of requiring it to be fresh: completed
    /// task ranges are restored from the manifest and only the gaps
    /// re-execute.
    pub resume: bool,
}

impl CheckpointConfig {
    pub fn validate(&self) -> Result<()> {
        if self.resume && self.dir.is_none() {
            bail!("checkpoint.resume requires checkpoint.dir");
        }
        Ok(())
    }
}

/// Input data binding (paper §3.4): column names + prompt template.
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// Jinja-style template rendered per example to build the prompt.
    pub prompt_template: String,
    /// Column holding the reference answer (empty = no reference).
    pub reference_column: String,
    /// Column holding retrieved context (RAG metrics).
    pub context_column: String,
    /// Column holding the original question (RAG metrics).
    pub question_column: String,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            prompt_template: "{{ prompt }}".into(),
            reference_column: "reference".into(),
            context_column: "context".into(),
            question_column: "question".into(),
        }
    }
}

/// The complete evaluation task specification.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalTask {
    pub task_id: String,
    pub model: ModelConfig,
    pub inference: InferenceConfig,
    pub metrics: Vec<MetricConfig>,
    pub statistics: StatisticsConfig,
    pub data: DataConfig,
    /// Number of parallel executors (Spark cluster size equivalent).
    pub executors: usize,
    /// Task scheduling behaviour: granularity, work stealing, speculative
    /// execution, retry/blacklist fault tolerance (see [`crate::sched`]).
    pub scheduler: SchedulerConfig,
    /// Run durability: task checkpointing and crash resumption.
    pub checkpoint: CheckpointConfig,
    /// Adaptive early stopping (`stopping` in the JSON): evaluate in
    /// waves and stop once every metric's CI half-width is certified at
    /// the target. `None` (the default) = the classic all-at-once run,
    /// bit for bit. See [`StoppingConfig`] and DESIGN.md
    /// "Adaptive stopping".
    pub stopping: Option<StoppingConfig>,
    /// Where executors physically run (`executor.backend` in the JSON):
    /// `thread` (default, in-process scoped threads — the pre-backend
    /// scheduler, bit for bit), `process` (one crash-isolated
    /// `slleval worker` OS process per executor; see
    /// [`crate::sched::backend`]), or `remote` (executors on
    /// `slleval serve-worker` hosts over TCP; see
    /// [`crate::sched::remote`]).
    pub backend: BackendKind,
    /// `slleval serve-worker` daemon addresses (`host:port`) for the
    /// remote backend (`executor.hosts` in the JSON, `--hosts` on the
    /// CLI). Executors are placed round-robin over this list.
    pub hosts: Vec<String>,
}

impl Default for EvalTask {
    fn default() -> Self {
        Self {
            task_id: "eval".into(),
            model: ModelConfig::default(),
            inference: InferenceConfig::default(),
            metrics: vec![MetricConfig::new("exact_match", "lexical")],
            statistics: StatisticsConfig::default(),
            data: DataConfig::default(),
            executors: 8,
            scheduler: SchedulerConfig::default(),
            checkpoint: CheckpointConfig::default(),
            stopping: None,
            backend: BackendKind::default(),
            hosts: Vec::new(),
        }
    }
}

impl EvalTask {
    /// Validate invariants that would otherwise fail deep inside a run.
    pub fn validate(&self) -> Result<()> {
        if self.task_id.is_empty() {
            bail!("task_id must be non-empty");
        }
        if self.executors == 0 {
            bail!("executors must be >= 1");
        }
        if self.inference.batch_size == 0 {
            bail!("batch_size must be >= 1");
        }
        if self.inference.concurrency == 0 {
            bail!("inference.concurrency must be >= 1");
        }
        if self.inference.rate_limit_rpm <= 0.0 || self.inference.rate_limit_tpm <= 0.0 {
            bail!("rate limits must be positive");
        }
        if let Some(budget) = self.inference.max_cost_usd {
            if budget <= 0.0 {
                bail!("max_cost_usd must be positive when set");
            }
        }
        if !(0.5..1.0).contains(&self.statistics.confidence_level) {
            bail!("confidence_level must be in [0.5, 1)");
        }
        if self.statistics.bootstrap_iterations < 10 {
            bail!("bootstrap_iterations must be >= 10");
        }
        if self.metrics.is_empty() {
            bail!("at least one metric is required");
        }
        for m in &self.metrics {
            match m.metric_type.as_str() {
                // Built-in families resolve against the shared registry
                // right here: a typo'd metric name fails at config load,
                // not after inference has already been paid for.
                "lexical" | "semantic" | "llm_judge" | "rag" => {
                    crate::metrics::builtin_registry().check(m)?;
                }
                // Custom metrics resolve against the runner's registry
                // (which carries user registrations) when a run starts.
                "custom" => {
                    if m.name.is_empty() {
                        bail!("custom metric with empty name");
                    }
                }
                t => bail!("unknown metric type '{t}' for metric '{}'", m.name),
            }
        }
        self.scheduler.validate()?;
        self.checkpoint.validate()?;
        if let Some(stopping) = &self.stopping {
            stopping.validate()?;
        }
        if self.backend == BackendKind::Remote && self.hosts.is_empty() {
            bail!(
                "the remote backend requires executor.hosts (or --hosts): \
                 addresses of running `slleval serve-worker` daemons"
            );
        }
        Ok(())
    }

    // -- JSON round trip -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task_id", Json::str(&self.task_id)),
            ("executors", Json::num(self.executors as f64)),
            (
                "model",
                Json::obj(vec![
                    ("provider", Json::str(&self.model.provider)),
                    ("model_name", Json::str(&self.model.model_name)),
                    ("temperature", Json::num(self.model.temperature)),
                    ("max_tokens", Json::num(self.model.max_tokens as f64)),
                ]),
            ),
            (
                "inference",
                Json::obj(vec![
                    ("batch_size", Json::num(self.inference.batch_size as f64)),
                    ("concurrency", Json::num(self.inference.concurrency as f64)),
                    ("rate_limit_rpm", Json::num(self.inference.rate_limit_rpm)),
                    ("rate_limit_tpm", Json::num(self.inference.rate_limit_tpm)),
                    ("cache_policy", Json::str(self.inference.cache_policy.as_str())),
                    ("cache_skipping", Json::Bool(self.inference.cache_skipping)),
                    ("max_retries", Json::num(self.inference.max_retries as f64)),
                    ("retry_delay", Json::num(self.inference.retry_delay)),
                    ("adaptive_rate_limits", Json::Bool(self.inference.adaptive_rate_limits)),
                    (
                        "max_cost_usd",
                        self.inference.max_cost_usd.map(Json::num).unwrap_or(Json::Null),
                    ),
                ]),
            ),
            (
                "metrics",
                Json::arr(
                    self.metrics
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("name", Json::str(&m.name)),
                                ("type", Json::str(&m.metric_type)),
                                ("params", Json::Obj(m.params.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "statistics",
                Json::obj(vec![
                    ("confidence_level", Json::num(self.statistics.confidence_level)),
                    (
                        "bootstrap_iterations",
                        Json::num(self.statistics.bootstrap_iterations as f64),
                    ),
                    ("ci_method", Json::str(self.statistics.ci_method.as_str())),
                    ("alpha", Json::num(self.statistics.alpha)),
                    ("permutations", Json::num(self.statistics.permutations as f64)),
                    ("seed", Json::num(self.statistics.seed as f64)),
                    ("use_device_bootstrap", Json::Bool(self.statistics.use_device_bootstrap)),
                ]),
            ),
            (
                "data",
                Json::obj(vec![
                    ("prompt_template", Json::str(&self.data.prompt_template)),
                    ("reference_column", Json::str(&self.data.reference_column)),
                    ("context_column", Json::str(&self.data.context_column)),
                    ("question_column", Json::str(&self.data.question_column)),
                ]),
            ),
            ("scheduler", self.scheduler.to_json()),
            (
                "executor",
                Json::obj(vec![
                    ("backend", Json::str(self.backend.as_str())),
                    (
                        "hosts",
                        Json::arr(self.hosts.iter().map(|h| Json::str(h.as_str())).collect()),
                    ),
                ]),
            ),
            (
                "checkpoint",
                Json::obj(vec![
                    (
                        "dir",
                        self.checkpoint.dir.as_deref().map(Json::str).unwrap_or(Json::Null),
                    ),
                    ("resume", Json::Bool(self.checkpoint.resume)),
                ]),
            ),
            (
                "stopping",
                self.stopping
                    .as_ref()
                    .map(|s| {
                        Json::obj(vec![
                            ("ci_half_width", Json::num(s.ci_half_width)),
                            ("alpha", Json::num(s.alpha)),
                            ("wave_size", Json::num(s.wave_size as f64)),
                            ("min_rows", Json::num(s.min_rows as f64)),
                            ("spend_alpha", Json::Bool(s.spend_alpha)),
                        ])
                    })
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<EvalTask> {
        let mut task = EvalTask {
            task_id: v.get("task_id")?.as_str()?.to_string(),
            executors: v.usize_or("executors", 8),
            ..EvalTask::default()
        };

        if let Some(m) = v.opt("model") {
            task.model = ModelConfig {
                provider: m.str_or("provider", "openai").to_string(),
                model_name: m.str_or("model_name", "gpt-4o").to_string(),
                temperature: m.f64_or("temperature", 0.0),
                max_tokens: m.usize_or("max_tokens", 1024),
            };
        }
        if let Some(i) = v.opt("inference") {
            task.inference = InferenceConfig {
                batch_size: i.usize_or("batch_size", 50),
                concurrency: i.usize_or("concurrency", 1),
                rate_limit_rpm: i.f64_or("rate_limit_rpm", 10_000.0),
                rate_limit_tpm: i.f64_or("rate_limit_tpm", 2_000_000.0),
                cache_policy: CachePolicy::from_str(i.str_or("cache_policy", "enabled"))?,
                cache_skipping: i.bool_or("cache_skipping", true),
                max_retries: i.usize_or("max_retries", 3),
                retry_delay: i.f64_or("retry_delay", 1.0),
                adaptive_rate_limits: i.bool_or("adaptive_rate_limits", false),
                max_cost_usd: i.opt("max_cost_usd").and_then(|v| v.as_f64().ok()),
            };
        }
        if let Some(ms) = v.opt("metrics") {
            task.metrics = ms
                .as_arr()?
                .iter()
                .map(|m| -> Result<MetricConfig, JsonError> {
                    Ok(MetricConfig {
                        name: m.get("name")?.as_str()?.to_string(),
                        metric_type: m.str_or("type", "lexical").to_string(),
                        params: m
                            .opt("params")
                            .map(|p| p.as_obj().cloned())
                            .transpose()?
                            .unwrap_or_default(),
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        if let Some(s) = v.opt("statistics") {
            task.statistics = StatisticsConfig {
                confidence_level: s.f64_or("confidence_level", 0.95),
                bootstrap_iterations: s.usize_or("bootstrap_iterations", 1000),
                ci_method: CiMethod::from_str(s.str_or("ci_method", "bca"))?,
                alpha: s.f64_or("alpha", 0.05),
                permutations: s.usize_or("permutations", 1000),
                seed: s.f64_or("seed", 42.0) as u64,
                use_device_bootstrap: s.bool_or("use_device_bootstrap", false),
            };
        }
        if let Some(d) = v.opt("data") {
            task.data = DataConfig {
                prompt_template: d.str_or("prompt_template", "{{ prompt }}").to_string(),
                reference_column: d.str_or("reference_column", "reference").to_string(),
                context_column: d.str_or("context_column", "context").to_string(),
                question_column: d.str_or("question_column", "question").to_string(),
            };
        }
        if let Some(s) = v.opt("scheduler") {
            task.scheduler = SchedulerConfig::from_json(s)?;
        }
        if let Some(e) = v.opt("executor") {
            task.backend = BackendKind::from_str(e.str_or("backend", "thread"))?;
            if let Some(hosts) = e.opt("hosts") {
                task.hosts = hosts
                    .as_arr()?
                    .iter()
                    .map(|h| -> Result<String, JsonError> { Ok(h.as_str()?.to_string()) })
                    .collect::<Result<Vec<_>, _>>()?;
            }
        }
        if let Some(c) = v.opt("checkpoint") {
            task.checkpoint = CheckpointConfig {
                dir: c.opt("dir").and_then(|d| d.as_str().ok()).map(String::from),
                resume: c.bool_or("resume", false),
            };
        }
        if let Some(s) = v.opt("stopping") {
            let default = StoppingConfig::default();
            task.stopping = Some(StoppingConfig {
                ci_half_width: s.f64_or("ci_half_width", default.ci_half_width),
                alpha: s.f64_or("alpha", default.alpha),
                wave_size: s.usize_or("wave_size", default.wave_size),
                min_rows: s.usize_or("min_rows", default.min_rows),
                spend_alpha: s.bool_or("spend_alpha", default.spend_alpha),
            });
        }
        task.validate()?;
        Ok(task)
    }

    pub fn from_file(path: &std::path::Path) -> Result<EvalTask> {
        let text = std::fs::read_to_string(path)?;
        EvalTask::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }
}

/// `slleval serve` daemon configuration (see [`crate::serve`] and
/// DESIGN.md "Eval service"). Loaded from `--config serve.json`, with
/// individual CLI flags overriding fields.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address, `host:port` (port 0 binds a free port; the
    /// daemon prints the resolved address on startup).
    pub listen: String,
    /// Response-cache directory shared by every run over the daemon's
    /// lifetime — the multi-tenant "resubmit pays zero inference"
    /// guarantee. `None` runs without a shared cache.
    pub cache_dir: Option<String>,
    /// Policy the shared cache is opened with. Each run's own
    /// `inference.cache_policy` still governs its lookups and writes.
    pub cache_policy: CachePolicy,
    /// Maximum accepted HTTP request body, bytes (task submissions are
    /// small; this bounds hostile or accidental floods).
    pub max_body_bytes: usize,
    /// Fast mode: virtual clock, simulated latency accounted but not
    /// slept — the CI/test configuration.
    pub fast: bool,
    /// Multiplier on simulated provider latency when running live
    /// (ignored in fast mode).
    pub latency_scale: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7464".into(),
            cache_dir: None,
            cache_policy: CachePolicy::Enabled,
            max_body_bytes: 8 * 1024 * 1024,
            fast: false,
            latency_scale: 1.0,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("listen", Json::str(&self.listen)),
            ("cache_dir", self.cache_dir.as_deref().map(Json::str).unwrap_or(Json::Null)),
            ("cache_policy", Json::str(self.cache_policy.as_str())),
            ("max_body_bytes", Json::num(self.max_body_bytes as f64)),
            ("fast", Json::Bool(self.fast)),
            ("latency_scale", Json::num(self.latency_scale)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ServeConfig> {
        let default = ServeConfig::default();
        let cfg = ServeConfig {
            listen: v.str_or("listen", &default.listen).to_string(),
            cache_dir: v.opt("cache_dir").and_then(|d| d.as_str().ok()).map(String::from),
            cache_policy: CachePolicy::from_str(
                v.str_or("cache_policy", default.cache_policy.as_str()),
            )?,
            max_body_bytes: v.usize_or("max_body_bytes", default.max_body_bytes),
            fast: v.bool_or("fast", default.fast),
            latency_scale: v.f64_or("latency_scale", default.latency_scale),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        ServeConfig::from_json(&Json::parse(&text)?)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.listen.contains(':') {
            bail!("serve listen address must be host:port, got {:?}", self.listen);
        }
        if self.max_body_bytes < 1024 {
            bail!("serve max_body_bytes must be >= 1024, got {}", self.max_body_bytes);
        }
        if self.latency_scale <= 0.0 || !self.latency_scale.is_finite() {
            bail!("serve latency_scale must be a positive number, got {}", self.latency_scale);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        EvalTask::default().validate().unwrap();
    }

    #[test]
    fn json_round_trip() {
        let mut task = EvalTask::default();
        task.task_id = "instruction-following-eval".into();
        task.metrics = vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("bertscore", "semantic"),
            MetricConfig::new("helpfulness", "llm_judge")
                .with_param("rubric", Json::str("Rate helpfulness 1-5")),
        ];
        task.inference.cache_policy = CachePolicy::Replay;
        task.statistics.ci_method = CiMethod::Percentile;
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);
    }

    #[test]
    fn serve_config_round_trip_and_defaults() {
        let mut cfg = ServeConfig::default();
        cfg.listen = "0.0.0.0:9000".into();
        cfg.cache_dir = Some("/tmp/serve-cache".into());
        cfg.cache_policy = CachePolicy::ReadOnly;
        cfg.fast = true;
        cfg.latency_scale = 0.25;
        let restored = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, restored);
        // An empty object parses to the defaults.
        let parsed = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(parsed, ServeConfig::default());
        assert!(parsed.cache_dir.is_none());
    }

    #[test]
    fn serve_config_validation_rejects_bad_fields() {
        let mut cfg = ServeConfig::default();
        cfg.listen = "no-port".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ServeConfig::default();
        cfg.max_body_bytes = 10;
        assert!(cfg.validate().is_err());
        let mut cfg = ServeConfig::default();
        cfg.latency_scale = 0.0;
        assert!(cfg.validate().is_err());
        assert!(ServeConfig::from_json(&Json::parse("{\"latency_scale\": -1}").unwrap()).is_err());
    }

    #[test]
    fn cache_policy_semantics() {
        assert!(CachePolicy::Enabled.reads() && CachePolicy::Enabled.writes());
        assert!(CachePolicy::ReadOnly.reads() && !CachePolicy::ReadOnly.writes());
        assert!(!CachePolicy::WriteOnly.reads() && CachePolicy::WriteOnly.writes());
        assert!(CachePolicy::Replay.reads() && !CachePolicy::Replay.writes());
        assert!(!CachePolicy::Disabled.reads() && !CachePolicy::Disabled.writes());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut t = EvalTask::default();
        t.executors = 0;
        assert!(t.validate().is_err());

        let mut t = EvalTask::default();
        t.statistics.confidence_level = 1.5;
        assert!(t.validate().is_err());

        let mut t = EvalTask::default();
        t.metrics.clear();
        assert!(t.validate().is_err());

        let mut t = EvalTask::default();
        t.metrics = vec![MetricConfig::new("x", "bogus_type")];
        assert!(t.validate().is_err());
    }

    #[test]
    fn metric_names_resolve_at_load_time() {
        // Unknown names in built-in families fail validate() (and thus
        // from_json), not deep inside a run after inference spend.
        let mut t = EvalTask::default();
        t.metrics = vec![MetricConfig::new("exact_matchh", "lexical")];
        let err = t.validate().unwrap_err();
        assert!(format!("{err}").contains("unknown metric"), "{err}");
        assert!(EvalTask::from_json(&t.to_json()).is_err());

        // Any name is a valid pointwise judge; custom names defer to the
        // runner's registry.
        let mut t = EvalTask::default();
        t.metrics = vec![
            MetricConfig::new("helpfulness", "llm_judge"),
            MetricConfig::new("my_scorer", "custom"),
        ];
        t.validate().unwrap();
        assert_eq!(EvalTask::from_json(&t.to_json()).unwrap(), t);
    }

    #[test]
    fn scheduler_config_round_trips_and_validates() {
        let mut task = EvalTask::default();
        task.scheduler.tasks_per_executor = 9;
        task.scheduler.speculation = false;
        task.scheduler.max_task_attempts = 5;
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        let mut bad = EvalTask::default();
        bad.scheduler.tasks_per_executor = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn checkpoint_and_budget_round_trip_and_validate() {
        let mut task = EvalTask::default();
        task.checkpoint = CheckpointConfig { dir: Some("runs/ckpt-7".into()), resume: true };
        task.inference.max_cost_usd = Some(12.5);
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        // Defaults (no checkpoint, no budget) survive too.
        let plain = EvalTask::default();
        assert_eq!(EvalTask::from_json(&plain.to_json()).unwrap(), plain);

        let mut bad = EvalTask::default();
        bad.checkpoint.resume = true; // resume without a dir
        assert!(bad.validate().is_err());

        let mut bad = EvalTask::default();
        bad.inference.max_cost_usd = Some(0.0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn concurrency_round_trips_and_validates() {
        let mut task = EvalTask::default();
        assert_eq!(task.inference.concurrency, 1, "default must be the sequential path");
        task.inference.concurrency = 8;
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        // A task file that predates the field parses to concurrency 1.
        let mut json = task.to_json();
        if let Json::Obj(map) = &mut json {
            if let Some(Json::Obj(inf)) = map.get_mut("inference") {
                inf.remove("concurrency");
            }
        }
        assert_eq!(EvalTask::from_json(&json).unwrap().inference.concurrency, 1);

        let mut bad = EvalTask::default();
        bad.inference.concurrency = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn cache_skipping_round_trips_and_defaults_on() {
        let mut task = EvalTask::default();
        assert!(task.inference.cache_skipping, "skipping is the default read path");
        task.inference.cache_skipping = false;
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        // A task file that predates the field parses with skipping on.
        let mut json = task.to_json();
        if let Json::Obj(map) = &mut json {
            if let Some(Json::Obj(inf)) = map.get_mut("inference") {
                inf.remove("cache_skipping");
            }
        }
        assert!(EvalTask::from_json(&json).unwrap().inference.cache_skipping);
    }

    #[test]
    fn stopping_round_trips_and_defaults_to_none() {
        // No `stopping` block = disabled, and the default task
        // round-trips with it still disabled (the bit-identity contract).
        let plain = EvalTask::default();
        assert!(plain.stopping.is_none());
        let restored = EvalTask::from_json(&plain.to_json()).unwrap();
        assert!(restored.stopping.is_none());
        assert_eq!(plain, restored);

        let mut task = EvalTask::default();
        task.stopping = Some(StoppingConfig {
            ci_half_width: 0.02,
            alpha: 0.1,
            wave_size: 150,
            min_rows: 60,
            spend_alpha: false,
        });
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        // An empty `{"stopping": {}}` block enables stopping with the
        // documented defaults.
        let mut json = EvalTask::default().to_json();
        if let Json::Obj(map) = &mut json {
            map.insert("stopping".into(), Json::obj(vec![]));
        }
        let parsed = EvalTask::from_json(&json).unwrap();
        assert_eq!(parsed.stopping, Some(StoppingConfig::default()));
    }

    #[test]
    fn stopping_validation_rejects_bad_fields() {
        let mut t = EvalTask::default();
        t.stopping = Some(StoppingConfig { ci_half_width: 0.0, ..Default::default() });
        assert!(t.validate().is_err());
        t.stopping = Some(StoppingConfig { alpha: 1.0, ..Default::default() });
        assert!(t.validate().is_err());
        t.stopping = Some(StoppingConfig { wave_size: 0, ..Default::default() });
        assert!(t.validate().is_err());
        t.stopping = Some(StoppingConfig { min_rows: 1, ..Default::default() });
        assert!(t.validate().is_err());
    }

    #[test]
    fn alpha_spending_schedule_is_geometric_and_bounded() {
        let s = StoppingConfig::default();
        assert!((s.look_alpha(0) - 0.025).abs() < 1e-15);
        assert!((s.look_alpha(1) - 0.0125).abs() < 1e-15);
        // The union bound over all looks stays within alpha.
        let total: f64 = (0..40).map(|k| s.look_alpha(k)).sum();
        assert!(total <= s.alpha + 1e-12, "spent {total} > alpha {}", s.alpha);
        // Deep looks never underflow to zero.
        assert!(s.look_alpha(500) > 0.0);
        // spend_alpha = false tests every look at full alpha.
        let naive = StoppingConfig { spend_alpha: false, ..Default::default() };
        assert_eq!(naive.look_alpha(7), naive.alpha);
    }

    #[test]
    fn unknown_policy_errors() {
        assert!(CachePolicy::from_str("fuzzy").is_err());
        assert!(CiMethod::from_str("magic").is_err());
    }

    #[test]
    fn executor_backend_round_trips_and_defaults_to_thread() {
        let mut task = EvalTask::default();
        assert_eq!(task.backend, BackendKind::Thread, "thread must stay the default");
        task.backend = BackendKind::Process;
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);

        // A task file that predates the field parses to the thread backend.
        let mut json = task.to_json();
        if let Json::Obj(map) = &mut json {
            map.remove("executor");
        }
        assert_eq!(EvalTask::from_json(&json).unwrap().backend, BackendKind::Thread);

        // Unknown backend names fail at load time.
        let mut json = task.to_json();
        if let Json::Obj(map) = &mut json {
            map.insert("executor".into(), Json::obj(vec![("backend", Json::str("bogus"))]));
        }
        assert!(EvalTask::from_json(&json).is_err());
    }

    #[test]
    fn remote_backend_requires_and_round_trips_hosts() {
        // Remote without hosts is rejected at validation.
        let mut task = EvalTask::default();
        task.backend = BackendKind::Remote;
        let err = task.validate().unwrap_err();
        assert!(format!("{err}").contains("hosts"), "{err}");

        // With hosts, the executor section round-trips through JSON.
        task.hosts = vec!["10.0.0.1:7077".into(), "10.0.0.2:7077".into()];
        task.validate().unwrap();
        let restored = EvalTask::from_json(&task.to_json()).unwrap();
        assert_eq!(task, restored);
        assert_eq!(restored.hosts.len(), 2);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("slleval-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("task.json");
        let task = EvalTask::default();
        task.save(&path).unwrap();
        let restored = EvalTask::from_file(&path).unwrap();
        assert_eq!(task, restored);
    }
}
