//! Minimal benchmarking harness (no `criterion` in the offline crate
//! set). Used by the `harness = false` bench targets: warmup + timed
//! iterations, mean / stddev / throughput reporting, and a simple
//! regression guard via environment baseline files.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Items/second given per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with automatic iteration-count calibration targeting
/// ~`target_ms` of total measurement.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64() * 1e9;
    let iters = ((target_ms * 1e6 / first.max(1.0)).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len().max(2) as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    };
    println!(
        "bench {:<44} {:>12} ± {:>10}  (min {:>10}, {} iters)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.std_ns),
        fmt_ns(r.min_ns),
        r.iters
    );
    r
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n==== {title} ====");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let r = bench("noop-ish", 5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            std_ns: 0.0,
            min_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
        assert!((r.mean_ms() - 1000.0).abs() < 1e-9);
    }
}
