//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and a
//! subcommand convention used by `main.rs`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();

        // First non-option token is the subcommand.
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                args.subcommand = iter.next();
            }
        }

        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn parse_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --config eval.json --executors 8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("config"), Some("eval.json"));
        assert_eq!(a.get_usize("executors", 1), 8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --table=3 --size=10000");
        assert_eq!(a.get("table"), Some("3"));
        assert_eq!(a.get_usize("size", 0), 10000);
    }

    #[test]
    fn flag_before_end() {
        let a = parse("run --dry-run --config x.json");
        // --dry-run consumes no value because the next token starts with --.
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get("config"), Some("x.json"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("missing", 2.5), 2.5);
        assert!(!a.has_flag("missing"));
    }

    #[test]
    fn no_subcommand_when_option_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }

    #[test]
    fn positional_args() {
        let a = parse("replay path/to/cache other");
        assert_eq!(a.subcommand.as_deref(), Some("replay"));
        assert_eq!(a.positional, vec!["path/to/cache", "other"]);
    }
}
