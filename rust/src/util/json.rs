//! Minimal self-contained JSON parser / writer.
//!
//! The offline crate set has no `serde_json`, so the config system, the
//! Delta transaction log, the artifact manifest, and the tracking store
//! all share this implementation. Supports the full JSON grammar plus
//! pretty-printing; numbers are kept as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — the cache keys and config hashes rely on this.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, found: &'static str },
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type { expected, found } => {
                write!(f, "json type error: expected {expected}, found {found}")
            }
            JsonError::MissingKey(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { expected: "string", found: other.type_name() }),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { expected: "number", found: other.type_name() }),
        }
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { expected: "bool", found: other.type_name() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { expected: "array", found: other.type_name() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { expected: "object", found: other.type_name() }),
        }
    }

    /// Fetch a required key from an object.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Fetch an optional key (None if absent or null).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => match o.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).and_then(|v| v.as_str().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.as_f64().ok()).map(|f| f as usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.opt(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    // -- constructors --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{}", n));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("invalid surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn roundtrip_escapes() {
        let original = Json::Str("line1\nline2\t\"quoted\" \\ end".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn roundtrip_unicode() {
        let v = Json::parse(r#""café 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀");
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn deterministic_object_order() {
        let a = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let b = Json::parse(r#"{"a": 2, "z": 1}"#).unwrap();
        assert_eq!(a.to_string(), b.to_string());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn accessors_and_defaults() {
        let v = Json::parse(r#"{"s": "x", "n": 7, "b": true}"#).unwrap();
        assert_eq!(v.str_or("s", "d"), "x");
        assert_eq!(v.str_or("missing", "d"), "d");
        assert_eq!(v.usize_or("n", 0), 7);
        assert_eq!(v.f64_or("missing", 1.5), 1.5);
        assert!(v.bool_or("b", false));
        assert!(v.get("missing").is_err());
    }
}
