//! Crash-safe filesystem publication primitives, shared by the Delta-protocol
//! transaction log and the run-checkpoint store.
//!
//! The discipline: content is always written to a hidden temp file in the
//! destination directory first, then *published* to its final name in one
//! atomic step, so readers see either nothing or the complete content —
//! never a partial file.
//!
//! Two publication modes:
//!
//! - [`write_atomic`] — last writer wins (`rename(2)` semantics). For
//!   files that are legitimately re-writable, e.g. stage metadata.
//! - [`publish_exclusive`] — first writer wins. Publication is a
//!   `link(2)` call, which (unlike `rename(2)` on Linux, which silently
//!   replaces an existing destination) fails with `EEXIST` when the
//!   destination already exists. This gives O_EXCL-style exclusivity *and*
//!   full-content atomicity in one step: a racing loser gets a
//!   [`Publish::Conflict`], and a crash at any point leaves either no
//!   destination file or a complete one — never a claimed-but-empty slot.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Outcome of an exclusive publication attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Publish {
    /// This writer's content is now at the destination.
    Committed,
    /// Another writer already published this destination; our content was
    /// discarded.
    Conflict,
}

/// Process-unique discriminator so concurrent writers (threads *and*
/// processes) never collide on temp-file names.
pub fn unique_suffix() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    format!("{}-{}", std::process::id(), SEQ.fetch_add(1, Ordering::Relaxed))
}

fn temp_sibling(final_path: &Path) -> PathBuf {
    let dir = final_path.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
    let name = final_path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    dir.join(format!(".tmp-{}-{}", unique_suffix(), name))
}

/// Atomically write `bytes` to `final_path` (write temp + rename). An
/// existing destination is replaced; readers never observe partial content.
pub fn write_atomic(final_path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = temp_sibling(final_path);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {tmp:?}"))?;
    let renamed = std::fs::rename(&tmp, final_path)
        .with_context(|| format!("publishing {final_path:?}"));
    if renamed.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    renamed
}

/// Atomically publish `bytes` at `final_path` iff nothing exists there yet.
/// Exactly one of any number of racing writers gets [`Publish::Committed`];
/// the rest get [`Publish::Conflict`] and the committed content is left
/// untouched. IO failures (as opposed to losing the race) are `Err`.
pub fn publish_exclusive(final_path: &Path, bytes: &[u8]) -> Result<Publish> {
    let tmp = temp_sibling(final_path);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {tmp:?}"))?;
    let outcome = match std::fs::hard_link(&tmp, final_path) {
        Ok(()) => Ok(Publish::Committed),
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(Publish::Conflict),
        Err(e) => Err(e).with_context(|| format!("claiming {final_path:?}")),
    };
    let _ = std::fs::remove_file(&tmp);
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-fsx-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_atomic_replaces() {
        let dir = tmp_dir("atomic");
        let path = dir.join("f.txt");
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        // No temp litter.
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with(".tmp-")
            })
            .collect();
        assert!(litter.is_empty());
    }

    #[test]
    fn exclusive_first_writer_wins() {
        let dir = tmp_dir("excl");
        let path = dir.join("v0.json");
        assert_eq!(publish_exclusive(&path, b"winner").unwrap(), Publish::Committed);
        assert_eq!(publish_exclusive(&path, b"loser").unwrap(), Publish::Conflict);
        assert_eq!(std::fs::read(&path).unwrap(), b"winner");
    }

    #[test]
    fn exclusive_race_exactly_one_commits() {
        let dir = tmp_dir("race");
        let path = dir.join("claimed.json");
        let committed: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let path = path.clone();
                    scope.spawn(move || {
                        let body = format!("writer-{i}");
                        publish_exclusive(&path, body.as_bytes()).unwrap()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|p| *p == Publish::Committed)
                .count()
        });
        assert_eq!(committed, 1);
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("writer-"), "{content}");
    }
}
