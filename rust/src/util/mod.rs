//! Shared utilities: JSON, PRNG, CLI parsing, property-test harness, misc.

pub mod bench;
pub mod cli;
pub mod fsx;
pub mod json;
pub mod proptest;
pub mod rng;

/// Format a f64 with fixed decimals, trimming "-0.000" to "0.000".
pub fn fmt_f(v: f64, decimals: usize) -> String {
    let s = format!("{v:.decimals$}");
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

/// Monotonic milliseconds since process start (coarse wall timing).
pub fn now_ms() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    // lint:allow(determinism): this IS the wall-clock telemetry helper
    START.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}

/// Wall-clock unix timestamp in seconds.
pub fn unix_ts() -> f64 {
    // lint:allow(determinism): this IS the wall-clock timestamp helper
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}
