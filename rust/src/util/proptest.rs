//! Hand-rolled property-testing harness (no `proptest` crate offline).
//!
//! A property is a closure over a seeded [`Rng`]; the harness runs it for a
//! fixed number of cases and, on failure, reports the failing seed so the
//! case can be replayed deterministically:
//!
//! ```ignore
//! check("bucket never exceeds limit", 256, |rng| {
//!     let limit = 1 + rng.below(100);
//!     ...
//!     ensure(used <= limit, format!("used {used} > limit {limit}"))
//! });
//! ```

use crate::util::rng::Rng;

/// Result of one property case: `Ok(())` or a human-readable failure.
pub type PropResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper for property bodies.
pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> PropResult {
    ensure(
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())),
        format!("{ctx}: {a} !≈ {b} (tol {tol})"),
    )
}

/// Run `cases` random cases of `prop`. Panics (test failure) with the
/// failing seed on the first counterexample.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(name: &str, cases: u64, mut prop: F) {
    // Base seed is stable so CI failures reproduce; override with
    // SLLEVAL_PROP_SEED to explore other schedules.
    let base: u64 = std::env::var("SLLEVAL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5ca1ab1e);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay: SLLEVAL_PROP_SEED={base} (case index {case})"
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random ASCII-ish sentence of 0..max_words words.
    pub fn sentence(rng: &mut Rng, max_words: usize) -> String {
        const WORDS: &[&str] = &[
            "the", "quick", "brown", "fox", "jumps", "over", "lazy", "dog",
            "paris", "capital", "france", "model", "answer", "question",
            "context", "token", "rate", "limit", "cache", "delta", "spark",
            "eval", "metric", "bootstrap", "sample", "york", "city",
        ];
        let n = rng.below(max_words + 1);
        (0..n)
            .map(|_| *rng.choose(WORDS))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Vector of f64 drawn from a mixture of scales (exercises skew).
    pub fn values(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match rng.below(3) {
                0 => rng.f64(),
                1 => rng.normal_with(0.5, 0.2),
                _ => rng.lognormal(0.0, 0.5) * 0.1,
            })
            .collect()
    }

    /// Vector of 0/1 outcomes with random base rate.
    pub fn binary(rng: &mut Rng, n: usize) -> Vec<f64> {
        let p = rng.f64();
        (0..n).map(|_| if rng.chance(p) { 1.0 } else { 0.0 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("x + 0 == x", 64, |rng| {
            let x = rng.f64();
            ensure((x + 0.0 - x).abs() < 1e-15, "identity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        check("sentence words bounded", 64, |rng| {
            let s = gen::sentence(rng, 12);
            ensure(s.split_whitespace().count() <= 12, "word count")
        });
        check("binary is 0/1", 64, |rng| {
            let b = gen::binary(rng, 50);
            ensure(b.iter().all(|&x| x == 0.0 || x == 1.0), "binary values")
        });
    }
}
