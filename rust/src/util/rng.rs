//! Deterministic PRNG + distributions.
//!
//! PCG64 (O'Neill 2014, XSL-RR variant) — the offline crate set has no
//! `rand`, and determinism across the simulator, the bootstrap, and the
//! property-test harness matters more than cryptographic quality.

/// PCG64 XSL-RR generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-executor streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::with_stream(self.next_u64(), stream.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (single value; the pair is dropped —
    /// simplicity over throughput; the bootstrap hot path uses `below`).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let mut u = self.f64();
        if u >= 1.0 {
            u = 1.0 - 1e-16;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose an element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` indices from [0, n) without replacement (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_bounds() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_positive_and_median() {
        let mut rng = Rng::new(13);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.lognormal(0.0, 0.5)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 1.0).abs() < 0.02, "median={median}"); // exp(mu)=1
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_without_replacement() {
        let mut rng = Rng::new(23);
        let s = rng.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 30);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
