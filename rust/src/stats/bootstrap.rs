//! Bootstrap resampling engine.
//!
//! Native Rust implementation (hot path: index sampling + statistic reuse
//! of a scratch buffer). The coordinator can offload mean-bootstraps to the
//! XLA `bootstrap.hlo` artifact when shapes fit (see
//! `runtime::SemanticRuntime::bootstrap_means`); this module is the
//! fallback and the reference.

use crate::util::rng::Rng;

/// Draw `iterations` bootstrap resamples of `values` and return the
/// statistic of each resample.
pub fn bootstrap_statistics<F: Fn(&[f64]) -> f64>(
    values: &[f64],
    stat: &F,
    iterations: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let n = values.len();
    if n == 0 {
        return vec![f64::NAN; iterations];
    }
    let mut out = Vec::with_capacity(iterations);
    let mut scratch = vec![0.0; n];
    for _ in 0..iterations {
        for slot in scratch.iter_mut() {
            *slot = values[rng.below(n)];
        }
        out.push(stat(&scratch));
    }
    out
}

/// Fast path for the mean statistic: accumulate directly, no scratch
/// buffer or closure dispatch. Identical distribution to
/// `bootstrap_statistics(values, &mean, ...)`.
pub fn bootstrap_means(values: &[f64], iterations: usize, rng: &mut Rng) -> Vec<f64> {
    let n = values.len();
    if n == 0 {
        return vec![f64::NAN; iterations];
    }
    let inv_n = 1.0 / n as f64;
    let mut out = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += values[rng.below(n)];
        }
        out.push(acc * inv_n);
    }
    out
}

/// Leave-one-out jackknife statistics (BCa acceleration).
pub fn jackknife_statistics<F: Fn(&[f64]) -> f64>(values: &[f64], stat: &F) -> Vec<f64> {
    let n = values.len();
    if n < 2 {
        return vec![stat(values)];
    }
    let mut out = Vec::with_capacity(n);
    let mut scratch = Vec::with_capacity(n - 1);
    for i in 0..n {
        scratch.clear();
        scratch.extend_from_slice(&values[..i]);
        scratch.extend_from_slice(&values[i + 1..]);
        out.push(stat(&scratch));
    }
    out
}

/// Jackknife means without re-summing: O(n) total.
pub fn jackknife_means(values: &[f64]) -> Vec<f64> {
    let n = values.len();
    if n < 2 {
        return values.to_vec();
    }
    let total: f64 = values.iter().sum();
    let inv = 1.0 / (n - 1) as f64;
    values.iter().map(|v| (total - v) * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::describe::{mean, std_dev};

    #[test]
    fn bootstrap_mean_distribution() {
        // Bootstrap means center on the sample mean with sd ≈ sem.
        let mut rng = Rng::new(1);
        let values: Vec<f64> = (0..200).map(|_| rng.normal_with(5.0, 2.0)).collect();
        let m = mean(&values);
        let sem = std_dev(&values) / (values.len() as f64).sqrt();
        let boots = bootstrap_means(&values, 4000, &mut rng);
        let bm = mean(&boots);
        let bsd = std_dev(&boots);
        assert!((bm - m).abs() < 3.0 * sem / (4000f64).sqrt() + 0.01, "bm {bm} m {m}");
        assert!((bsd - sem).abs() / sem < 0.1, "bsd {bsd} sem {sem}");
    }

    #[test]
    fn fast_and_generic_paths_agree_statistically() {
        let mut rng = Rng::new(2);
        let values: Vec<f64> = (0..100).map(|_| rng.f64()).collect();
        let mut r1 = Rng::new(3);
        let fast = bootstrap_means(&values, 2000, &mut r1);
        let mut r2 = Rng::new(3);
        let gen = bootstrap_statistics(&values, &mean, 2000, &mut r2);
        // Same RNG stream and same index draws → identical sequences.
        for (f, g) in fast.iter().zip(&gen) {
            assert!((f - g).abs() < 1e-12);
        }
    }

    #[test]
    fn jackknife_means_match_generic() {
        let values = [1.0, 2.0, 3.0, 4.0, 5.5];
        let fast = jackknife_means(&values);
        let gen = jackknife_statistics(&values, &mean);
        for (f, g) in fast.iter().zip(&gen) {
            assert!((f - g).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_input() {
        let mut rng = Rng::new(0);
        let b = bootstrap_means(&[], 5, &mut rng);
        assert_eq!(b.len(), 5);
        assert!(b.iter().all(|x| x.is_nan()));
    }

    #[test]
    fn constant_values_constant_bootstrap() {
        let mut rng = Rng::new(4);
        let b = bootstrap_means(&[7.0; 30], 100, &mut rng);
        assert!(b.iter().all(|&x| (x - 7.0).abs() < 1e-12));
    }
}
