//! Special functions underpinning the statistics stack.
//!
//! Implemented from scratch (no scipy here): ln-gamma (Lanczos),
//! regularized incomplete beta/gamma, erf, and the distribution CDFs /
//! quantiles built on them (normal, Student-t, chi-squared). Accuracy is
//! validated against scipy-generated fixtures in `stats_golden.rs`.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEFFS[0];
        let t = x + 7.5;
        for (i, &c) in COEFFS.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Regularized incomplete beta I_x(a, b) via the continued fraction
/// (Numerical Recipes `betai`/`betacf`).
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "beta_inc requires positive parameters");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - front * betacf(b, a, 1.0 - x) / b
    }
}

fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized lower incomplete gamma P(a, x) (series + continued fraction).
pub fn gamma_inc(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series representation.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..300 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 3e-16 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // Continued fraction for Q(a,x); P = 1 - Q.
        const FPMIN: f64 = 1e-300;
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / FPMIN;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..300 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < FPMIN {
                d = FPMIN;
            }
            c = b + an / c;
            if c.abs() < FPMIN {
                c = FPMIN;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 3e-16 {
                break;
            }
        }
        1.0 - h * (-x + a * x.ln() - ln_gamma(a)).exp()
    }
}

/// Error function (Abramowitz–Stegun 7.1.26-style rational approx is not
/// accurate enough; use the incomplete gamma identity instead).
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let v = gamma_inc(0.5, x * x);
    if x > 0.0 {
        v
    } else {
        -v
    }
}

/// Standard normal CDF.
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Standard normal quantile (Acklam's inverse-CDF approximation, refined
/// with one Halley step — |rel err| < 1e-9 over (0,1)).
pub fn normal_ppf(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "p out of range: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley refinement step.
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Student-t CDF with `df` degrees of freedom.
pub fn t_cdf(t: f64, df: f64) -> f64 {
    assert!(df > 0.0);
    if t == 0.0 {
        return 0.5;
    }
    let x = df / (df + t * t);
    let p = 0.5 * beta_inc(df / 2.0, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Two-sided p-value for a t statistic.
pub fn t_sf_two_sided(t: f64, df: f64) -> f64 {
    (2.0 * (1.0 - t_cdf(t.abs(), df))).clamp(0.0, 1.0)
}

/// Student-t quantile (bisection on the CDF; adequate for CI bounds).
pub fn t_ppf(p: f64, df: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p));
    if p == 0.5 {
        return 0.0;
    }
    // Bracket with the normal quantile scaled generously.
    let z = normal_ppf(p);
    let mut lo = z.abs().mul_add(-10.0, -1.0).min(-1e3);
    let mut hi = z.abs().mul_add(10.0, 1.0).max(1e3);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if t_cdf(mid, df) < p {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-12 * (1.0 + hi.abs()) {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// Chi-squared CDF with `df` degrees of freedom.
pub fn chi2_cdf(x: f64, df: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    gamma_inc(df / 2.0, x / 2.0)
}

/// Binomial(n, 0.5) two-sided exact p-value of observing `k` (or more
/// extreme) — used by McNemar's exact test.
pub fn binom_test_half(k: u64, n: u64) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let k_ext = k.min(n - k);
    // P(X <= k_ext) * 2 for symmetric p=0.5; cap at 1.
    let mut log_probs = Vec::with_capacity(n as usize + 1);
    let ln_half_n = n as f64 * 0.5f64.ln();
    for i in 0..=n {
        let ln_choose = ln_gamma(n as f64 + 1.0)
            - ln_gamma(i as f64 + 1.0)
            - ln_gamma((n - i) as f64 + 1.0);
        log_probs.push(ln_choose + ln_half_n);
    }
    let tail: f64 = (0..=k_ext).map(|i| log_probs[i as usize].exp()).sum();
    (2.0 * tail).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} != {b} (tol {tol})");
    }

    #[test]
    fn ln_gamma_known_values() {
        close(ln_gamma(1.0), 0.0, 1e-12);
        close(ln_gamma(2.0), 0.0, 1e-12);
        close(ln_gamma(5.0), 24f64.ln(), 1e-10); // Γ(5)=24
        close(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-10);
    }

    #[test]
    fn erf_known_values() {
        close(erf(0.0), 0.0, 1e-15);
        close(erf(1.0), 0.8427007929497149, 1e-10);
        close(erf(-1.0), -0.8427007929497149, 1e-10);
        close(erf(2.0), 0.9953222650189527, 1e-10);
    }

    #[test]
    fn normal_cdf_symmetry() {
        close(normal_cdf(0.0), 0.5, 1e-15);
        close(normal_cdf(1.959963984540054), 0.975, 1e-9);
        close(normal_cdf(-1.959963984540054), 0.025, 1e-9);
    }

    #[test]
    fn normal_ppf_inverts_cdf() {
        for &p in &[0.001, 0.025, 0.1, 0.5, 0.9, 0.975, 0.999] {
            close(normal_cdf(normal_ppf(p)), p, 1e-9);
        }
        close(normal_ppf(0.975), 1.959963984540054, 1e-8);
    }

    #[test]
    fn t_cdf_matches_known() {
        // t=2.0, df=10 → CDF = 0.963306 (scipy.stats.t.cdf(2, 10)).
        close(t_cdf(2.0, 10.0), 0.9633059826238042, 1e-9);
        close(t_cdf(0.0, 5.0), 0.5, 1e-15);
        // Large df approaches normal.
        close(t_cdf(1.96, 1e6), normal_cdf(1.96), 1e-5);
    }

    #[test]
    fn t_ppf_inverts() {
        for &df in &[3.0, 10.0, 30.0, 100.0] {
            for &p in &[0.025, 0.05, 0.5, 0.95, 0.975] {
                close(t_cdf(t_ppf(p, df), df), p, 1e-9);
            }
        }
        // scipy.stats.t.ppf(0.975, 10) = 2.2281388519649385
        close(t_ppf(0.975, 10.0), 2.2281388519649385, 1e-7);
    }

    #[test]
    fn chi2_cdf_known() {
        // scipy.stats.chi2.cdf(3.841458820694124, 1) = 0.95
        close(chi2_cdf(3.841458820694124, 1.0), 0.95, 1e-9);
        close(chi2_cdf(0.0, 4.0), 0.0, 1e-15);
    }

    #[test]
    fn beta_inc_bounds_and_symmetry() {
        close(beta_inc(2.0, 3.0, 0.0), 0.0, 1e-15);
        close(beta_inc(2.0, 3.0, 1.0), 1.0, 1e-15);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        let x = 0.3;
        close(beta_inc(2.5, 1.5, x), 1.0 - beta_inc(1.5, 2.5, 1.0 - x), 1e-12);
        // scipy.special.betainc(2, 3, 0.5) = 0.6875
        close(beta_inc(2.0, 3.0, 0.5), 0.6875, 1e-10);
    }

    #[test]
    fn gamma_inc_known() {
        // P(1, x) = 1 - exp(-x)
        for &x in &[0.1, 1.0, 3.0] {
            close(gamma_inc(1.0, x), 1.0 - (-x as f64).exp(), 1e-12);
        }
    }

    #[test]
    fn binom_test_half_known() {
        // scipy.stats.binomtest(1, 10, 0.5).pvalue = 0.021484375
        close(binom_test_half(1, 10), 0.021484375, 1e-12);
        // Balanced outcome → p = 1 (capped).
        close(binom_test_half(5, 10), 1.0, 1e-12);
        close(binom_test_half(0, 0), 1.0, 1e-15);
    }
}
