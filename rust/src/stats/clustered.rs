//! Cluster-aware inference (paper §6.1 limitation, implemented here):
//! evaluation datasets often contain *related* examples (follow-up
//! questions on one topic), violating the independence assumption of the
//! standard tests. Two remedies:
//!
//! - **cluster-robust paired t-test** — aggregate per-example differences
//!   to cluster means and t-test across clusters (conservative, simple);
//! - **cluster bootstrap CI** — resample whole clusters with replacement.

use super::describe::{mean, quantile_sorted, std_dev};
use super::special::t_sf_two_sided;
use super::tests::TestResult;
use super::ConfidenceInterval;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Group per-example values by cluster id.
fn group<'a>(values: &'a [f64], clusters: &'a [u64]) -> BTreeMap<u64, Vec<f64>> {
    assert_eq!(values.len(), clusters.len());
    let mut map: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
    for (&v, &c) in values.iter().zip(clusters) {
        map.entry(c).or_default().push(v);
    }
    map
}

/// Cluster-robust paired t-test: per-cluster mean differences, t-test
/// across clusters (df = clusters - 1).
pub fn clustered_paired_t_test(a: &[f64], b: &[f64], clusters: &[u64]) -> TestResult {
    assert_eq!(a.len(), b.len());
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let by_cluster = group(&diffs, clusters);
    let cluster_means: Vec<f64> = by_cluster.values().map(|v| mean(v)).collect();
    let g = cluster_means.len();
    if g < 2 {
        return TestResult { statistic: 0.0, p_value: 1.0, test: "clustered_t", n_used: g };
    }
    let md = mean(&cluster_means);
    let sd = std_dev(&cluster_means);
    if sd < 1e-300 {
        let p = if md.abs() < 1e-300 { 1.0 } else { 0.0 };
        return TestResult { statistic: 0.0, p_value: p, test: "clustered_t", n_used: g };
    }
    let t = md / (sd / (g as f64).sqrt());
    TestResult {
        statistic: t,
        p_value: t_sf_two_sided(t, (g - 1) as f64),
        test: "clustered_t",
        n_used: g,
    }
}

/// Cluster bootstrap percentile CI of the mean: resample clusters with
/// replacement, pool their values, take the mean.
pub fn cluster_bootstrap_ci(
    values: &[f64],
    clusters: &[u64],
    level: f64,
    iterations: usize,
    rng: &mut Rng,
) -> ConfidenceInterval {
    let by_cluster: Vec<Vec<f64>> = group(values, clusters).into_values().collect();
    let g = by_cluster.len();
    let point = mean(values);
    if g == 0 {
        return ConfidenceInterval { point, lo: point, hi: point, level, method: "cluster_boot" };
    }
    let mut boots = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let mut acc = 0.0;
        let mut n = 0usize;
        for _ in 0..g {
            let c = &by_cluster[rng.below(g)];
            acc += c.iter().sum::<f64>();
            n += c.len();
        }
        boots.push(acc / n.max(1) as f64);
    }
    boots.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = 1.0 - level;
    ConfidenceInterval {
        point,
        lo: quantile_sorted(&boots, alpha / 2.0),
        hi: quantile_sorted(&boots, 1.0 - alpha / 2.0),
        level,
        method: "cluster_boot",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::paired_t_test;

    /// Build clustered data: `g` clusters × `m` members; within-cluster
    /// values share a random cluster effect → strong dependence.
    fn clustered_data(
        g: usize,
        m: usize,
        cluster_sd: f64,
        noise_sd: f64,
        shift: f64,
        rng: &mut Rng,
    ) -> (Vec<f64>, Vec<f64>, Vec<u64>) {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut cl = Vec::new();
        for c in 0..g {
            let effect = rng.normal_with(0.0, cluster_sd);
            for _ in 0..m {
                let base = rng.normal_with(effect, noise_sd);
                a.push(base);
                b.push(base + shift + rng.normal_with(0.0, noise_sd * 0.1));
                cl.push(c as u64);
            }
        }
        (a, b, cl)
    }

    #[test]
    fn clustered_test_uses_cluster_count() {
        let mut rng = Rng::new(1);
        let (a, b, cl) = clustered_data(8, 25, 1.0, 0.2, 0.0, &mut rng);
        let r = clustered_paired_t_test(&a, &b, &cl);
        assert_eq!(r.n_used, 8);
        assert_eq!(r.test, "clustered_t");
    }

    #[test]
    fn naive_test_overconfident_under_clustering() {
        // Under a clustered null with per-cluster difference shifts, the
        // naive paired t treats 200 correlated examples as independent and
        // rejects far too often; the clustered test stays calibrated.
        let mut rng = Rng::new(2);
        let trials = 300;
        let mut naive_rej = 0;
        let mut clustered_rej = 0;
        for _ in 0..trials {
            // Null at the *cluster* level: each cluster's B-shift is drawn
            // with mean 0, but is constant within the cluster.
            let g = 10;
            let m = 20;
            let mut a = Vec::new();
            let mut b = Vec::new();
            let mut cl = Vec::new();
            for c in 0..g {
                let cluster_shift = rng.normal_with(0.0, 0.5);
                for _ in 0..m {
                    let x = rng.normal();
                    a.push(x);
                    b.push(x + cluster_shift + rng.normal_with(0.0, 0.1));
                    cl.push(c as u64);
                }
            }
            if paired_t_test(&a, &b).significant(0.05) {
                naive_rej += 1;
            }
            if clustered_paired_t_test(&a, &b, &cl).significant(0.05) {
                clustered_rej += 1;
            }
        }
        let naive_rate = naive_rej as f64 / trials as f64;
        let clustered_rate = clustered_rej as f64 / trials as f64;
        assert!(naive_rate > 0.3, "naive should be badly overconfident: {naive_rate}");
        assert!(clustered_rate < 0.12, "clustered should be calibrated: {clustered_rate}");
    }

    #[test]
    fn clustered_detects_real_shift() {
        let mut rng = Rng::new(3);
        let (a, b, cl) = clustered_data(20, 10, 0.3, 0.2, 1.0, &mut rng);
        let r = clustered_paired_t_test(&a, &b, &cl);
        assert!(r.p_value < 1e-4, "p {}", r.p_value);
    }

    #[test]
    fn cluster_bootstrap_wider_than_naive() {
        let mut rng = Rng::new(4);
        let (a, _, cl) = clustered_data(10, 30, 1.5, 0.1, 0.0, &mut rng);
        let mut r1 = Rng::new(5);
        let clustered = cluster_bootstrap_ci(&a, &cl, 0.95, 800, &mut r1);
        let mut r2 = Rng::new(5);
        let naive =
            crate::stats::percentile_bootstrap(&a, mean, 0.95, 800, &mut r2);
        assert!(
            clustered.width() > naive.width() * 1.5,
            "clustered {} vs naive {}",
            clustered.width(),
            naive.width()
        );
        assert!(clustered.lo <= clustered.point && clustered.point <= clustered.hi);
    }

    #[test]
    fn degenerate_inputs() {
        let r = clustered_paired_t_test(&[1.0], &[2.0], &[0]);
        assert_eq!(r.p_value, 1.0);
        let mut rng = Rng::new(6);
        let ci = cluster_bootstrap_ci(&[], &[], 0.95, 10, &mut rng);
        assert!(ci.point.is_nan() || ci.lo == ci.hi);
    }
}
