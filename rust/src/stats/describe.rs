//! Descriptive statistics: moments, quantiles, ranks.

/// Sample mean. Returns NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Standard error of the mean.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Sample skewness (g1, biased).
pub fn skewness(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if xs.len() < 3 {
        return 0.0;
    }
    let m = mean(xs);
    let m2 = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n;
    let m3 = xs.iter().map(|x| (x - m).powi(3)).sum::<f64>() / n;
    if m2 <= 0.0 {
        0.0
    } else {
        m3 / m2.powf(1.5)
    }
}

/// Quantile via linear interpolation on the sorted copy (type-7 like
/// numpy's default).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "q out of range");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&sorted, q)
}

/// Quantile on an already-sorted slice (hot path for bootstrap CIs).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Midranks (average ranks for ties), 1-based — Wilcoxon needs these.
pub fn midranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[order[j + 1]] == xs[order[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &order[i..=j] {
            ranks[k] = avg_rank;
        }
        i = j + 1;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
        assert!((std_dev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert!(mean(&[]).is_nan());
        assert_eq!(variance(&[1.0]), 0.0);
        assert_eq!(quantile(&[3.0], 0.5), 3.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        // numpy.percentile([1,2,3,4], 25) = 1.75
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn quantile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        assert!((quantile(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn skewness_signs() {
        let right = [1.0, 1.0, 1.0, 2.0, 10.0];
        let left = [-10.0, -2.0, -1.0, -1.0, -1.0];
        assert!(skewness(&right) > 0.5);
        assert!(skewness(&left) < -0.5);
        let sym = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(skewness(&sym).abs() < 1e-12);
    }

    #[test]
    fn midranks_with_ties() {
        let xs = [10.0, 20.0, 20.0, 30.0];
        assert_eq!(midranks(&xs), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn midranks_all_equal() {
        let xs = [5.0; 4];
        assert_eq!(midranks(&xs), vec![2.5; 4]);
    }
}
