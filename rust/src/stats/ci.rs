//! Confidence intervals (paper §4.2): percentile bootstrap, BCa bootstrap,
//! and analytical methods (t-based for means, Wilson for proportions).

use super::bootstrap::{bootstrap_statistics, jackknife_statistics};
use super::describe::{mean, quantile_sorted, std_err};
use super::special::{normal_cdf, normal_ppf, t_ppf};
use crate::util::rng::Rng;

/// A two-sided confidence interval around a point estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfidenceInterval {
    pub point: f64,
    pub lo: f64,
    pub hi: f64,
    pub level: f64,
    pub method: &'static str,
}

impl ConfidenceInterval {
    pub fn contains(&self, v: f64) -> bool {
        self.lo <= v && v <= self.hi
    }

    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }

    /// Half of [`ConfidenceInterval::width`] — the "± margin" the adaptive
    /// stopping rule certifies against (`stopping.ci_half_width`). For
    /// asymmetric intervals (percentile/BCa) this is the conservative
    /// symmetric margin, not the distance from the point estimate.
    pub fn half_width(&self) -> f64 {
        self.width() / 2.0
    }
}

/// Percentile bootstrap CI of the mean-like statistic `stat` (paper §4.2).
pub fn percentile_bootstrap<F: Fn(&[f64]) -> f64>(
    values: &[f64],
    stat: F,
    level: f64,
    iterations: usize,
    rng: &mut Rng,
) -> ConfidenceInterval {
    let point = stat(values);
    let mut boots = bootstrap_statistics(values, &stat, iterations, rng);
    boots.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = 1.0 - level;
    ConfidenceInterval {
        point,
        lo: quantile_sorted(&boots, alpha / 2.0),
        hi: quantile_sorted(&boots, 1.0 - alpha / 2.0),
        level,
        method: "percentile",
    }
}

/// Bias-corrected and accelerated (BCa) bootstrap CI (Efron & Tibshirani).
///
/// z0 from the fraction of bootstrap stats below the point estimate; the
/// acceleration from jackknife skewness.
pub fn bca_bootstrap<F: Fn(&[f64]) -> f64>(
    values: &[f64],
    stat: F,
    level: f64,
    iterations: usize,
    rng: &mut Rng,
) -> ConfidenceInterval {
    let point = stat(values);
    let mut boots = bootstrap_statistics(values, &stat, iterations, rng);
    boots.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Bias correction z0.
    let below = boots.iter().filter(|&&b| b < point).count() as f64;
    let prop = (below / boots.len() as f64).clamp(1e-9, 1.0 - 1e-9);
    let z0 = normal_ppf(prop);

    // Acceleration from jackknife values.
    let jack = jackknife_statistics(values, &stat);
    let jmean = mean(&jack);
    let num: f64 = jack.iter().map(|j| (jmean - j).powi(3)).sum();
    let den: f64 = jack.iter().map(|j| (jmean - j).powi(2)).sum::<f64>().powf(1.5);
    let a = if den.abs() < 1e-300 { 0.0 } else { num / (6.0 * den) };

    let alpha = 1.0 - level;
    let z_lo = normal_ppf(alpha / 2.0);
    let z_hi = normal_ppf(1.0 - alpha / 2.0);
    let adj = |z: f64| -> f64 {
        let zc = z0 + (z0 + z) / (1.0 - a * (z0 + z));
        normal_cdf(zc).clamp(0.0, 1.0)
    };
    ConfidenceInterval {
        point,
        lo: quantile_sorted(&boots, adj(z_lo)),
        hi: quantile_sorted(&boots, adj(z_hi)),
        level,
        method: "bca",
    }
}

/// Analytical t-based CI for a mean: x̄ ± t_{α/2, n-1} · s/√n.
pub fn t_interval(values: &[f64], level: f64) -> ConfidenceInterval {
    let n = values.len();
    let point = mean(values);
    if n < 2 {
        return ConfidenceInterval { point, lo: point, hi: point, level, method: "t" };
    }
    let alpha = 1.0 - level;
    let tcrit = t_ppf(1.0 - alpha / 2.0, (n - 1) as f64);
    let half = tcrit * std_err(values);
    ConfidenceInterval { point, lo: point - half, hi: point + half, level, method: "t" }
}

/// Wilson score interval for a proportion (paper §4.2: better behaviour
/// near 0/1 than the Wald interval).
pub fn wilson_interval(successes: u64, n: u64, level: f64) -> ConfidenceInterval {
    if n == 0 {
        return ConfidenceInterval { point: f64::NAN, lo: 0.0, hi: 1.0, level, method: "wilson" };
    }
    let p = successes as f64 / n as f64;
    let z = normal_ppf(1.0 - (1.0 - level) / 2.0);
    let nf = n as f64;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = z * (p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt() / denom;
    ConfidenceInterval {
        point: p,
        lo: (center - half).max(0.0),
        hi: (center + half).min(1.0),
        level,
        method: "wilson",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn normal_sample(n: usize, mu: f64, sd: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_with(mu, sd)).collect()
    }

    #[test]
    fn t_interval_matches_known() {
        // scipy: t.interval(0.95, 9, loc=m, scale=sem) over 10 values.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        let ci = t_interval(&xs, 0.95);
        assert!((ci.point - 5.5).abs() < 1e-12);
        // scipy gives (3.334149409, 7.665850591)
        assert!((ci.lo - 3.3341494102783162).abs() < 1e-6, "lo {}", ci.lo);
        assert!((ci.hi - 7.665850589721684).abs() < 1e-6, "hi {}", ci.hi);
    }

    #[test]
    fn wilson_matches_known() {
        // statsmodels proportion_confint(8, 10, method='wilson')
        // = (0.4901625, 0.9433178)
        let ci = wilson_interval(8, 10, 0.95);
        assert!((ci.lo - 0.49016).abs() < 1e-4, "lo {}", ci.lo);
        assert!((ci.hi - 0.94331).abs() < 1e-4, "hi {}", ci.hi);
    }

    #[test]
    fn wilson_edge_cases() {
        let ci = wilson_interval(0, 20, 0.95);
        assert_eq!(ci.point, 0.0);
        assert!(ci.lo >= 0.0 && ci.hi > 0.0 && ci.hi < 0.3);
        let ci = wilson_interval(20, 20, 0.95);
        assert!(ci.lo > 0.7 && ci.hi <= 1.0);
        let ci = wilson_interval(0, 0, 0.95);
        assert!(ci.point.is_nan());
    }

    #[test]
    fn bootstrap_cis_cover_point() {
        let xs = normal_sample(100, 2.0, 1.0, 3);
        let mut rng = Rng::new(5);
        let pct = percentile_bootstrap(&xs, mean, 0.95, 500, &mut rng);
        assert!(pct.contains(pct.point));
        let mut rng = Rng::new(5);
        let bca = bca_bootstrap(&xs, mean, 0.95, 500, &mut rng);
        assert!(bca.contains(bca.point));
        // Both should be near the t interval for normal data.
        let t = t_interval(&xs, 0.95);
        assert!((pct.lo - t.lo).abs() < 0.15, "pct lo {} t lo {}", pct.lo, t.lo);
        assert!((bca.hi - t.hi).abs() < 0.15);
    }

    #[test]
    fn property_ci_ordering() {
        check("ci lo <= point <= hi", 50, |rng| {
            let n = 10 + rng.below(100);
            let xs: Vec<f64> = (0..n).map(|_| rng.lognormal(0.0, 0.6)).collect();
            let mut brng = rng.fork(1);
            let pct = percentile_bootstrap(&xs, mean, 0.95, 200, &mut brng);
            let bca = bca_bootstrap(&xs, mean, 0.95, 200, &mut brng);
            let t = t_interval(&xs, 0.95);
            ensure(pct.lo <= pct.point + 1e-9 && pct.point <= pct.hi + 1e-9, "pct order")?;
            ensure(bca.lo <= bca.hi, "bca order")?;
            ensure(t.lo <= t.point && t.point <= t.hi, "t order")?;
            Ok(())
        });
    }

    #[test]
    fn higher_level_wider_interval() {
        let xs = normal_sample(50, 0.0, 1.0, 7);
        let c90 = t_interval(&xs, 0.90);
        let c99 = t_interval(&xs, 0.99);
        assert!(c99.width() > c90.width());
    }

    #[test]
    fn half_width_degenerate_inputs() {
        // n < 2: the t interval collapses to the point — zero half-width,
        // never NaN (the stopping rule must not certify on it by accident
        // of a NaN comparison, so callers gate on n >= 2 themselves).
        let ci = t_interval(&[3.0], 0.95);
        assert_eq!(ci.width(), 0.0);
        assert_eq!(ci.half_width(), 0.0);
        let ci = t_interval(&[], 0.95);
        assert!(ci.point.is_nan());
        // All-equal values: zero variance collapses the t interval too.
        let ci = t_interval(&[2.5; 40], 0.95);
        assert_eq!(ci.half_width(), 0.0);
        assert_eq!(ci.point, 2.5);
        // Wilson n=0: the [0,1] fallback has half-width 0.5.
        let ci = wilson_interval(0, 0, 0.95);
        assert!((ci.half_width() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn half_width_is_half_of_width() {
        let xs = normal_sample(80, 1.0, 2.0, 17);
        let ci = t_interval(&xs, 0.95);
        assert!(ci.half_width() > 0.0);
        assert!((ci.half_width() * 2.0 - ci.width()).abs() < 1e-15);
        let mut rng = Rng::new(19);
        let pct = percentile_bootstrap(&xs, mean, 0.95, 300, &mut rng);
        assert!((pct.half_width() * 2.0 - pct.width()).abs() < 1e-12);
    }

    #[test]
    fn bca_shifts_for_skewed_data() {
        // Log-normal data: BCa interval should differ from percentile
        // (that's the whole point of the correction).
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..60).map(|_| rng.lognormal(0.0, 0.8)).collect();
        let mut r1 = Rng::new(13);
        let pct = percentile_bootstrap(&xs, mean, 0.95, 2000, &mut r1);
        let mut r2 = Rng::new(13);
        let bca = bca_bootstrap(&xs, mean, 0.95, 2000, &mut r2);
        assert!(
            (pct.lo - bca.lo).abs() > 1e-4 || (pct.hi - bca.hi).abs() > 1e-4,
            "BCa should adjust percentiles for skewed data"
        );
    }
}
