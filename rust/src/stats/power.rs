//! Power analysis: how many examples does an evaluation need?
//!
//! The paper argues for statistical rigor in comparisons; the natural
//! companion (and a practical extension) is sample-size planning:
//! "to detect a 2-point accuracy difference at 80% power, evaluate at
//! least N examples."

use super::special::{normal_cdf, normal_ppf};

/// Required sample size for a paired comparison of means with effect size
/// `d` (Cohen's d of the paired differences), significance `alpha`
/// (two-sided), and `power`.
pub fn sample_size_paired_t(d: f64, alpha: f64, power: f64) -> usize {
    assert!(d != 0.0, "effect size must be non-zero");
    let z_a = normal_ppf(1.0 - alpha / 2.0);
    let z_b = normal_ppf(power);
    let n = ((z_a + z_b) / d.abs()).powi(2);
    // Small-sample t correction: +2 is the standard rule-of-thumb bump.
    (n.ceil() as usize + 2).max(3)
}

/// Required discordant-pair count for McNemar to detect an accuracy gap:
/// `p01` and `p10` are the expected discordant probabilities per example
/// (model A wrong/B right, and vice versa). Returns (examples, discordant)
/// estimates.
pub fn sample_size_mcnemar(p01: f64, p10: f64, alpha: f64, power: f64) -> (usize, usize) {
    assert!(p01 != p10, "null effect has no finite sample size");
    let pd = p01 + p10;
    let z_a = normal_ppf(1.0 - alpha / 2.0);
    let z_b = normal_ppf(power);
    // Connor (1987) approximation.
    let diff = (p10 - p01).abs();
    let n = ((z_a * pd.sqrt() + z_b * (pd - diff * diff / pd).max(0.0).sqrt()) / diff).powi(2);
    let examples = n.ceil() as usize;
    (examples, (examples as f64 * pd).ceil() as usize)
}

/// Achieved power of a paired t comparison given `n` and effect size `d`.
pub fn power_paired_t(d: f64, n: usize, alpha: f64) -> f64 {
    let z_a = normal_ppf(1.0 - alpha / 2.0);
    let ncp = d.abs() * (n as f64).sqrt();
    // Normal approximation to the noncentral t.
    (normal_cdf(ncp - z_a) + normal_cdf(-ncp - z_a)).clamp(0.0, 1.0)
}

/// Minimum detectable effect (Cohen's d) at a given n / alpha / power.
pub fn minimum_detectable_effect(n: usize, alpha: f64, power: f64) -> f64 {
    let z_a = normal_ppf(1.0 - alpha / 2.0);
    let z_b = normal_ppf(power);
    (z_a + z_b) / (n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::paired_t_test;
    use crate::util::rng::Rng;

    #[test]
    fn classic_reference_values() {
        // d=0.5, alpha=0.05, power=0.8 → n ≈ 34 (G*Power: 34).
        let n = sample_size_paired_t(0.5, 0.05, 0.8);
        assert!((30..=38).contains(&n), "n {n}");
        // d=0.2 → n ≈ 199.
        let n = sample_size_paired_t(0.2, 0.05, 0.8);
        assert!((190..=210).contains(&n), "n {n}");
    }

    #[test]
    fn power_monotone_in_n_and_d() {
        assert!(power_paired_t(0.3, 100, 0.05) > power_paired_t(0.3, 50, 0.05));
        assert!(power_paired_t(0.5, 50, 0.05) > power_paired_t(0.2, 50, 0.05));
        let p = power_paired_t(0.5, 34, 0.05);
        assert!((0.75..0.88).contains(&p), "power {p}");
    }

    #[test]
    fn mde_inverts_sample_size() {
        let n = sample_size_paired_t(0.25, 0.05, 0.8);
        let mde = minimum_detectable_effect(n, 0.05, 0.8);
        assert!((mde - 0.25).abs() < 0.03, "mde {mde}");
    }

    #[test]
    fn mcnemar_sample_size_plausible() {
        // 2-point accuracy gap with 10% discordance: p10=0.06, p01=0.04.
        let (examples, discordant) = sample_size_mcnemar(0.04, 0.06, 0.05, 0.8);
        assert!((1500..4500).contains(&examples), "examples {examples}");
        assert!(discordant > 150);
    }

    #[test]
    fn empirical_power_matches_prediction() {
        // Simulate: paired comparison at the planned n should reject at
        // ≈ the target power.
        let d = 0.4;
        let n = sample_size_paired_t(d, 0.05, 0.8);
        let mut rng = Rng::new(9);
        let trials = 400;
        let mut rejections = 0;
        for _ in 0..trials {
            // Construct pairs whose differences are N(d, 1) — Cohen's d of
            // the paired differences is exactly `d`.
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = a.iter().map(|x| x - rng.normal_with(d, 1.0)).collect();
            if paired_t_test(&a, &b).significant(0.05) {
                rejections += 1;
            }
        }
        let power = rejections as f64 / trials as f64;
        assert!((0.68..0.92).contains(&power), "empirical power {power}");
    }
}
