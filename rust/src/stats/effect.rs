//! Effect sizes (paper §4.4): Cohen's d, Hedges' g, odds ratio.

use super::describe::{mean, variance};

/// Effect size with a conventional magnitude label.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EffectSize {
    pub value: f64,
    pub measure: &'static str,
}

impl EffectSize {
    /// Cohen's conventional labels (0.2 / 0.5 / 0.8 thresholds).
    pub fn magnitude(&self) -> &'static str {
        let v = self.value.abs();
        match self.measure {
            "odds_ratio" => {
                // Convert OR to d-equivalent via ln(OR)·√3/π.
                let d = (v.max(1e-12)).ln().abs() * 3f64.sqrt() / std::f64::consts::PI;
                label(d)
            }
            _ => label(v),
        }
    }
}

fn label(d: f64) -> &'static str {
    if d < 0.2 {
        "negligible"
    } else if d < 0.5 {
        "small"
    } else if d < 0.8 {
        "medium"
    } else {
        "large"
    }
}

/// Cohen's d with pooled standard deviation.
pub fn cohens_d(a: &[f64], b: &[f64]) -> EffectSize {
    let (na, nb) = (a.len() as f64, b.len() as f64);
    if a.len() < 2 || b.len() < 2 {
        return EffectSize { value: 0.0, measure: "cohens_d" };
    }
    let pooled = (((na - 1.0) * variance(a) + (nb - 1.0) * variance(b)) / (na + nb - 2.0)).sqrt();
    let value = if pooled < 1e-300 { 0.0 } else { (mean(a) - mean(b)) / pooled };
    EffectSize { value, measure: "cohens_d" }
}

/// Hedges' g: small-sample bias-corrected Cohen's d
/// (correction J ≈ 1 − 3/(4·df − 1)).
pub fn hedges_g(a: &[f64], b: &[f64]) -> EffectSize {
    let d = cohens_d(a, b).value;
    let df = (a.len() + b.len()) as f64 - 2.0;
    let j = if df > 1.0 { 1.0 - 3.0 / (4.0 * df - 1.0) } else { 1.0 };
    EffectSize { value: d * j, measure: "hedges_g" }
}

/// Odds ratio for paired binary outcomes, with Haldane–Anscombe 0.5
/// correction when any cell is empty.
pub fn odds_ratio(a: &[f64], b: &[f64]) -> EffectSize {
    let sa = a.iter().filter(|&&x| x >= 0.5).count() as f64;
    let sb = b.iter().filter(|&&x| x >= 0.5).count() as f64;
    let fa = a.len() as f64 - sa;
    let fb = b.len() as f64 - sb;
    let (mut sa, mut fa, mut sb, mut fb) = (sa, fa, sb, fb);
    if sa == 0.0 || fa == 0.0 || sb == 0.0 || fb == 0.0 {
        sa += 0.5;
        fa += 0.5;
        sb += 0.5;
        fb += 0.5;
    }
    EffectSize { value: (sa / fa) / (sb / fb), measure: "odds_ratio" }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohens_d_known() {
        // Two groups shifted by 1 pooled sd → d = 1.
        let a = [2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0, 1.0, 2.0, 3.0, 4.0]; // mean diff 2, sd ≈ 1.581
        let d = cohens_d(&a, &b);
        assert!((d.value - 2.0 / 1.5811388300841898).abs() < 1e-9, "d {}", d.value);
        assert_eq!(d.magnitude(), "large");
    }

    #[test]
    fn hedges_smaller_than_cohens() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 3.0, 4.0, 5.0];
        let d = cohens_d(&a, &b).value.abs();
        let g = hedges_g(&a, &b).value.abs();
        assert!(g < d, "g {g} must shrink d {d}");
    }

    #[test]
    fn magnitude_labels() {
        assert_eq!(EffectSize { value: 0.1, measure: "cohens_d" }.magnitude(), "negligible");
        assert_eq!(EffectSize { value: 0.3, measure: "cohens_d" }.magnitude(), "small");
        assert_eq!(EffectSize { value: -0.6, measure: "cohens_d" }.magnitude(), "medium");
        assert_eq!(EffectSize { value: 1.2, measure: "cohens_d" }.magnitude(), "large");
    }

    #[test]
    fn odds_ratio_basic() {
        // a: 8/10 success, b: 5/10 → OR = (8/2)/(5/5) = 4.
        let a: Vec<f64> = (0..10).map(|i| if i < 8 { 1.0 } else { 0.0 }).collect();
        let b: Vec<f64> = (0..10).map(|i| if i < 5 { 1.0 } else { 0.0 }).collect();
        let or = odds_ratio(&a, &b);
        assert!((or.value - 4.0).abs() < 1e-12);
    }

    #[test]
    fn odds_ratio_zero_cell_corrected() {
        let a = vec![1.0; 10];
        let b: Vec<f64> = (0..10).map(|i| if i < 5 { 1.0 } else { 0.0 }).collect();
        let or = odds_ratio(&a, &b);
        assert!(or.value.is_finite() && or.value > 1.0);
    }

    #[test]
    fn zero_variance_safe() {
        let d = cohens_d(&[1.0; 5], &[1.0; 5]);
        assert_eq!(d.value, 0.0);
    }
}
