//! Statistics stack (paper §4.2–§4.4): confidence intervals (percentile /
//! BCa bootstrap, t, Wilson), significance tests (paired t, McNemar,
//! Wilcoxon signed-rank, permutation), effect sizes, Shapiro–Wilk
//! normality, and the Table 2 test-selection heuristic.
//!
//! Everything is implemented from scratch on the special functions in
//! [`special`] and cross-validated against scipy fixtures
//! (`rust/tests/stats_golden.rs`) plus the paper's own coverage / Type-I
//! experiments (Table 5, §5.4 benches).

pub mod bootstrap;
pub mod ci;
pub mod clustered;
pub mod describe;
pub mod effect;
pub mod power;
pub mod select;
pub mod shapiro;
pub mod special;
pub mod tests;

pub use ci::{bca_bootstrap, percentile_bootstrap, t_interval, wilson_interval, ConfidenceInterval};
pub use effect::{cohens_d, hedges_g, odds_ratio, EffectSize};
pub use select::{detect_scale, run_selected_test, select_test, MetricScale, TestChoice};
pub use shapiro::{shapiro_wilk, ShapiroResult};
pub use tests::{mcnemar_test, paired_t_test, permutation_test, wilcoxon_signed_rank, TestResult};
