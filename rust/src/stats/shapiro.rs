//! Shapiro–Wilk normality test (Royston 1995, AS R94).
//!
//! The test-selection heuristic (paper Table 2) uses this as its
//! distributional diagnostic: continuous metrics route to the paired
//! t-test only when the differences pass normality.

use super::special::{normal_cdf, normal_ppf};

/// Shapiro–Wilk outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShapiroResult {
    pub w: f64,
    pub p_value: f64,
}

impl ShapiroResult {
    pub fn looks_normal(&self, alpha: f64) -> bool {
        self.p_value >= alpha
    }
}

/// Run the test. Requires 3 ≤ n ≤ 5000 (Royston's validated range);
/// outside it we clamp behaviour: n < 3 returns W=1, p=1 (can't reject),
/// n > 5000 uses a subsample of the first 5000 (documented approximation).
pub fn shapiro_wilk(xs: &[f64]) -> ShapiroResult {
    let mut sorted: Vec<f64> = xs.iter().copied().filter(|v| v.is_finite()).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if sorted.len() > 5000 {
        sorted.truncate(5000);
    }
    let n = sorted.len();
    if n < 3 {
        return ShapiroResult { w: 1.0, p_value: 1.0 };
    }
    let range = sorted[n - 1] - sorted[0];
    if range < 1e-300 {
        // Constant data: maximally non-normal.
        return ShapiroResult { w: 0.0, p_value: 0.0 };
    }

    // Expected normal order statistics m_i (Blom approximation).
    let nf = n as f64;
    let m: Vec<f64> = (1..=n)
        .map(|i| normal_ppf((i as f64 - 0.375) / (nf + 0.25)))
        .collect();
    let ssm: f64 = m.iter().map(|v| v * v).sum();
    let rsn = 1.0 / nf.sqrt();

    // Coefficients a (Royston polynomial corrections on the tails).
    let mut a = vec![0.0; n];
    if n == 3 {
        a[2] = std::f64::consts::FRAC_1_SQRT_2;
        a[0] = -a[2];
    } else {
        let c = |v: &[f64]| -> Vec<f64> {
            let norm = ssm.sqrt();
            v.iter().map(|x| x / norm).collect()
        };
        let cvec = c(&m);
        let u = rsn;
        let an = cvec[n - 1] + 0.221157 * u - 0.147981 * u * u - 2.071190 * u.powi(3)
            + 4.434685 * u.powi(4)
            - 2.706056 * u.powi(5);
        if n <= 5 {
            let phi = (ssm - 2.0 * m[n - 1] * m[n - 1]) / (1.0 - 2.0 * an * an);
            a[n - 1] = an;
            a[0] = -an;
            for i in 1..n - 1 {
                a[i] = m[i] / phi.sqrt();
            }
        } else {
            let an1 = cvec[n - 2] + 0.042981 * u - 0.293762 * u * u - 1.752461 * u.powi(3)
                + 5.682633 * u.powi(4)
                - 3.582633 * u.powi(5);
            let phi = (ssm - 2.0 * m[n - 1] * m[n - 1] - 2.0 * m[n - 2] * m[n - 2])
                / (1.0 - 2.0 * an * an - 2.0 * an1 * an1);
            a[n - 1] = an;
            a[n - 2] = an1;
            a[0] = -an;
            a[1] = -an1;
            for i in 2..n - 2 {
                a[i] = m[i] / phi.sqrt();
            }
        }
    }

    // W statistic.
    let mean = sorted.iter().sum::<f64>() / nf;
    let ssd: f64 = sorted.iter().map(|x| (x - mean) * (x - mean)).sum();
    let b: f64 = a.iter().zip(&sorted).map(|(ai, xi)| ai * xi).sum();
    let w = ((b * b) / ssd).clamp(0.0, 1.0);

    // P-value via Royston's normalizing transformations.
    let p_value = if n == 3 {
        let p = 6.0 / std::f64::consts::PI
            * ((w.sqrt()).asin() - (0.75f64).sqrt().asin());
        p.clamp(0.0, 1.0)
    } else if n <= 11 {
        let g = -2.273 + 0.459 * nf;
        let mu = 0.5440 - 0.39978 * nf + 0.025054 * nf * nf - 0.0006714 * nf.powi(3);
        let sigma = (1.3822 - 0.77857 * nf + 0.062767 * nf * nf - 0.0020322 * nf.powi(3)).exp();
        let arg = g - (1.0 - w).ln();
        if arg <= 0.0 {
            0.0
        } else {
            let z = (-(arg.ln()) - mu) / sigma;
            1.0 - normal_cdf(z)
        }
    } else {
        let ln_n = nf.ln();
        let mu = -1.5861 - 0.31082 * ln_n - 0.083751 * ln_n * ln_n + 0.0038915 * ln_n.powi(3);
        let sigma = (-0.4803 - 0.082676 * ln_n + 0.0030302 * ln_n * ln_n).exp();
        let z = ((1.0 - w).ln() - mu) / sigma;
        1.0 - normal_cdf(z)
    };

    ShapiroResult { w, p_value: p_value.clamp(0.0, 1.0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn normal_data_passes() {
        let mut rng = Rng::new(1);
        let mut passes = 0;
        for _ in 0..50 {
            let xs: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
            if shapiro_wilk(&xs).looks_normal(0.05) {
                passes += 1;
            }
        }
        // ~95% of normal samples should pass at alpha=0.05.
        assert!(passes >= 42, "passes {passes}/50");
    }

    #[test]
    fn uniform_data_rejected_large_n() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..500).map(|_| rng.f64()).collect();
        let r = shapiro_wilk(&xs);
        assert!(r.p_value < 0.01, "uniform p {}", r.p_value);
    }

    #[test]
    fn lognormal_rejected() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..200).map(|_| rng.lognormal(0.0, 0.8)).collect();
        let r = shapiro_wilk(&xs);
        assert!(r.p_value < 0.001, "lognormal p {}", r.p_value);
        assert!(r.w < 0.95);
    }

    #[test]
    fn w_statistic_plausible_range() {
        let mut rng = Rng::new(4);
        let xs: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let r = shapiro_wilk(&xs);
        assert!(r.w > 0.9 && r.w <= 1.0, "w {}", r.w);
    }

    #[test]
    fn scipy_reference_case() {
        // scipy.stats.shapiro([148, 154, 158, 160, 161, 162, 166, 170,
        //   182, 195, 236]) → W=0.7888, p=0.00672 (classic outlier data).
        let xs = [
            148.0, 154.0, 158.0, 160.0, 161.0, 162.0, 166.0, 170.0, 182.0, 195.0, 236.0,
        ];
        let r = shapiro_wilk(&xs);
        assert!((r.w - 0.7888).abs() < 0.01, "w {}", r.w);
        assert!((r.p_value - 0.00672).abs() < 0.005, "p {}", r.p_value);
    }

    #[test]
    fn tiny_and_constant_inputs() {
        assert_eq!(shapiro_wilk(&[1.0, 2.0]).p_value, 1.0);
        let r = shapiro_wilk(&[5.0; 20]);
        assert_eq!(r.p_value, 0.0);
    }

    #[test]
    fn small_n_exact_range() {
        let xs = [1.0, 2.0, 3.0];
        let r = shapiro_wilk(&xs);
        assert!((0.0..=1.0).contains(&r.p_value));
        assert!(r.w > 0.9); // perfectly spaced data looks normal
    }
}
