//! Significance-test selection heuristic (paper §4.3 Table 2).
//!
//! | Metric type            | Sample size | Recommended test            |
//! |------------------------|-------------|-----------------------------|
//! | Binary                 | any         | McNemar (exact for n<10)    |
//! | Continuous, normal     | n > 30      | Paired t-test               |
//! | Continuous, non-normal | any         | Wilcoxon signed-rank        |
//! | Ordinal                | any         | Wilcoxon signed-rank        |
//! | Complex/custom         | any         | Bootstrap permutation       |

use super::shapiro::shapiro_wilk;
use super::tests::{mcnemar_test, paired_t_test, permutation_test, wilcoxon_signed_rank, TestResult};
use crate::util::rng::Rng;

/// How the metric's values behave (drives Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricScale {
    /// 0/1 outcomes (exact match, contains).
    Binary,
    /// Real-valued (BLEU, similarity, F1).
    Continuous,
    /// Small discrete grades (judge scores 1–5).
    Ordinal,
    /// Anything else / custom aggregate.
    Complex,
}

/// Which test Table 2 recommends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TestChoice {
    McNemar,
    PairedT,
    Wilcoxon,
    Permutation,
}

impl TestChoice {
    pub fn as_str(self) -> &'static str {
        match self {
            TestChoice::McNemar => "mcnemar",
            TestChoice::PairedT => "paired_t",
            TestChoice::Wilcoxon => "wilcoxon",
            TestChoice::Permutation => "permutation",
        }
    }
}

/// Detect the scale from observed values (used when the metric registry
/// doesn't declare one).
pub fn detect_scale(values: &[f64]) -> MetricScale {
    if values.iter().all(|&v| v == 0.0 || v == 1.0) {
        return MetricScale::Binary;
    }
    // Few distinct integer-ish levels → ordinal.
    let mut distinct: Vec<i64> = Vec::new();
    let mut all_int = true;
    for &v in values {
        if (v - v.round()).abs() > 1e-9 {
            all_int = false;
            break;
        }
        let r = v.round() as i64;
        if !distinct.contains(&r) {
            distinct.push(r);
            if distinct.len() > 10 {
                break;
            }
        }
    }
    if all_int && distinct.len() <= 10 {
        MetricScale::Ordinal
    } else {
        MetricScale::Continuous
    }
}

/// Table 2 selection: scale + sample size + normality diagnostic on the
/// paired differences.
pub fn select_test(scale: MetricScale, diffs: &[f64]) -> TestChoice {
    match scale {
        MetricScale::Binary => TestChoice::McNemar,
        MetricScale::Ordinal => TestChoice::Wilcoxon,
        MetricScale::Complex => TestChoice::Permutation,
        MetricScale::Continuous => {
            let n = diffs.len();
            if n > 30 && shapiro_wilk(diffs).looks_normal(0.05) {
                TestChoice::PairedT
            } else {
                TestChoice::Wilcoxon
            }
        }
    }
}

/// Run the recommended test end to end.
pub fn run_selected_test(
    scale: MetricScale,
    a: &[f64],
    b: &[f64],
    permutations: usize,
    rng: &mut Rng,
) -> (TestChoice, TestResult) {
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let choice = select_test(scale, &diffs);
    let result = match choice {
        TestChoice::McNemar => mcnemar_test(a, b),
        TestChoice::PairedT => paired_t_test(a, b),
        TestChoice::Wilcoxon => wilcoxon_signed_rank(a, b),
        TestChoice::Permutation => permutation_test(a, b, permutations, rng),
    };
    (choice, result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_routes_to_mcnemar() {
        assert_eq!(detect_scale(&[0.0, 1.0, 1.0, 0.0]), MetricScale::Binary);
        assert_eq!(select_test(MetricScale::Binary, &[0.0, 1.0]), TestChoice::McNemar);
    }

    #[test]
    fn judge_scores_are_ordinal() {
        let scores = [1.0, 3.0, 5.0, 2.0, 4.0, 3.0];
        assert_eq!(detect_scale(&scores), MetricScale::Ordinal);
        assert_eq!(select_test(MetricScale::Ordinal, &scores), TestChoice::Wilcoxon);
    }

    #[test]
    fn continuous_normal_large_n_routes_to_t() {
        let mut rng = Rng::new(1);
        let diffs: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        assert_eq!(detect_scale(&diffs), MetricScale::Continuous);
        assert_eq!(select_test(MetricScale::Continuous, &diffs), TestChoice::PairedT);
    }

    #[test]
    fn continuous_skewed_routes_to_wilcoxon() {
        let mut rng = Rng::new(2);
        let diffs: Vec<f64> = (0..100).map(|_| rng.lognormal(0.0, 1.0)).collect();
        assert_eq!(select_test(MetricScale::Continuous, &diffs), TestChoice::Wilcoxon);
    }

    #[test]
    fn continuous_small_n_routes_to_wilcoxon() {
        let mut rng = Rng::new(3);
        let diffs: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        assert_eq!(select_test(MetricScale::Continuous, &diffs), TestChoice::Wilcoxon);
    }

    #[test]
    fn complex_routes_to_permutation() {
        assert_eq!(select_test(MetricScale::Complex, &[1.0]), TestChoice::Permutation);
    }

    #[test]
    fn run_selected_executes() {
        let mut rng = Rng::new(4);
        let a: Vec<f64> = (0..50).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
        let b: Vec<f64> = (0..50).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect();
        let (choice, result) = run_selected_test(MetricScale::Binary, &a, &b, 100, &mut rng);
        assert_eq!(choice, TestChoice::McNemar);
        assert!((0.0..=1.0).contains(&result.p_value));
    }
}
