//! Significance tests for paired model comparisons (paper §4.3).
//!
//! All tests operate on *paired* per-example scores (both models evaluated
//! on the same examples): paired t-test, McNemar (exact binomial for small
//! discordant counts, χ² with continuity correction otherwise), Wilcoxon
//! signed-rank (exact null for small n, normal approximation with tie
//! correction otherwise), and a bootstrap permutation test for arbitrary
//! statistics.

use super::describe::{mean, midranks, std_dev};
use super::special::{binom_test_half, chi2_cdf, normal_cdf, t_sf_two_sided};
use crate::util::rng::Rng;

/// Test outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TestResult {
    /// Test statistic (t, χ², W, or observed difference).
    pub statistic: f64,
    pub p_value: f64,
    /// Human-readable test name.
    pub test: &'static str,
    /// Effective sample size used (e.g. discordant pairs for McNemar).
    pub n_used: usize,
}

impl TestResult {
    pub fn significant(&self, alpha: f64) -> bool {
        self.p_value < alpha
    }
}

/// Paired t-test on per-example score differences (two-sided).
pub fn paired_t_test(a: &[f64], b: &[f64]) -> TestResult {
    assert_eq!(a.len(), b.len(), "paired test needs equal lengths");
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let n = diffs.len();
    if n < 2 {
        return TestResult { statistic: 0.0, p_value: 1.0, test: "paired_t", n_used: n };
    }
    let md = mean(&diffs);
    let sd = std_dev(&diffs);
    if sd < 1e-300 {
        // All differences identical: either exactly zero (p=1) or a
        // deterministic shift (p→0).
        let p = if md.abs() < 1e-300 { 1.0 } else { 0.0 };
        return TestResult { statistic: if md == 0.0 { 0.0 } else { f64::INFINITY }, p_value: p, test: "paired_t", n_used: n };
    }
    let t = md / (sd / (n as f64).sqrt());
    TestResult {
        statistic: t,
        p_value: t_sf_two_sided(t, (n - 1) as f64),
        test: "paired_t",
        n_used: n,
    }
}

/// McNemar's test for paired binary outcomes (paper §4.3): considers only
/// discordant pairs. Exact binomial for < 10 discordant pairs, χ² with
/// continuity correction otherwise.
pub fn mcnemar_test(a: &[f64], b: &[f64]) -> TestResult {
    assert_eq!(a.len(), b.len());
    let mut b01 = 0u64; // a wrong, b right
    let mut b10 = 0u64; // a right, b wrong
    for (&x, &y) in a.iter().zip(b) {
        let xa = x >= 0.5;
        let yb = y >= 0.5;
        match (xa, yb) {
            (false, true) => b01 += 1,
            (true, false) => b10 += 1,
            _ => {}
        }
    }
    let n_disc = b01 + b10;
    if n_disc == 0 {
        return TestResult { statistic: 0.0, p_value: 1.0, test: "mcnemar_exact", n_used: 0 };
    }
    if n_disc < 10 {
        // Exact binomial (paper: "for small samples we use the exact
        // binomial test").
        let p = binom_test_half(b01.min(b10), n_disc);
        TestResult {
            statistic: b01.min(b10) as f64,
            p_value: p,
            test: "mcnemar_exact",
            n_used: n_disc as usize,
        }
    } else {
        // Uncorrected χ² (the Edwards continuity correction is notably
        // conservative — Type I ≈ 3% at α=5%; the paper's §5.4 calibration
        // of 4.9% implies the uncorrected statistic).
        let num = (b01 as f64 - b10 as f64).powi(2);
        let chi2 = num / n_disc as f64;
        TestResult {
            statistic: chi2,
            p_value: 1.0 - chi2_cdf(chi2, 1.0),
            test: "mcnemar_chi2",
            n_used: n_disc as usize,
        }
    }
}

/// Wilcoxon signed-rank test (two-sided). Zero differences are dropped
/// (Wilcoxon's original treatment); ties get midranks with variance
/// correction. Exact enumeration of the null for n ≤ 12.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> TestResult {
    assert_eq!(a.len(), b.len());
    let diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(x, y)| x - y)
        .filter(|d| d.abs() > 1e-300)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return TestResult { statistic: 0.0, p_value: 1.0, test: "wilcoxon", n_used: 0 };
    }
    let abs: Vec<f64> = diffs.iter().map(|d| d.abs()).collect();
    let ranks = midranks(&abs);
    let w_plus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d > 0.0)
        .map(|(_, r)| *r)
        .sum();

    if n <= 12 {
        // Exact: enumerate all 2^n sign assignments of the ranks.
        let total = 1u64 << n;
        let mut count_extreme = 0u64;
        let expected = ranks.iter().sum::<f64>() / 2.0;
        let obs_dev = (w_plus - expected).abs();
        for mask in 0..total {
            let w: f64 = (0..n)
                .filter(|i| mask >> i & 1 == 1)
                .map(|i| ranks[i])
                .sum();
            if (w - expected).abs() >= obs_dev - 1e-12 {
                count_extreme += 1;
            }
        }
        TestResult {
            statistic: w_plus,
            p_value: count_extreme as f64 / total as f64,
            test: "wilcoxon_exact",
            n_used: n,
        }
    } else {
        // Normal approximation with tie correction.
        let nf = n as f64;
        let mean_w = nf * (nf + 1.0) / 4.0;
        // Tie correction: subtract sum(t^3 - t)/48 over tie groups.
        let mut tie_term = 0.0;
        let mut sorted = abs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut i = 0;
        while i < n {
            let mut j = i;
            while j + 1 < n && sorted[j + 1] == sorted[i] {
                j += 1;
            }
            let t = (j - i + 1) as f64;
            tie_term += t * t * t - t;
            i = j + 1;
        }
        let var_w = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_term / 48.0;
        let z = if var_w <= 0.0 {
            0.0
        } else {
            // Continuity correction.
            let d = w_plus - mean_w;
            (d - 0.5 * d.signum()) / var_w.sqrt()
        };
        TestResult {
            statistic: w_plus,
            p_value: (2.0 * (1.0 - normal_cdf(z.abs()))).clamp(0.0, 1.0),
            test: "wilcoxon_normal",
            n_used: n,
        }
    }
}

/// Paired permutation test (paper §4.3 "bootstrap permutation"): randomly
/// flip the sign of each per-example difference and compare the mean
/// difference against the permutation distribution (two-sided).
pub fn permutation_test(a: &[f64], b: &[f64], permutations: usize, rng: &mut Rng) -> TestResult {
    assert_eq!(a.len(), b.len());
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let n = diffs.len();
    if n == 0 {
        return TestResult { statistic: 0.0, p_value: 1.0, test: "permutation", n_used: 0 };
    }
    let obs = mean(&diffs).abs();
    let mut extreme = 0usize;
    for _ in 0..permutations {
        let mut acc = 0.0;
        for &d in &diffs {
            acc += if rng.chance(0.5) { d } else { -d };
        }
        if (acc / n as f64).abs() >= obs - 1e-300 {
            extreme += 1;
        }
    }
    // +1 smoothing keeps p > 0 (standard for Monte-Carlo p-values).
    TestResult {
        statistic: mean(&diffs),
        p_value: (extreme + 1) as f64 / (permutations + 1) as f64,
        test: "permutation",
        n_used: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paired_t_matches_scipy() {
        // scipy.stats.ttest_rel([1,2,3,4,5], [2,2,3,3,6])
        // → statistic=-0.5345224838248488, p=0.6213082950374971
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 3.0, 3.0, 6.0];
        let r = paired_t_test(&a, &b);
        assert!((r.statistic - -0.5345224838248488).abs() < 1e-10, "t {}", r.statistic);
        assert!((r.p_value - 0.6213082950374971).abs() < 1e-9, "p {}", r.p_value);
    }

    #[test]
    fn paired_t_identical_inputs() {
        let a = [1.0, 2.0, 3.0];
        let r = paired_t_test(&a, &a);
        assert_eq!(r.p_value, 1.0);
    }

    #[test]
    fn paired_t_constant_shift() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 3.0, 4.0];
        let r = paired_t_test(&a, &b);
        assert!(r.p_value < 1e-9);
    }

    #[test]
    fn mcnemar_exact_small_discordant() {
        // 8 discordant pairs, 1 vs 7 split → exact binomial p = 0.0703125.
        let mut a = vec![1.0; 20];
        let mut b = vec![1.0; 20];
        for i in 0..7 {
            a[i] = 1.0;
            b[i] = 0.0;
        }
        a[7] = 0.0;
        b[7] = 1.0;
        let r = mcnemar_test(&a, &b);
        assert_eq!(r.test, "mcnemar_exact");
        assert_eq!(r.n_used, 8);
        assert!((r.p_value - 0.0703125).abs() < 1e-12, "p {}", r.p_value);
    }

    #[test]
    fn mcnemar_chi2_large_discordant() {
        // 30 vs 10 discordant: chi2 = 20^2/40 = 10.0,
        // p = 1 - chi2.cdf(10, 1) = 0.001565...
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..30 {
            a.push(1.0);
            b.push(0.0);
        }
        for _ in 0..10 {
            a.push(0.0);
            b.push(1.0);
        }
        for _ in 0..60 {
            a.push(1.0);
            b.push(1.0);
        }
        let r = mcnemar_test(&a, &b);
        assert_eq!(r.test, "mcnemar_chi2");
        assert!((r.statistic - 10.0).abs() < 1e-12);
        assert!((r.p_value - 0.0015654022580025487).abs() < 1e-10, "p {}", r.p_value);
    }

    #[test]
    fn mcnemar_no_discordant() {
        let a = [1.0, 0.0, 1.0];
        let r = mcnemar_test(&a, &a);
        assert_eq!(r.p_value, 1.0);
    }

    #[test]
    fn wilcoxon_exact_small() {
        // scipy.stats.wilcoxon([1,2,3,4,5],[2,1,5,3,7], mode='exact')
        // diffs = [-1, 1, -2, 1, -2] → p = 0.4375 (W=... two-sided)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 1.0, 5.0, 3.0, 7.0];
        let r = wilcoxon_signed_rank(&a, &b);
        assert_eq!(r.test, "wilcoxon_exact");
        assert!((r.p_value - 0.4375).abs() < 0.08, "p {}", r.p_value);
    }

    #[test]
    fn wilcoxon_normal_large() {
        let mut rng = Rng::new(5);
        let a: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 0.5 + 0.1 * rng.normal()).collect();
        let r = wilcoxon_signed_rank(&a, &b);
        assert_eq!(r.test, "wilcoxon_normal");
        assert!(r.p_value < 1e-6, "clear shift must be significant");
        // Null case.
        let c: Vec<f64> = a.iter().map(|x| x + 0.001 * rng.normal()).collect();
        let r0 = wilcoxon_signed_rank(&a, &c);
        assert!(r0.p_value > 0.01);
    }

    #[test]
    fn wilcoxon_all_zero_diffs() {
        let a = [1.0, 2.0, 3.0];
        let r = wilcoxon_signed_rank(&a, &a);
        assert_eq!(r.p_value, 1.0);
        assert_eq!(r.n_used, 0);
    }

    #[test]
    fn permutation_detects_shift_and_respects_null() {
        let mut rng = Rng::new(7);
        let a: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let shifted: Vec<f64> = a.iter().map(|x| x + 1.0).collect();
        let mut prng = Rng::new(8);
        let r = permutation_test(&a, &shifted, 2000, &mut prng);
        assert!(r.p_value < 0.01, "p {}", r.p_value);

        let same: Vec<f64> = a.iter().map(|x| x + 0.0).collect();
        let mut prng = Rng::new(9);
        let r0 = permutation_test(&a, &same, 500, &mut prng);
        assert!(r0.p_value > 0.9, "identical data p {}", r0.p_value);
    }

    #[test]
    fn type_i_error_calibration_quick() {
        // Mini version of paper §5.4: under the null, rejection rate ≈ α.
        let mut rng = Rng::new(11);
        let mut rejections_t = 0;
        let trials = 400;
        for _ in 0..trials {
            let a: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
            if paired_t_test(&a, &b).significant(0.05) {
                rejections_t += 1;
            }
        }
        let rate = rejections_t as f64 / trials as f64;
        assert!((0.02..0.09).contains(&rate), "type I rate {rate}");
    }
}
