//! Regeneration of every table and figure in the paper's evaluation
//! (§5). Each function returns the rendered table plus the raw series so
//! benches and tests can assert on the *shape* of the result (who wins,
//! by what factor, where the crossovers fall) — absolute numbers differ
//! from the authors' Databricks testbed by construction.

use crate::providers::pricing::{lookup, ModelProfile};
use crate::report::table;
use crate::sim::{simulate, simulate_sequential, SimParams};
use crate::stats::describe::{mean, std_dev};
use crate::stats::{
    bca_bootstrap, mcnemar_test, paired_t_test, percentile_bootstrap, t_interval,
    wilcoxon_signed_rank,
};
use crate::util::rng::Rng;

/// Figure 2: throughput vs executor count (3 runs, mean ± stddev).
pub struct Fig2Row {
    pub executors: usize,
    pub mean_throughput: f64,
    pub std_throughput: f64,
}

pub fn figure2(n_examples: usize) -> (Vec<Fig2Row>, String) {
    let mut rows = Vec::new();
    for executors in [1, 2, 4, 6, 8, 12, 16] {
        let tps: Vec<f64> = (0..3)
            .map(|run| {
                let p = SimParams { executors, n_examples, seed: run as u64, ..Default::default() };
                simulate(&p, None).throughput_per_min
            })
            .collect();
        rows.push(Fig2Row {
            executors,
            mean_throughput: mean(&tps),
            std_throughput: std_dev(&tps),
        });
    }
    let seq = simulate_sequential(&SimParams { n_examples: n_examples.min(5000), ..Default::default() });
    let mut cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.executors.to_string(),
                format!("{:.0}", r.mean_throughput),
                format!("±{:.0}", r.std_throughput),
            ]
        })
        .collect();
    cells.push(vec![
        "sequential".into(),
        format!("{:.0}", seq.throughput_per_min),
        "±0".into(),
    ]);
    let speedup = rows.iter().find(|r| r.executors == 8).map(|r| r.mean_throughput).unwrap_or(0.0)
        / seq.throughput_per_min.max(1e-9);
    let mut text = String::from("Figure 2 — throughput scaling with executor count\n");
    text.push_str(&table(&["executors", "examples/min", "stddev"], &cells));
    text.push_str(&format!(
        "sequential baseline {:.0}/min; speedup at 8 executors = {:.1}x (paper: 21x)\n",
        seq.throughput_per_min, speedup
    ));
    (rows, text)
}

/// Table 3: throughput by dataset size at 8 executors.
pub struct Tab3Row {
    pub examples: usize,
    pub throughput: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub total_secs: f64,
}

pub fn table3() -> (Vec<Tab3Row>, String) {
    let mut rows = Vec::new();
    for n in [1_000usize, 10_000, 50_000, 100_000] {
        let p = SimParams { n_examples: n, executors: 8, ..Default::default() };
        let out = simulate(&p, lookup("openai", "gpt-4o"));
        rows.push(Tab3Row {
            examples: n,
            throughput: out.throughput_per_min,
            p50_ms: out.latency_p50_ms,
            p99_ms: out.latency_p99_ms,
            total_secs: out.total_secs,
        });
    }
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.examples.to_string(),
                format!("{:.0}/min", r.throughput),
                format!("{:.0} ms", r.p50_ms),
                format!("{:.0} ms", r.p99_ms),
                if r.total_secs < 100.0 {
                    format!("{:.1}s", r.total_secs)
                } else {
                    format!("{:.1}min", r.total_secs / 60.0)
                },
            ]
        })
        .collect();
    let mut text = String::from("Table 3 — throughput by dataset size (8 executors, gpt-4o sim)\n");
    text.push_str(&table(
        &["Examples", "Throughput", "Latency p50", "Latency p99", "Total Time"],
        &cells,
    ));
    (rows, text)
}

/// Table 4: caching effectiveness over evaluation iterations.
pub struct Tab4Row {
    pub label: String,
    pub hit_rate: f64,
    pub api_calls: u64,
    pub cost: f64,
    pub secs: f64,
}

pub fn table4(n_examples: usize) -> (Vec<Tab4Row>, String) {
    let profile = lookup("openai", "gpt-4o").unwrap();
    // §5.3 workload: 500-token prompts, 200-token responses.
    let base = SimParams {
        n_examples,
        executors: 8,
        input_tokens: 500,
        output_tokens: 200,
        tokens_per_request: 180.0,
        ..Default::default()
    };

    let mut rows = Vec::new();
    let initial = simulate(&base, Some(profile));
    rows.push(Tab4Row {
        label: "Initial run".into(),
        hit_rate: 0.0,
        api_calls: initial.api_calls,
        cost: initial.cost_usd,
        secs: initial.total_secs,
    });
    // Three metric-iteration replays: 100% hit rate, metric-compute only.
    let replay_params = SimParams {
        cache_hit_rate: 1.0,
        local_ms: 3.0, // per-example metric recomputation
        ..base.clone()
    };
    for i in 1..=3 {
        let replay = simulate(&SimParams { seed: i, ..replay_params.clone() }, Some(profile));
        rows.push(Tab4Row {
            label: format!("Metric change {i}"),
            hit_rate: 1.0,
            api_calls: replay.api_calls,
            cost: replay.cost_usd,
            secs: replay.total_secs,
        });
    }
    let with_cache_cost: f64 = rows.iter().map(|r| r.cost).sum();
    let with_cache_time: f64 = rows.iter().map(|r| r.secs).sum();
    let without_cache_cost = initial.cost_usd * 4.0;
    let without_cache_time = initial.total_secs * 4.0;

    let mut cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.0}%", r.hit_rate * 100.0),
                r.api_calls.to_string(),
                format!("${:.2}", r.cost),
                format!("{:.0}s", r.secs),
            ]
        })
        .collect();
    cells.push(vec![
        "Total (with cache)".into(),
        "-".into(),
        rows.iter().map(|r| r.api_calls).sum::<u64>().to_string(),
        format!("${:.2}", with_cache_cost),
        format!("{:.1}min", with_cache_time / 60.0),
    ]);
    cells.push(vec![
        "Without cache".into(),
        "-".into(),
        (initial.api_calls * 4).to_string(),
        format!("${:.2}", without_cache_cost),
        format!("{:.1}min", without_cache_time / 60.0),
    ]);
    let mut text = format!("Table 4 — caching effectiveness ({n_examples} examples)\n");
    text.push_str(&table(&["Iteration", "Cache Hits", "API Calls", "Cost", "Time"], &cells));
    text.push_str(&format!(
        "savings: cost {:.0}% (paper: 75%), time {:.0}% (paper: 69%)\n",
        100.0 * (1.0 - with_cache_cost / without_cache_cost),
        100.0 * (1.0 - with_cache_time / without_cache_time),
    ));
    (rows, text)
}

/// Table 5: empirical coverage of 95% CIs on lognormal(σ=0.5) data.
pub struct Tab5Row {
    pub method: &'static str,
    pub coverage: Vec<f64>, // per sample size
}

pub fn table5(datasets: usize, bootstrap_iters: usize) -> (Vec<Tab5Row>, String) {
    let sizes = [50usize, 200, 1000];
    let sigma: f64 = 0.5;
    // True mean of lognormal(0, σ): exp(σ²/2).
    let true_mean = (sigma * sigma / 2.0).exp();

    let mut cover = vec![[0usize; 3]; 3]; // method × size
    let mut rng = Rng::new(12345);
    for (si, &n) in sizes.iter().enumerate() {
        for _ in 0..datasets {
            let xs: Vec<f64> = (0..n).map(|_| rng.lognormal(0.0, sigma)).collect();
            let mut brng = rng.fork(1);
            let pct = percentile_bootstrap(&xs, mean, 0.95, bootstrap_iters, &mut brng);
            let mut brng = rng.fork(2);
            let bca = bca_bootstrap(&xs, mean, 0.95, bootstrap_iters, &mut brng);
            let t = t_interval(&xs, 0.95);
            if pct.contains(true_mean) {
                cover[0][si] += 1;
            }
            if bca.contains(true_mean) {
                cover[1][si] += 1;
            }
            if t.contains(true_mean) {
                cover[2][si] += 1;
            }
        }
    }
    let methods = ["Percentile bootstrap", "BCa bootstrap", "Analytical (t-based)"];
    let rows: Vec<Tab5Row> = methods
        .iter()
        .enumerate()
        .map(|(mi, &method)| Tab5Row {
            method,
            coverage: (0..3).map(|si| cover[mi][si] as f64 / datasets as f64).collect(),
        })
        .collect();
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut c = vec![r.method.to_string()];
            c.extend(r.coverage.iter().map(|v| format!("{:.1}%", v * 100.0)));
            c
        })
        .collect();
    let mut text = format!(
        "Table 5 — empirical coverage of 95% CIs (lognormal σ=0.5, {datasets} datasets)\n"
    );
    text.push_str(&table(&["Method", "n = 50", "n = 200", "n = 1000"], &cells));
    (rows, text)
}

/// Table 6: cost comparison across providers (10k examples, 400/150 tok).
pub fn table6() -> (Vec<(&'static ModelProfile, f64, f64, f64)>, String) {
    let picks = [
        ("openai", "gpt-4o"),
        ("openai", "gpt-4o-mini"),
        ("anthropic", "claude-3-5-sonnet"),
        ("anthropic", "claude-3-haiku"),
        ("google", "gemini-1.5-pro"),
    ];
    let mut rows = Vec::new();
    for (prov, model) in picks {
        let m = lookup(prov, model).unwrap();
        let (input, output, total) = m.workload_cost(10_000, 400, 150);
        rows.push((m, input, output, total));
    }
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|(m, i, o, t)| {
            vec![
                format!("{}/{}", m.provider, m.model),
                format!("${:.2}", i),
                format!("${:.2}", o),
                format!("${:.2}", t),
            ]
        })
        .collect();
    let mut text = String::from("Table 6 — cost comparison across providers (10,000 examples)\n");
    text.push_str(&table(&["Provider/Model", "Input Cost", "Output Cost", "Total"], &cells));
    (rows, text)
}

/// §5.4: Type I error of the significance tests under the null.
pub struct TypeIRow {
    pub test: &'static str,
    pub rate: f64,
}

pub fn type_i_error(comparisons: usize, n: usize) -> (Vec<TypeIRow>, String) {
    let mut rng = Rng::new(777);
    let mut rej = [0usize; 3];
    for _ in 0..comparisons {
        // Null: both "models" draw from the same distribution.
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        if paired_t_test(&a, &b).significant(0.05) {
            rej[0] += 1;
        }
        if wilcoxon_signed_rank(&a, &b).significant(0.05) {
            rej[1] += 1;
        }
        // Binary null for McNemar.
        let ab: Vec<f64> = (0..n).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
        let bb: Vec<f64> = (0..n).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
        if mcnemar_test(&ab, &bb).significant(0.05) {
            rej[2] += 1;
        }
    }
    let rows = vec![
        TypeIRow { test: "Paired t-test", rate: rej[0] as f64 / comparisons as f64 },
        TypeIRow { test: "Wilcoxon signed-rank", rate: rej[1] as f64 / comparisons as f64 },
        TypeIRow { test: "McNemar", rate: rej[2] as f64 / comparisons as f64 },
    ];
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| vec![r.test.to_string(), format!("{:.2}%", r.rate * 100.0)])
        .collect();
    let mut text = format!(
        "§5.4 — Type I error at α=0.05 ({comparisons} null comparisons, n={n}; paper: 4.9–5.1%)\n"
    );
    text.push_str(&table(&["Test", "Rejection rate"], &cells));
    (rows, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_shape() {
        let (rows, text) = figure2(10_000);
        assert!(text.contains("Figure 2"));
        // Linear region: 4 executors ≈ 4× one executor (±25%).
        let t1 = rows.iter().find(|r| r.executors == 1).unwrap().mean_throughput;
        let t4 = rows.iter().find(|r| r.executors == 4).unwrap().mean_throughput;
        assert!((3.0..5.0).contains(&(t4 / t1)), "4-exec scaling {}", t4 / t1);
        // Plateau: 16 ≈ 8-12 region capped near global limit.
        let t16 = rows.iter().find(|r| r.executors == 16).unwrap().mean_throughput;
        assert!(t16 < 10_500.0, "plateau {t16}");
    }

    #[test]
    fn table3_shape() {
        let (rows, _) = table3();
        // Throughput grows with dataset size (scheduling amortization).
        assert!(rows[0].throughput < rows[3].throughput);
        // Large runs near the paper's ~9,800/min plateau.
        assert!((8_000.0..10_500.0).contains(&rows[3].throughput), "{}", rows[3].throughput);
    }

    #[test]
    fn table4_savings() {
        let (rows, text) = table4(50_000);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[1].api_calls, 0);
        assert_eq!(rows[1].cost, 0.0);
        // Replays are much faster than the initial run.
        assert!(rows[1].secs < rows[0].secs / 3.0);
        assert!(text.contains("savings"));
    }

    #[test]
    fn table5_bca_beats_percentile_small_n() {
        // Smaller reps for test speed; the bench runs the full 1000.
        let (rows, _) = table5(150, 300);
        let pct50 = rows[0].coverage[0];
        let bca50 = rows[1].coverage[0];
        assert!(bca50 >= pct50 - 0.02, "bca {bca50} pct {pct50}");
        // All methods close to nominal at n=1000.
        for r in &rows {
            assert!(r.coverage[2] > 0.90, "{}: {:?}", r.method, r.coverage);
        }
    }

    #[test]
    fn table6_matches_paper_exactly() {
        let (rows, text) = table6();
        assert!((rows[0].3 - 32.50).abs() < 1e-9); // gpt-4o
        assert!((rows[1].3 - 1.50).abs() < 1e-9); // gpt-4o-mini
        assert!((rows[2].3 - 34.50).abs() < 1e-9); // claude-3-5-sonnet
        assert!((rows[4].3 - 12.50).abs() < 1e-9); // gemini-1.5-pro
        assert!(text.contains("Table 6"));
    }

    #[test]
    fn type_i_error_near_nominal() {
        let (rows, _) = type_i_error(400, 60);
        for r in &rows {
            assert!(
                (0.02..0.09).contains(&r.rate),
                "{} rate {} out of band",
                r.test,
                r.rate
            );
        }
    }
}
