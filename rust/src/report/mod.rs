//! Plain-text / markdown table rendering for results and paper-table
//! reproductions.

pub mod tables;

use crate::coordinator::{ComparisonResult, EvalResult};

/// Render rows as an aligned ASCII table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<width$} | ", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('\n');
    out.push_str("|");
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Evaluation summary block (quickstart-style console output).
pub fn eval_summary(result: &EvalResult) -> String {
    let mut rows = Vec::new();
    for m in &result.metrics {
        rows.push(vec![
            m.name.clone(),
            format!("{:.4}", m.value),
            format!("({:.4}, {:.4})", m.ci.lo, m.ci.hi),
            m.ci.method.to_string(),
            m.n.to_string(),
            m.n_failed.to_string(),
            m.unparseable.to_string(),
        ]);
    }
    let mut out = format!(
        "== {} — {}/{} ==\n",
        result.task_id, result.provider, result.model
    );
    out.push_str(&table(
        &["metric", "value", "95% CI", "method", "n", "failed", "unparseable"],
        &rows,
    ));
    let inf = &result.inference;
    out.push_str(&format!(
        "inference: {} examples, {} api calls, {} cache hits ({:.1}% hit rate), \
         {} retries, {} failed\n",
        inf.examples,
        inf.api_calls,
        inf.cache_hits,
        100.0 * inf.cache_hits as f64 / (inf.cache_hits + inf.cache_misses).max(1) as f64,
        inf.retries,
        inf.failed,
    ));
    out.push_str(&format!(
        "cost: ${:.4}  |  latency p50 {:.0}ms p99 {:.0}ms  |  throughput {:.0}/min  |  wall {:.1}s\n",
        inf.total_cost_usd, inf.latency_p50_ms, inf.latency_p99_ms, inf.throughput_per_min, inf.wall_secs,
    ));
    // Rescore/replay runs carry the configured concurrency but never
    // pipeline (no provider calls) — only report a pipeline that ran.
    if inf.concurrency > 1 && inf.peak_in_flight > 0 {
        out.push_str(&format!(
            "pipeline: concurrency {} per executor, peak {} in flight\n",
            inf.concurrency, inf.peak_in_flight,
        ));
    }
    let mc = &result.metric_calls;
    if mc.total() > 0 {
        // Judge/RAG metric calls are billed separately from inference.
        out.push_str(&format!(
            "metric stage: {} judge api calls (${:.4}), {} cache hits, {} failed\n",
            mc.api_calls, mc.cost_usd, mc.cache_hits, mc.failed,
        ));
    }
    let s = &inf.sched;
    out.push_str(&format!(
        "scheduler: {} tasks, {} steals, {} speculative ({} won), {} splits, {} retries, \
         {} blacklisted  |  task skew {:.2}x\n",
        s.tasks,
        s.steals,
        s.speculative_launched,
        s.speculative_wins,
        s.splits,
        s.retries,
        s.blacklisted_executors.len(),
        s.skew_ratio,
    ));
    if s.executor_deaths > 0 {
        // Deaths are distinct from task failures: a whole executor
        // (process) was lost and its in-flight work retried elsewhere.
        out.push_str(&format!(
            "executor deaths: {} (in-flight tasks retried on surviving executors)\n",
            s.executor_deaths,
        ));
        if s.host_deaths > 0 {
            // Remote backend: whole serve-worker hosts lost, each taking
            // all of its executor connections down at once.
            out.push_str(&format!(
                "host deaths: {} (every executor on a lost host settled together)\n",
                s.host_deaths,
            ));
        }
    }
    if s.restored_rows > 0 {
        // Distinguish carried-over (restored) work from re-executed work:
        // api_calls/cost above cover only this run's fresh executions.
        out.push_str(&format!(
            "resume: {} tasks ({} rows) restored from checkpoint; \
             {} rows freshly executed this run\n",
            s.restored_tasks,
            s.restored_rows,
            inf.examples.saturating_sub(s.restored_rows),
        ));
    }
    if s.rows_saved > 0 || s.waves > 0 {
        // Adaptive stopping ran: account every row as evaluated or saved,
        // and name the certified metrics with their stop wave.
        let certified: Vec<String> = result
            .metrics
            .iter()
            .filter(|m| m.certified == Some(true))
            .map(|m| match m.stopped_at_wave {
                Some(w) => format!("{} (wave {})", m.name, w),
                None => m.name.clone(),
            })
            .collect();
        out.push_str(&format!(
            "stopping: {} waves, {} rows evaluated, {} rows saved  |  certified: {}\n",
            s.waves,
            s.rows_evaluated,
            s.rows_saved,
            if certified.is_empty() { "none".to_string() } else { certified.join(", ") },
        ));
    }
    out
}

/// Comparison summary block.
pub fn comparison_summary(result: &ComparisonResult) -> String {
    let mut rows = Vec::new();
    for c in &result.comparisons {
        rows.push(vec![
            c.metric.clone(),
            format!("{:.4}", c.value_a),
            format!("{:.4}", c.value_b),
            format!("{:+.4}", c.value_a - c.value_b),
            c.test.test.to_string(),
            format!("{:.4}", c.test.p_value),
            if c.test.significant(result.alpha) { "YES".into() } else { "no".into() },
            format!("{:.3} ({})", c.cohens_d.value, c.cohens_d.magnitude()),
        ]);
    }
    let mut out = format!("== {} vs {} (α = {}) ==\n", result.model_a, result.model_b, result.alpha);
    out.push_str(&table(
        &["metric", "A", "B", "Δ", "test", "p", "sig", "cohen's d"],
        &rows,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer-name".into(), "2.5".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        // All rows equal width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[3].contains("longer-name"));
    }

    #[test]
    fn empty_rows_ok() {
        let t = table(&["a"], &[]);
        assert!(t.contains("| a"));
    }
}
