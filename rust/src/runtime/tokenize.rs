//! SimTokenizer: deterministic hash tokenizer for the SimLM encoder.
//!
//! The artifacts embed no learned vocabulary, so tokenization is a stable
//! word-hash into `[RESERVED, vocab)`. Both sides of a comparison tokenize
//! identically, which is the property the semantic metrics need: equal
//! strings → identical token ids → cosine similarity 1.0, and shared words
//! map to shared ids so partial overlap is graded smoothly.

/// Token id 0 is padding, 1 is BOS/unknown-empty.
const PAD: i32 = 0;
const BOS: i32 = 1;
const RESERVED: u64 = 2;

#[derive(Debug, Clone)]
pub struct SimTokenizer {
    pub vocab_size: usize,
    pub max_seq: usize,
}

impl SimTokenizer {
    pub fn new(vocab_size: usize, max_seq: usize) -> Self {
        Self { vocab_size, max_seq }
    }

    /// FNV-1a over the lowercased word bytes.
    fn word_id(&self, word: &str) -> i32 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in word.bytes() {
            let b = b.to_ascii_lowercase();
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        (RESERVED + h % (self.vocab_size as u64 - RESERVED)) as i32
    }

    /// Split into alphanumeric word chunks (punctuation-separated).
    fn words(text: &str) -> impl Iterator<Item = &str> {
        text.split(|c: char| !c.is_alphanumeric() && c != '\'')
            .filter(|w| !w.is_empty())
    }

    /// Encode to fixed-length `(ids, mask)` of `max_seq`, truncating long
    /// inputs and padding short ones.
    pub fn encode(&self, text: &str) -> (Vec<i32>, Vec<f32>) {
        let mut ids = Vec::with_capacity(self.max_seq);
        ids.push(BOS);
        for w in Self::words(text) {
            if ids.len() >= self.max_seq {
                break;
            }
            ids.push(self.word_id(w));
        }
        let used = ids.len();
        let mut mask = vec![1.0f32; used];
        ids.resize(self.max_seq, PAD);
        mask.resize(self.max_seq, 0.0);
        (ids, mask)
    }

    /// Number of non-pad tokens `encode` would produce.
    pub fn token_count(&self, text: &str) -> usize {
        (1 + Self::words(text).count()).min(self.max_seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> SimTokenizer {
        SimTokenizer::new(4096, 64)
    }

    #[test]
    fn fixed_length_output() {
        let (ids, mask) = tok().encode("hello world");
        assert_eq!(ids.len(), 64);
        assert_eq!(mask.len(), 64);
        assert_eq!(mask.iter().filter(|&&m| m > 0.0).count(), 3); // BOS + 2
    }

    #[test]
    fn deterministic_and_case_insensitive() {
        let (a, _) = tok().encode("The Quick Fox");
        let (b, _) = tok().encode("the quick fox");
        assert_eq!(a, b);
    }

    #[test]
    fn equal_strings_equal_ids() {
        let t = tok();
        assert_eq!(t.encode("new york city"), t.encode("new york city"));
    }

    #[test]
    fn ids_in_range() {
        let (ids, _) = tok().encode("a b c d e f g punctuation, and: more!");
        for &id in &ids {
            assert!((0..4096).contains(&id), "id {id} out of range");
        }
    }

    #[test]
    fn truncates_long_input() {
        let long: String = (0..500).map(|i| format!("w{i} ")).collect();
        let (ids, mask) = tok().encode(&long);
        assert_eq!(ids.len(), 64);
        assert!(mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn empty_input_is_bos_only() {
        let (ids, mask) = tok().encode("");
        assert_eq!(ids[0], 1);
        assert_eq!(mask[0], 1.0);
        assert_eq!(mask[1], 0.0);
    }

    #[test]
    fn shared_words_share_ids() {
        let t = tok();
        let (a, _) = t.encode("paris is the capital");
        let (b, _) = t.encode("capital paris");
        // "paris" id appears in both encodings.
        assert!(b.contains(&a[1]));
    }
}
