//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// SimLM encoder dimensions (must match `python/compile/model.py`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub d_ff: usize,
    pub batch: usize,
    pub seed: u64,
    pub kernel_tile_m: usize,
    pub kernel_tile_n: usize,
}

/// Fixed shapes of the bootstrap-resample graph.
#[derive(Debug, Clone)]
pub struct BootstrapDims {
    pub resamples: usize,
    pub max_n: usize,
}

/// One weight tensor: name + shape, in the exact order of `weights.bin`.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub bootstrap: BootstrapDims,
    pub params: Vec<ParamSpec>,
    pub weights_file: PathBuf,
    pub weights_sha256: String,
    pub embedder_hlo: PathBuf,
    pub bertscore_hlo: PathBuf,
    pub bootstrap_hlo: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        if v.get("format_version")?.as_i64()? != 1 {
            bail!("unsupported manifest format_version");
        }

        let m = v.get("model")?;
        let model = ModelDims {
            vocab_size: m.get("vocab_size")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            max_seq: m.get("max_seq")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            batch: m.get("batch")?.as_usize()?,
            seed: m.get("seed")?.as_i64()? as u64,
            kernel_tile_m: m.usize_or("kernel_tile_m", 32),
            kernel_tile_n: m.usize_or("kernel_tile_n", 32),
        };

        let b = v.get("bootstrap")?;
        let bootstrap = BootstrapDims {
            resamples: b.get("resamples")?.as_usize()?,
            max_n: b.get("max_n")?.as_usize()?,
        };

        let w = v.get("weights")?;
        let params = w
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize().map_err(anyhow::Error::from))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let art = v.get("artifacts")?;
        let art_file = |name: &str| -> Result<PathBuf> {
            Ok(dir.join(art.get(name)?.get("file")?.as_str()?))
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            weights_file: dir.join(w.get("file")?.as_str()?),
            weights_sha256: w.get("sha256")?.as_str()?.to_string(),
            embedder_hlo: art_file("embedder")?,
            bertscore_hlo: art_file("bertscore")?,
            bootstrap_hlo: art_file("bootstrap")?,
            model,
            bootstrap,
            params,
        })
    }

    /// Total weight scalar count (f32 elements in weights.bin).
    pub fn total_weights(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_manifest() {
        let dir = artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_model % m.model.n_heads, 0);
        assert!(m.model.batch > 0);
        assert!(m.total_weights() > 0);
        // Weight blob size must match the manifest exactly.
        let meta = std::fs::metadata(&m.weights_file).unwrap();
        assert_eq!(meta.len() as usize, m.total_weights() * 4);
        assert!(m.embedder_hlo.exists());
        assert!(m.bertscore_hlo.exists());
        assert!(m.bootstrap_hlo.exists());
    }
}
