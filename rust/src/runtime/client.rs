//! PJRT runtime: load the AOT artifacts once, execute them from the L3 hot
//! path. Python never runs here — the HLO text was produced at build time
//! by `python/compile/aot.py`.
//!
//! Weight tensors are transferred to the device once at load (as
//! `PjRtBuffer`s) and reused for every call; only the small per-call inputs
//! (token ids, masks, resample indices) cross the host↔device boundary per
//! execution.

use std::path::Path;

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::tokenize::SimTokenizer;
use crate::util::rng::Rng;

/// Per-example BERTScore output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BertScore {
    pub precision: f32,
    pub recall: f32,
    pub f1: f32,
}

/// Loaded semantic runtime: one PJRT CPU client + three compiled
/// executables + resident weight buffers.
///
/// NOTE: PJRT handles are raw pointers (`!Send`/`!Sync`); the coordinator
/// owns the runtime on a dedicated thread and funnels batches through it.
pub struct SemanticRuntime {
    pub manifest: Manifest,
    pub tokenizer: SimTokenizer,
    client: PjRtClient,
    weights: Vec<PjRtBuffer>,
    embedder: PjRtLoadedExecutable,
    bertscore: PjRtLoadedExecutable,
    bootstrap: PjRtLoadedExecutable,
    /// Executions per artifact, for perf accounting.
    pub exec_counts: std::cell::Cell<(u64, u64, u64)>,
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {path:?}"))
}

impl SemanticRuntime {
    /// Load manifest, weights, and compile all three artifacts.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;

        // Weights: verify integrity, then transfer each tensor to device.
        let blob = std::fs::read(&manifest.weights_file)
            .with_context(|| format!("reading {:?}", manifest.weights_file))?;
        let digest = format!("{:x}", Sha256::digest(&blob));
        if digest != manifest.weights_sha256 {
            bail!(
                "weights.bin sha256 mismatch: manifest {} != file {digest}",
                manifest.weights_sha256
            );
        }
        if blob.len() != manifest.total_weights() * 4 {
            bail!("weights.bin size mismatch");
        }
        let mut weights = Vec::with_capacity(manifest.params.len());
        let mut off = 0usize;
        for p in &manifest.params {
            let n = p.len();
            let mut host = vec![0f32; n];
            let bytes = &blob[off * 4..(off + n) * 4];
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                host[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            weights.push(client.buffer_from_host_buffer(&host, &p.shape, None)?);
            off += n;
        }

        let embedder = compile(&client, &manifest.embedder_hlo)?;
        let bertscore = compile(&client, &manifest.bertscore_hlo)?;
        let bootstrap = compile(&client, &manifest.bootstrap_hlo)?;
        let tokenizer = SimTokenizer::new(manifest.model.vocab_size, manifest.model.max_seq);

        Ok(Self {
            manifest,
            tokenizer,
            client,
            weights,
            embedder,
            bertscore,
            bootstrap,
            exec_counts: std::cell::Cell::new((0, 0, 0)),
        })
    }

    fn ids_buffer(&self, ids: &[i32]) -> Result<PjRtBuffer> {
        let m = &self.manifest.model;
        Ok(self
            .client
            .buffer_from_host_buffer(ids, &[m.batch, m.max_seq], None)?)
    }

    fn mask_buffer(&self, mask: &[f32]) -> Result<PjRtBuffer> {
        let m = &self.manifest.model;
        Ok(self
            .client
            .buffer_from_host_buffer(mask, &[m.batch, m.max_seq], None)?)
    }

    /// Embed one fixed-size batch: `ids`/`mask` are row-major
    /// `[batch, max_seq]`. Returns `[batch, d_model]` row-major.
    pub fn embed_batch(&self, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        assert_eq!(ids.len(), m.batch * m.max_seq);
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        let ids_b = self.ids_buffer(ids)?;
        let mask_b = self.mask_buffer(mask)?;
        args.push(&ids_b);
        args.push(&mask_b);
        let out = self.embedder.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        let (e, b, s) = self.exec_counts.get();
        self.exec_counts.set((e + 1, b, s));
        Ok(lit.to_vec::<f32>()?)
    }

    /// Embed arbitrarily many texts: tokenize, pad to full batches, return
    /// one unit-norm `d_model` vector per text.
    pub fn embed_texts(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let m = &self.manifest.model;
        let (bsz, seq, d) = (m.batch, m.max_seq, m.d_model);
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(bsz) {
            let mut ids = vec![0i32; bsz * seq];
            let mut mask = vec![0f32; bsz * seq];
            for (i, text) in chunk.iter().enumerate() {
                let (t_ids, t_mask) = self.tokenizer.encode(text);
                ids[i * seq..(i + 1) * seq].copy_from_slice(&t_ids);
                mask[i * seq..(i + 1) * seq].copy_from_slice(&t_mask);
            }
            let pooled = self.embed_batch(&ids, &mask)?;
            for i in 0..chunk.len() {
                out.push(pooled[i * d..(i + 1) * d].to_vec());
            }
        }
        Ok(out)
    }

    /// BERTScore over one fixed batch of (candidate, reference) id/mask
    /// pairs. Returns `batch` scores.
    pub fn bertscore_batch(
        &self,
        ids_a: &[i32],
        mask_a: &[f32],
        ids_b: &[i32],
        mask_b: &[f32],
    ) -> Result<Vec<BertScore>> {
        let m = &self.manifest.model;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        let a_ids = self.ids_buffer(ids_a)?;
        let a_mask = self.mask_buffer(mask_a)?;
        let b_ids = self.ids_buffer(ids_b)?;
        let b_mask = self.mask_buffer(mask_b)?;
        args.extend([&a_ids, &a_mask, &b_ids, &b_mask]);
        let out = self.bertscore.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let (p, r, f1) = lit.to_tuple3()?;
        let p = p.to_vec::<f32>()?;
        let r = r.to_vec::<f32>()?;
        let f1 = f1.to_vec::<f32>()?;
        let (e, b, s) = self.exec_counts.get();
        self.exec_counts.set((e, b + 1, s));
        Ok((0..m.batch)
            .map(|i| BertScore { precision: p[i], recall: r[i], f1: f1[i] })
            .collect())
    }

    /// BERTScore for arbitrarily many (candidate, reference) text pairs.
    pub fn bertscore_texts(&self, pairs: &[(&str, &str)]) -> Result<Vec<BertScore>> {
        let m = &self.manifest.model;
        let (bsz, seq) = (m.batch, m.max_seq);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(bsz) {
            let mut ids_a = vec![0i32; bsz * seq];
            let mut mask_a = vec![0f32; bsz * seq];
            let mut ids_b = vec![0i32; bsz * seq];
            let mut mask_b = vec![0f32; bsz * seq];
            for (i, (cand, reference)) in chunk.iter().enumerate() {
                let (ia, ma) = self.tokenizer.encode(cand);
                let (ib, mb) = self.tokenizer.encode(reference);
                ids_a[i * seq..(i + 1) * seq].copy_from_slice(&ia);
                mask_a[i * seq..(i + 1) * seq].copy_from_slice(&ma);
                ids_b[i * seq..(i + 1) * seq].copy_from_slice(&ib);
                mask_b[i * seq..(i + 1) * seq].copy_from_slice(&mb);
            }
            let scores = self.bertscore_batch(&ids_a, &mask_a, &ids_b, &mask_b)?;
            out.extend_from_slice(&scores[..chunk.len()]);
        }
        Ok(out)
    }

    /// Bootstrap resample means on the device: draws `resamples` index rows
    /// with the supplied RNG and returns the resample means.
    ///
    /// Falls back to `None` when `values.len() > max_n`; the caller then
    /// uses the native Rust bootstrap (`stats::bootstrap`).
    pub fn bootstrap_means(&self, values: &[f64], rng: &mut Rng) -> Result<Option<Vec<f64>>> {
        let b = &self.manifest.bootstrap;
        let n = values.len();
        if n == 0 || n > b.max_n {
            return Ok(None);
        }
        let (r, max_n) = (b.resamples, b.max_n);

        let mut vals = vec![0f32; max_n];
        for (i, &v) in values.iter().enumerate() {
            vals[i] = v as f32;
        }
        let mut idx = vec![0i32; r * max_n];
        let mut mask = vec![0f32; r * max_n];
        for row in 0..r {
            let base = row * max_n;
            for j in 0..n {
                idx[base + j] = rng.below(n) as i32;
                mask[base + j] = 1.0;
            }
        }

        let vals_b = self.client.buffer_from_host_buffer(&vals, &[max_n], None)?;
        let idx_b = self.client.buffer_from_host_buffer(&idx, &[r, max_n], None)?;
        let mask_b = self.client.buffer_from_host_buffer(&mask, &[r, max_n], None)?;
        let out = self.bootstrap.execute_b(&[&vals_b, &idx_b, &mask_b])?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        let means = lit.to_vec::<f32>()?;
        let (e, bb, s) = self.exec_counts.get();
        self.exec_counts.set((e, bb, s + 1));
        Ok(Some(means.into_iter().map(|m| m as f64).collect()))
    }

    /// Cosine similarity between two embedding vectors (both unit-norm).
    pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }
}
