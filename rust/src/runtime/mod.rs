//! Runtime layer: PJRT CPU client wrapping the `xla` crate.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `weights.bin` + `manifest.json`), compiles them
//! once, and executes them from the coordinator's metric/statistics stages.
//! This is the only module that touches PJRT; everything above it deals in
//! plain Rust types.

pub mod client;
pub mod manifest;
pub mod tokenize;

pub use client::{BertScore, SemanticRuntime};
pub use manifest::Manifest;
pub use tokenize::SimTokenizer;

use std::path::PathBuf;

/// Default artifact directory: `$SLLEVAL_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SLLEVAL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
