//! # spark-llm-eval
//!
//! A distributed framework for statistically rigorous large-language-model
//! evaluation — a full-system reproduction of *"Spark-LLM-Eval: A
//! Distributed Framework for Statistically Rigorous Large Language Model
//! Evaluation"* (Mitra, CS.DC 2026) as a three-layer Rust + JAX + Pallas
//! stack with Python strictly at build time.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)** — the coordinator: data-parallel execution engine,
//!   per-executor token-bucket rate limiting, multi-provider inference
//!   abstraction, Delta-Lake-style response cache with replay, four metric
//!   families, and the integrated statistics stack (bootstrap CIs,
//!   significance tests, effect sizes).
//! - **L2 (JAX, build time)** — SimLM encoder + BERTScore + bootstrap
//!   compute graphs, AOT-lowered to HLO text.
//! - **L1 (Pallas, build time)** — fused token-similarity max-matching
//!   kernel for BERTScore.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and is the only
//! bridge between layers at run time.

pub mod analysis;
pub mod cache;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod metrics;
pub mod providers;
pub mod ratelimit;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod storage;
pub mod template;
pub mod tracking;
pub mod util;
