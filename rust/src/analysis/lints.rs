//! The lint rules: project invariants checked over the lexed token
//! streams. Each rule is a pure function from source files to
//! diagnostics; suppression (inline `lint:allow`, baseline) is applied by
//! the driver in [`super`], never here.

use super::lexer::{Tok, TokKind};
use super::{Diagnostic, SourceFile};
use std::collections::BTreeMap;

/// Rule names, also the only values `lint:allow(...)` accepts.
pub const RULES: [&str; 4] = ["determinism", "panic-safety", "wire-protocol", "config-doc"];

fn diag(rule: &str, file: &str, line: u32, subject: &str, message: &str) -> Diagnostic {
    Diagnostic {
        rule: rule.to_string(),
        file: file.to_string(),
        line,
        subject: subject.to_string(),
        message: message.to_string(),
    }
}

fn p_at(toks: &[&Tok], i: usize, s: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == s)
}

fn id_at(toks: &[&Tok], i: usize, s: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == s)
}

fn str_at(toks: &[&Tok], i: usize, s: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Str && t.text == s)
}

// ---------------------------------------------------------------------------
// Rule: determinism
// ---------------------------------------------------------------------------

/// Files that *are* the wall-clock abstraction (or deliberately measure
/// wall time) and are exempt from the `Instant::now`/`SystemTime::now`
/// check.
const CLOCK_EXEMPT: [&str; 2] = ["rust/src/ratelimit/mod.rs", "rust/src/util/bench.rs"];

/// Modules where hash-iteration order can reach fingerprints, task
/// ordering, or serialized output; `HashMap`/`HashSet` are banned here in
/// favour of `BTreeMap`/`BTreeSet` (or an explicit sort).
const HASH_SCOPED_PREFIXES: [&str; 9] = [
    "rust/src/sched/",
    "rust/src/coordinator/",
    "rust/src/checkpoint/",
    "rust/src/cache/",
    "rust/src/config/",
    "rust/src/report/",
    "rust/src/tracking/",
    "rust/src/analysis/",
    "rust/src/storage/",
];

pub fn determinism(file: &SourceFile) -> Vec<Diagnostic> {
    let rel = file.rel.as_str();
    if !rel.starts_with("rust/src/") {
        return Vec::new();
    }
    let clock_exempt = CLOCK_EXEMPT.contains(&rel);
    let hash_scoped = HASH_SCOPED_PREFIXES.iter().any(|p| rel.starts_with(p))
        || rel == "rust/src/util/json.rs";
    let rng_exempt = rel == "rust/src/util/rng.rs";
    let toks = file.lexed.code_tokens();
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident {
            continue;
        }
        let name = t.text.as_str();
        if !clock_exempt
            && (name == "Instant" || name == "SystemTime")
            && p_at(&toks, i + 1, "::")
            && id_at(&toks, i + 2, "now")
        {
            out.push(diag(
                "determinism",
                rel,
                t.line,
                &format!("{name}::now"),
                "wall-clock read outside the Clock abstraction; thread a `ratelimit::Clock`, or lint:allow where wall time is intended (telemetry, I/O deadlines)",
            ));
        }
        if hash_scoped && (name == "HashMap" || name == "HashSet") {
            out.push(diag(
                "determinism",
                rel,
                t.line,
                name,
                "hash iteration order is nondeterministic in a determinism-critical module; use BTreeMap/BTreeSet or sort before anything ordered reaches fingerprints, task order, or serialized output",
            ));
        }
        if !rng_exempt && matches!(name, "thread_rng" | "from_entropy" | "OsRng") {
            out.push(diag(
                "determinism",
                rel,
                t.line,
                name,
                "unseeded randomness outside util/rng; derive every Rng from the task seed so runs replay bit-identically",
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: panic-safety
// ---------------------------------------------------------------------------

/// Executor-side task code — a panic here aborts a pool (or a worker
/// process mid-task) instead of surfacing as a retryable task failure —
/// plus the eval-service daemon (`serve/`), where a panic on a
/// malformed request or inside a run must become a 400/500 response or
/// a failed-run state, never a daemon abort, plus the whole storage
/// subsystem (`storage/`): a panic mid-commit can strand claimed log
/// versions and half-published tables, so every failure must unwind as
/// an `Err` the caller can retry or surface.
const PANIC_SCOPED: [&str; 14] = [
    "rust/src/coordinator/plan_exec.rs",
    "rust/src/coordinator/worker.rs",
    "rust/src/providers/pipeline.rs",
    "rust/src/sched/backend.rs",
    "rust/src/serve/api.rs",
    "rust/src/serve/http.rs",
    "rust/src/serve/mod.rs",
    "rust/src/serve/registry.rs",
    "rust/src/serve/runloop.rs",
    "rust/src/storage/actions.rs",
    "rust/src/storage/delta.rs",
    "rust/src/storage/maintain.rs",
    "rust/src/storage/migrate.rs",
    "rust/src/storage/mod.rs",
];

pub fn panic_safety(file: &SourceFile) -> Vec<Diagnostic> {
    let rel = file.rel.as_str();
    if !PANIC_SCOPED.contains(&rel) {
        return Vec::new();
    }
    let toks = file.lexed.code_tokens();
    let mut out = Vec::new();
    const MSG: &str = "executor-side task code must surface failures as retryable task errors, not abort the pool; return an Err (recover poisoned locks with `.unwrap_or_else(|p| p.into_inner())`)";
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Punct
            && t.text == "."
            && (id_at(&toks, i + 1, "unwrap") || id_at(&toks, i + 1, "expect"))
            && p_at(&toks, i + 2, "(")
        {
            let callee = &toks[i + 1];
            out.push(diag("panic-safety", rel, callee.line, &format!(".{}()", callee.text), MSG));
        }
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
            && p_at(&toks, i + 1, "!")
        {
            out.push(diag("panic-safety", rel, t.line, &format!("{}!", t.text), MSG));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: wire-protocol
// ---------------------------------------------------------------------------

/// Every file that emits or dispatches executor protocol frames.
const WIRE_FILES: [&str; 5] = [
    "rust/src/sched/wire.rs",
    "rust/src/sched/backend.rs",
    "rust/src/sched/remote.rs",
    "rust/src/coordinator/worker.rs",
    "rust/src/coordinator/plan_exec.rs",
];

/// The file whose module doc comment is the protocol's documentation of
/// record.
const WIRE_DOC_FILE: &str = "rust/src/sched/backend.rs";

/// Pull every `"type":"<name>"` frame-type mention out of a flat string
/// (a format-spliced frame literal, or one protocol doc line).
fn splice_frame_types(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let needle = "\"type\":\"";
    let mut rest = s;
    while let Some(pos) = rest.find(needle) {
        let tail = &rest[pos + needle.len()..];
        let name: String = tail
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            out.push(name);
        }
        rest = tail;
    }
    out
}

/// Frame types this file emits (as `("type", Json::str("…"))` pairs or
/// format-spliced string literals) and the ones it handles (as match arms
/// or equality tests on `.str_or("type", …)`).
fn wire_sets(file: &SourceFile) -> (BTreeMap<String, u32>, BTreeMap<String, u32>) {
    let toks = file.lexed.code_tokens();
    let mut emitted: BTreeMap<String, u32> = BTreeMap::new();
    let mut handled: BTreeMap<String, u32> = BTreeMap::new();
    for (i, t) in toks.iter().enumerate() {
        // Emission, structured: ("type", Json::str("task"))
        if t.kind == TokKind::Str
            && t.text == "type"
            && p_at(&toks, i + 1, ",")
            && id_at(&toks, i + 2, "Json")
            && p_at(&toks, i + 3, "::")
            && id_at(&toks, i + 4, "str")
            && p_at(&toks, i + 5, "(")
        {
            // A non-literal argument (e.g. a metric type field) is not a
            // frame type; only a string literal counts.
            if let Some(f) = toks.get(i + 6).filter(|f| f.kind == TokKind::Str) {
                emitted.entry(f.text.clone()).or_insert(f.line);
            }
        }
        // Emission, spliced: any string literal containing "type":"…"
        if matches!(t.kind, TokKind::Str | TokKind::RawStr) {
            for name in splice_frame_types(&t.text) {
                emitted.entry(name).or_insert(t.line);
            }
        }
        // Dispatch: .str_or("type", …)
        if t.kind == TokKind::Punct
            && t.text == "."
            && id_at(&toks, i + 1, "str_or")
            && p_at(&toks, i + 2, "(")
            && str_at(&toks, i + 3, "type")
        {
            let back = i.saturating_sub(12);
            // match <expr>.str_or("type", …) { "a" | "b" => …, … }
            if toks[back..i].iter().any(|t| t.kind == TokKind::Ident && t.text == "match") {
                let mut j = i + 4;
                while j < toks.len() && !p_at(&toks, j, "{") {
                    j += 1;
                }
                let mut depth = 0usize;
                while j < toks.len() {
                    if p_at(&toks, j, "{") {
                        depth += 1;
                    } else if p_at(&toks, j, "}") {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    } else if depth == 1
                        && toks[j].kind == TokKind::Str
                        && (p_at(&toks, j + 1, "=>") || p_at(&toks, j + 1, "|"))
                    {
                        handled.entry(toks[j].text.clone()).or_insert(toks[j].line);
                    }
                    j += 1;
                }
            }
            // let ty = <expr>.str_or("type", …); … ty == "hello" …
            let mut k = back;
            while k + 2 < i {
                if id_at(&toks, k, "let")
                    && toks[k + 1].kind == TokKind::Ident
                    && p_at(&toks, k + 2, "=")
                {
                    let bind = toks[k + 1].text.clone();
                    for (m, tm) in toks.iter().enumerate() {
                        if tm.kind == TokKind::Ident && tm.text == bind && p_at(&toks, m + 1, "==")
                        {
                            if let Some(s) = toks.get(m + 2).filter(|s| s.kind == TokKind::Str) {
                                handled.entry(s.text.clone()).or_insert(s.line);
                            }
                        }
                        if tm.kind == TokKind::Str
                            && p_at(&toks, m + 1, "==")
                            && id_at(&toks, m + 2, &bind)
                        {
                            handled.entry(tm.text.clone()).or_insert(tm.line);
                        }
                    }
                    break;
                }
                k += 1;
            }
        }
    }
    (emitted, handled)
}

pub fn wire_protocol(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut emitted: BTreeMap<String, (String, u32)> = BTreeMap::new();
    let mut handled: BTreeMap<String, (String, u32)> = BTreeMap::new();
    let mut doc: BTreeMap<String, u32> = BTreeMap::new();
    let mut doc_file_seen = false;
    for f in files {
        if !WIRE_FILES.contains(&f.rel.as_str()) {
            continue;
        }
        let (e, h) = wire_sets(f);
        for (name, line) in e {
            emitted.entry(name).or_insert((f.rel.clone(), line));
        }
        for (name, line) in h {
            handled.entry(name).or_insert((f.rel.clone(), line));
        }
        if f.rel == WIRE_DOC_FILE {
            doc_file_seen = true;
            // The protocol documentation of record: `//!` doc lines that
            // mention `{"type":"…"}` frames.
            for c in &f.lexed.comments {
                if !c.text.starts_with('!') {
                    continue;
                }
                for (off, line_text) in c.text.split('\n').enumerate() {
                    for name in splice_frame_types(line_text) {
                        doc.entry(name).or_insert(c.line + off as u32);
                    }
                }
            }
        }
    }
    // Without the doc file in the set (e.g. a fixture run) there is no
    // documentation of record; emitted-vs-handled is still validated.
    let mut out = Vec::new();
    for (name, (file, line)) in &emitted {
        if !handled.contains_key(name) {
            out.push(diag(
                "wire-protocol",
                file,
                *line,
                name,
                "frame type is emitted but no peer dispatches on it; add a handler arm or remove the emission",
            ));
        }
        if doc_file_seen && !doc.contains_key(name) {
            out.push(diag(
                "wire-protocol",
                file,
                *line,
                name,
                "frame type is missing from the protocol doc comment in rust/src/sched/backend.rs",
            ));
        }
    }
    for (name, (file, line)) in &handled {
        if !emitted.contains_key(name) {
            out.push(diag(
                "wire-protocol",
                file,
                *line,
                name,
                "frame type is handled but nothing emits it; dead protocol arm or a missing emitter",
            ));
            if doc_file_seen && !doc.contains_key(name) {
                out.push(diag(
                    "wire-protocol",
                    file,
                    *line,
                    name,
                    "frame type is missing from the protocol doc comment in rust/src/sched/backend.rs",
                ));
            }
        }
    }
    for (name, line) in &doc {
        if !emitted.contains_key(name) && !handled.contains_key(name) {
            out.push(diag(
                "wire-protocol",
                WIRE_DOC_FILE,
                *line,
                name,
                "documented frame type never appears in code; prune the doc comment or restore the frame",
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: config-doc
// ---------------------------------------------------------------------------

const CONFIG_FILE: &str = "rust/src/config/mod.rs";

/// JSON accessor methods whose string argument names an EvalTask field.
const ACCESSORS: [&str; 6] = ["str_or", "f64_or", "usize_or", "bool_or", "get", "opt"];

/// Does `word` appear in `docs` delimited by non-identifier characters?
fn word_in(docs: &str, word: &str) -> bool {
    let bytes = docs.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = docs[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let end = at + word.len();
        let after_ok = end >= bytes.len() || {
            let b = bytes[end];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

pub fn config_doc(files: &[SourceFile], docs: &str) -> Vec<Diagnostic> {
    let Some(cfg) = files.iter().find(|f| f.rel == CONFIG_FILE) else {
        return Vec::new();
    };
    let toks = cfg.lexed.code_tokens();
    let mut seen: BTreeMap<String, u32> = BTreeMap::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Punct
            && t.text == "."
            && toks
                .get(i + 1)
                .is_some_and(|a| a.kind == TokKind::Ident && ACCESSORS.contains(&a.text.as_str()))
            && p_at(&toks, i + 2, "(")
        {
            if let Some(field) =
                toks.get(i + 3).filter(|f| f.kind == TokKind::Str && !f.text.is_empty())
            {
                seen.entry(field.text.clone()).or_insert(field.line);
            }
        }
    }
    let mut out = Vec::new();
    for (field, line) in &seen {
        if !word_in(docs, field) {
            out.push(diag(
                "config-doc",
                CONFIG_FILE,
                *line,
                field,
                "EvalTask JSON field is parsed here but never mentioned in DESIGN.md or README.md; document it (the field reference table in DESIGN.md is the usual home)",
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splice_extraction_finds_every_frame_in_a_line() {
        let got = splice_frame_types(r#"{"type":"ready"} | {"type":"init_error","error":"..."}"#);
        assert_eq!(got, vec!["ready".to_string(), "init_error".to_string()]);
        assert!(splice_frame_types("no frames here").is_empty());
    }

    #[test]
    fn wave_loop_modules_are_determinism_scoped() {
        // The adaptive-stopping wave loop spans these modules; a HashMap
        // (or an unmarked Instant::now) in any of them can change wave
        // decisions between replays, so all must sit inside the
        // determinism scope.
        for rel in [
            "rust/src/coordinator/stopping.rs",
            "rust/src/coordinator/runner.rs",
            "rust/src/sched/mod.rs",
            "rust/src/sched/backend.rs",
        ] {
            let file = SourceFile {
                rel: rel.to_string(),
                lexed: super::super::lexer::lex("fn f() { let m = HashMap::new(); }"),
            };
            assert!(
                determinism(&file).iter().any(|d| d.subject == "HashMap"),
                "{rel} must be determinism-scoped"
            );
        }
    }

    #[test]
    fn skipping_path_modules_are_determinism_and_panic_scoped() {
        // The data-skipping read path (stats computation → log replay →
        // candidate pruning → lazy file loads) must stay deterministic:
        // a HashMap in any of these modules could reorder candidate
        // files or stats keys between runs, breaking the bit-identity
        // contract between skipping on and off. The same modules are
        // panic-scoped: a panic mid-commit strands claimed log versions.
        for rel in [
            "rust/src/storage/actions.rs",
            "rust/src/storage/delta.rs",
            "rust/src/storage/maintain.rs",
            "rust/src/storage/migrate.rs",
            "rust/src/cache/mod.rs",
        ] {
            let file = SourceFile {
                rel: rel.to_string(),
                lexed: super::super::lexer::lex("fn f() { let m = HashMap::new(); }"),
            };
            assert!(
                determinism(&file).iter().any(|d| d.subject == "HashMap"),
                "{rel} must be determinism-scoped"
            );
        }
        for rel in PANIC_SCOPED.iter().filter(|r| r.starts_with("rust/src/storage/")) {
            let file = SourceFile {
                rel: rel.to_string(),
                lexed: super::super::lexer::lex("fn f() { x.unwrap(); }"),
            };
            assert!(
                panic_safety(&file).iter().any(|d| d.subject == ".unwrap()"),
                "{rel} must be panic-scoped"
            );
        }
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(word_in("the `seed` field", "seed"));
        assert!(word_in("alpha|beta", "alpha"));
        assert!(!word_in("reseeded", "seed"));
        assert!(!word_in("seed_value only", "seed"));
    }
}
