//! A minimal Rust lexer for the `slleval lint` pass.
//!
//! Hand-rolled like everything else in this crate — no syn, no
//! proc-macro2. It understands exactly as much Rust as the lints need so
//! that rule patterns match *code* and never text inside strings or
//! comments: line comments, nested block comments, string literals with
//! escapes, raw/byte strings with arbitrary `#` fences, raw identifiers,
//! and the char-literal-vs-lifetime ambiguity. It does not parse;
//! downstream rules pattern-match on the token stream.
//!
//! The lexer also locates `#[cfg(test)]` item spans by brace matching, so
//! rules can exempt test code without any notion of scopes.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (raw identifiers lose their `r#` prefix).
    Ident,
    /// `'a`-style lifetime or loop label (without the quote).
    Lifetime,
    /// String or byte-string literal; `text` holds the *decoded* contents.
    Str,
    /// Raw (byte) string literal; `text` holds the verbatim contents.
    RawStr,
    /// Char or byte literal; `text` holds the raw contents between quotes.
    Char,
    /// Numeric literal.
    Num,
    /// Punctuation, longest-match (`::`, `=>`, `==`, ... else one char).
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line where the comment starts.
    pub line: u32,
    /// Contents after `//` (line) or between `/*` and `*/` (block); a
    /// `//!` module doc keeps its leading `!`, a `///` doc its third `/`.
    pub text: String,
}

#[derive(Debug, Default)]
pub struct LexedFile {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// Inclusive line spans of `#[cfg(test)]` items.
    test_spans: Vec<(u32, u32)>,
}

impl LexedFile {
    /// Whether `line` falls inside a `#[cfg(test)]` item.
    pub fn in_test_code(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// The token stream with every `#[cfg(test)]` region removed.
    pub fn code_tokens(&self) -> Vec<&Tok> {
        self.tokens.iter().filter(|t| !self.in_test_code(t.line)).collect()
    }
}

pub fn lex(src: &str) -> LexedFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut tokens: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments (before punctuation so `//` never lexes as two slashes).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment { line, text: chars[start..j].iter().collect() });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    text.push('\n');
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    text.push_str("/*");
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    if depth > 0 {
                        text.push_str("*/");
                    }
                    j += 2;
                } else {
                    text.push(chars[j]);
                    j += 1;
                }
            }
            comments.push(Comment { line: start_line, text });
            i = j;
            continue;
        }
        if c == '"' {
            let start_line = line;
            let (decoded, end, nl) = scan_string(&chars, i + 1);
            tokens.push(Tok { kind: TokKind::Str, text: decoded, line: start_line });
            line += nl;
            i = end;
            continue;
        }
        if c == '\'' {
            // Lifetime/label: a quote followed by an identifier char that
            // is not immediately closed (`'a` yes, `'a'` no).
            if i + 1 < n
                && (chars[i + 1] == '_' || chars[i + 1].is_ascii_alphabetic())
                && !(i + 2 < n && chars[i + 2] == '\'')
            {
                let start = i + 1;
                let mut j = start;
                while j < n && (chars[j] == '_' || chars[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                tokens.push(Tok {
                    kind: TokKind::Lifetime,
                    text: chars[start..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            let (text, end) = scan_char(&chars, i + 1);
            tokens.push(Tok { kind: TokKind::Char, text, line });
            i = end;
            continue;
        }
        if c == '_' || c.is_ascii_alphabetic() {
            let start = i;
            let mut j = i;
            while j < n && (chars[j] == '_' || chars[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            let word: String = chars[start..j].iter().collect();
            // Raw / byte string prefixes: r"…", r#"…"#, br"…", b"…".
            if (word == "r" || word == "br") && j < n && (chars[j] == '"' || chars[j] == '#') {
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let start_line = line;
                    let (text, end, nl) = scan_raw_string(&chars, k + 1, hashes);
                    tokens.push(Tok { kind: TokKind::RawStr, text, line: start_line });
                    line += nl;
                    i = end;
                    continue;
                }
                if word == "r"
                    && hashes == 1
                    && k < n
                    && (chars[k] == '_' || chars[k].is_ascii_alphabetic())
                {
                    // Raw identifier: r#type — lex as the bare identifier.
                    let mut m = k;
                    while m < n && (chars[m] == '_' || chars[m].is_ascii_alphanumeric()) {
                        m += 1;
                    }
                    tokens.push(Tok {
                        kind: TokKind::Ident,
                        text: chars[k..m].iter().collect(),
                        line,
                    });
                    i = m;
                    continue;
                }
                // Plain `r`/`br` identifier followed by `#`: fall through.
            }
            if word == "b" && j < n && chars[j] == '"' {
                let start_line = line;
                let (decoded, end, nl) = scan_string(&chars, j + 1);
                tokens.push(Tok { kind: TokKind::Str, text: decoded, line: start_line });
                line += nl;
                i = end;
                continue;
            }
            if word == "b" && j < n && chars[j] == '\'' {
                let (text, end) = scan_char(&chars, j + 1);
                tokens.push(Tok { kind: TokKind::Char, text, line });
                i = end;
                continue;
            }
            tokens.push(Tok { kind: TokKind::Ident, text: word, line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n {
                let d = chars[j];
                if d == '_' || d.is_ascii_alphanumeric() {
                    j += 1;
                } else if d == '.'
                    && j + 1 < n
                    && chars[j + 1].is_ascii_digit()
                    && !(j > start && chars[j - 1] == '.')
                {
                    j += 1; // fractional part: 1.25 but not 1..5
                } else if (d == '+' || d == '-')
                    && j > start
                    && (chars[j - 1] == 'e' || chars[j - 1] == 'E')
                    && j + 1 < n
                    && chars[j + 1].is_ascii_digit()
                {
                    j += 1; // signed exponent: 1e-9
                } else {
                    break;
                }
            }
            tokens.push(Tok { kind: TokKind::Num, text: chars[start..j].iter().collect(), line });
            i = j;
            continue;
        }
        // Punctuation, longest match first; the multi-char set is only
        // what rule patterns rely on plus the operators that would
        // otherwise mis-split (`/=` must not look like a comment start).
        let two: String = chars[i..(i + 2).min(n)].iter().collect();
        const PUNCT2: [&str; 21] = [
            "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..", "+=", "-=", "*=", "/=",
            "%=", "^=", "&=", "|=", "<<", ">>", "##",
        ];
        if two.chars().count() == 2 && PUNCT2.contains(&two.as_str()) {
            // ..= and shift-assigns extend to three chars.
            let three: String = chars[i..(i + 3).min(n)].iter().collect();
            if three == "..=" || three == "<<=" || three == ">>=" {
                tokens.push(Tok { kind: TokKind::Punct, text: three, line });
                i += 3;
                continue;
            }
            tokens.push(Tok { kind: TokKind::Punct, text: two, line });
            i += 2;
            continue;
        }
        tokens.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }

    let test_spans = test_spans(&tokens);
    LexedFile { tokens, comments, test_spans }
}

/// Scan a (byte) string body starting after the opening quote. Returns
/// the decoded contents, the index after the closing quote, and the
/// number of newlines consumed.
fn scan_string(chars: &[char], mut i: usize) -> (String, usize, u32) {
    let n = chars.len();
    let mut out = String::new();
    let mut nl = 0u32;
    while i < n {
        let c = chars[i];
        if c == '\\' && i + 1 < n {
            let e = chars[i + 1];
            match e {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'r' => out.push('\r'),
                '0' => out.push('\0'),
                '\\' => out.push('\\'),
                '"' => out.push('"'),
                '\'' => out.push('\''),
                'u' => {
                    if i + 2 < n && chars[i + 2] == '{' {
                        let mut j = i + 3;
                        let mut hex = String::new();
                        while j < n && chars[j] != '}' {
                            hex.push(chars[j]);
                            j += 1;
                        }
                        if let Some(ch) =
                            u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32)
                        {
                            out.push(ch);
                        }
                        i = j + 1;
                        continue;
                    }
                }
                '\n' => nl += 1, // line continuation: swallow the newline
                _ => {
                    out.push('\\');
                    out.push(e);
                }
            }
            i += 2;
            continue;
        }
        if c == '"' {
            return (out, i + 1, nl);
        }
        if c == '\n' {
            nl += 1;
        }
        out.push(c);
        i += 1;
    }
    (out, n, nl) // unterminated: tolerate at EOF
}

/// Scan a raw string body (after the opening quote) fenced by `hashes`
/// `#` characters. Contents are verbatim — no escapes.
fn scan_raw_string(chars: &[char], mut i: usize, hashes: usize) -> (String, usize, u32) {
    let n = chars.len();
    let mut out = String::new();
    let mut nl = 0u32;
    while i < n {
        if chars[i] == '"' {
            let mut k = 0usize;
            while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return (out, i + 1 + hashes, nl);
            }
        }
        if chars[i] == '\n' {
            nl += 1;
        }
        out.push(chars[i]);
        i += 1;
    }
    (out, n, nl)
}

/// Scan a char/byte literal body starting after the opening quote.
fn scan_char(chars: &[char], mut i: usize) -> (String, usize) {
    let n = chars.len();
    let mut out = String::new();
    while i < n {
        let c = chars[i];
        if c == '\\' && i + 1 < n {
            out.push(c);
            out.push(chars[i + 1]);
            i += 2;
            continue;
        }
        if c == '\'' {
            return (out, i + 1);
        }
        out.push(c);
        i += 1;
    }
    (out, n)
}

fn is_p(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn is_id(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// Find the inclusive line span of every `#[cfg(test)]` item: the
/// attribute, any further attributes, then either a `;`-terminated item
/// or a brace-matched body.
fn test_spans(tokens: &[Tok]) -> Vec<(u32, u32)> {
    let n = tokens.len();
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < n {
        let attr = i + 6 < n
            && is_p(&tokens[i], "#")
            && is_p(&tokens[i + 1], "[")
            && is_id(&tokens[i + 2], "cfg")
            && is_p(&tokens[i + 3], "(")
            && is_id(&tokens[i + 4], "test")
            && is_p(&tokens[i + 5], ")")
            && is_p(&tokens[i + 6], "]");
        if !attr {
            i += 1;
            continue;
        }
        let attr_line = tokens[i].line;
        let mut j = i + 7;
        // Skip any further attributes on the same item.
        while j + 1 < n && is_p(&tokens[j], "#") && is_p(&tokens[j + 1], "[") {
            let mut depth = 0usize;
            let mut k = j + 1;
            while k < n {
                if is_p(&tokens[k], "[") {
                    depth += 1;
                } else if is_p(&tokens[k], "]") {
                    depth -= 1;
                    if depth == 0 {
                        k += 1;
                        break;
                    }
                }
                k += 1;
            }
            j = k;
        }
        // The item: everything up to a top-level `;` or a matched body.
        let mut end_line = attr_line;
        while j < n {
            if is_p(&tokens[j], ";") {
                end_line = tokens[j].line;
                j += 1;
                break;
            }
            if is_p(&tokens[j], "{") {
                let mut depth = 0usize;
                while j < n {
                    if is_p(&tokens[j], "{") {
                        depth += 1;
                    } else if is_p(&tokens[j], "}") {
                        depth -= 1;
                        if depth == 0 {
                            end_line = tokens[j].line;
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
                break;
            }
            j += 1;
        }
        spans.push((attr_line, end_line.max(attr_line)));
        i = j.max(i + 1);
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let src = r##"
// Instant::now in a line comment
/* Instant::now in /* a nested */ block comment */
let s = "Instant::now() in a string";
let r = r#"Instant::now() in a raw string"#;
let x = real_ident;
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(ids.contains(&"real_ident".to_string()));
        let f = lex(src);
        assert_eq!(f.comments.len(), 2);
        assert!(f.comments[1].text.contains("/* a nested */"));
    }

    #[test]
    fn decoded_strings_and_raw_fences() {
        let f = lex("let s = \"a\\\"b\\n\"; let r = r##\"x\"#y\"##;");
        let strs: Vec<&Tok> =
            f.tokens.iter().filter(|t| matches!(t.kind, TokKind::Str | TokKind::RawStr)).collect();
        assert_eq!(strs[0].text, "a\"b\n");
        assert_eq!(strs[1].text, "x\"#y");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<&Tok> = f.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<&Tok> = f.tokens.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn cfg_test_spans_cover_the_mod_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn live2() {}\n";
        let f = lex(src);
        assert!(!f.in_test_code(1));
        assert!(f.in_test_code(2));
        assert!(f.in_test_code(4));
        assert!(!f.in_test_code(6));
    }

    #[test]
    fn multiline_strings_keep_line_numbers_aligned() {
        let src = "let a = \"one\ntwo\nthree\";\nlet after = 1;\n";
        let f = lex(src);
        let after = f.tokens.iter().find(|t| is_id(t, "after")).expect("after");
        assert_eq!(after.line, 4);
    }
}
