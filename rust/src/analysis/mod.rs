//! `slleval lint` — a dependency-free static analysis pass over this
//! repository's own sources, enforcing the project invariants that make
//! the statistical claims trustworthy: determinism of scheduled paths,
//! panic-safety of executor-side code, agreement of the executor wire
//! protocol (doc ⇔ emitters ⇔ handlers), and EvalTask-config/doc sync.
//!
//! The pass runs in three places with identical results: the
//! `slleval lint` subcommand, the `cargo test -q` tier-1 gate
//! (`rust/tests/lint_gate.rs`), and CI. Rules live in [`lints`], the
//! hand-rolled token stream they match over in [`lexer`].
//!
//! Suppression is deliberate and always justified:
//! - inline: `// lint:allow(<rule>): <reason>` on the offending line or
//!   the line above — a missing reason is itself a violation. The allow
//!   must be the comment's own content (a dedicated comment); prose that
//!   merely *mentions* `lint:allow(...)` mid-sentence is ignored;
//! - baseline: a checked-in JSON array of `{rule, file, subject, reason}`
//!   entries (default `rust/lint-baseline.json`) for triaged legacy debt.
//!   Entries that no longer match any violation are *stale* and fail the
//!   lint, so the tree only ever ratchets cleaner.

pub mod lexer;
pub mod lints;

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

pub use lints::RULES;

/// One lint finding, before suppression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: String,
    /// Repo-relative path with forward slashes (`rust/src/...`).
    pub file: String,
    pub line: u32,
    /// The offending identifier / frame type / config field — also the
    /// key baseline entries match on.
    pub subject: String,
    pub message: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}: {}", self.file, self.line, self.rule, self.subject, self.message)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(&self.rule)),
            ("file", Json::str(&self.file)),
            ("line", Json::num(self.line as f64)),
            ("subject", Json::str(&self.subject)),
            ("message", Json::str(&self.message)),
        ])
    }
}

/// One lexed input file plus its repo-relative path; rules scope
/// themselves by `rel`.
pub struct SourceFile {
    pub rel: String,
    pub lexed: lexer::LexedFile,
}

/// A checked-in suppression with a written justification.
#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub subject: String,
    pub reason: String,
}

/// The result of one lint pass.
pub struct LintOutcome {
    /// Unsuppressed findings — non-empty means the gate fails.
    pub violations: Vec<Diagnostic>,
    /// Findings silenced by an inline allow or a baseline entry, paired
    /// with the written justification.
    pub suppressed: Vec<(Diagnostic, String)>,
    pub files_scanned: usize,
}

impl LintOutcome {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clean", Json::Bool(self.clean())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("violations", Json::arr(self.violations.iter().map(|d| d.to_json()).collect())),
            (
                "suppressed",
                Json::arr(
                    self.suppressed
                        .iter()
                        .map(|(d, reason)| {
                            let mut j = d.to_json();
                            if let Json::Obj(m) = &mut j {
                                m.insert("reason".to_string(), Json::str(reason));
                            }
                            j
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// An inline `// lint:allow(rule): reason` comment.
struct InlineAllow {
    file: String,
    line: u32,
    rule: String,
    reason: String,
    used: bool,
}

/// Parse every inline allow out of a file's comments. Malformed allows
/// (unknown rule, missing reason) are reported as violations directly.
fn collect_allows(file: &SourceFile, diags: &mut Vec<Diagnostic>) -> Vec<InlineAllow> {
    const MARK: &str = "lint:allow(";
    let mut out = Vec::new();
    for c in &file.lexed.comments {
        for (off, line_text) in c.text.split('\n').enumerate() {
            let line = c.line + off as u32;
            // Only dedicated allow comments count: after stripping the
            // doc-marker/whitespace prefix, the line must *be* the
            // suppression. Prose that merely mentions `lint:allow(...)`
            // (like this module's own docs) stays prose.
            let lt = line_text.trim_start_matches(|c: char| {
                c == '/' || c == '!' || c == '*' || c.is_whitespace()
            });
            if !lt.starts_with(MARK) {
                continue;
            }
            let tail = &lt[MARK.len()..];
            let Some(close) = tail.find(')') else {
                diags.push(Diagnostic {
                    rule: "lint-allow".to_string(),
                    file: file.rel.clone(),
                    line,
                    subject: "lint:allow".to_string(),
                    message: "malformed suppression; expected `lint:allow(<rule>): <reason>`"
                        .to_string(),
                });
                continue;
            };
            let rule = tail[..close].trim().to_string();
            let rest = tail[close + 1..].trim_start();
            let reason = rest.strip_prefix(':').map(|r| r.trim().to_string()).unwrap_or_default();
            if !RULES.contains(&rule.as_str()) {
                diags.push(Diagnostic {
                    rule: "lint-allow".to_string(),
                    file: file.rel.clone(),
                    line,
                    subject: rule.clone(),
                    message: format!("unknown lint rule in suppression (known: {})", RULES.join(", ")),
                });
                continue;
            }
            if reason.is_empty() {
                diags.push(Diagnostic {
                    rule: "lint-allow".to_string(),
                    file: file.rel.clone(),
                    line,
                    subject: rule.clone(),
                    message: "suppression without a justification; write `lint:allow(rule): <why this is fine>`".to_string(),
                });
                continue;
            }
            out.push(InlineAllow { file: file.rel.clone(), line, rule, reason, used: false });
        }
    }
    out
}

/// Run every rule over already-lexed sources and apply suppression.
/// `docs` is the concatenated DESIGN.md + README.md text (for the
/// config-doc rule); `baseline` the parsed baseline entries.
pub fn lint_sources(
    files: &[SourceFile],
    docs: &str,
    baseline: &[BaselineEntry],
) -> LintOutcome {
    let mut raw: Vec<Diagnostic> = Vec::new();
    let mut violations: Vec<Diagnostic> = Vec::new();
    let mut allows: Vec<InlineAllow> = Vec::new();
    for f in files {
        allows.extend(collect_allows(f, &mut violations));
        raw.extend(lints::determinism(f));
        raw.extend(lints::panic_safety(f));
    }
    raw.extend(lints::wire_protocol(files));
    raw.extend(lints::config_doc(files, docs));

    let mut suppressed: Vec<(Diagnostic, String)> = Vec::new();
    let mut baseline_used = vec![false; baseline.len()];
    'next: for d in raw {
        for a in allows.iter_mut() {
            if a.rule == d.rule && a.file == d.file && (a.line == d.line || a.line + 1 == d.line) {
                a.used = true;
                suppressed.push((d, a.reason.clone()));
                continue 'next;
            }
        }
        for (k, b) in baseline.iter().enumerate() {
            if b.rule == d.rule && b.file == d.file && b.subject == d.subject {
                baseline_used[k] = true;
                if b.reason.trim().is_empty() {
                    violations.push(Diagnostic {
                        rule: "baseline".to_string(),
                        file: d.file.clone(),
                        line: d.line,
                        subject: d.subject.clone(),
                        message: "baseline entry matches this violation but carries no justification; add a `reason`".to_string(),
                    });
                } else {
                    suppressed.push((d, b.reason.clone()));
                }
                continue 'next;
            }
        }
        violations.push(d);
    }
    for a in &allows {
        if !a.used {
            violations.push(Diagnostic {
                rule: "unused-allow".to_string(),
                file: a.file.clone(),
                line: a.line,
                subject: a.rule.clone(),
                message: "lint:allow matches no violation on this or the next line; remove the stale suppression".to_string(),
            });
        }
    }
    for (k, b) in baseline.iter().enumerate() {
        if !baseline_used[k] {
            violations.push(Diagnostic {
                rule: "baseline".to_string(),
                file: b.file.clone(),
                line: 0,
                subject: b.subject.clone(),
                message: format!(
                    "stale baseline entry (rule {}): it matches no current violation; delete it so the tree ratchets",
                    b.rule
                ),
            });
        }
    }
    violations.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.subject).cmp(&(&b.file, b.line, &b.rule, &b.subject))
    });
    LintOutcome { violations, suppressed, files_scanned: files.len() }
}

/// Parse a baseline file: a JSON array of
/// `{"rule": "...", "file": "...", "subject": "...", "reason": "..."}`.
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>> {
    let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let arr = v.as_arr().map_err(|e| anyhow::anyhow!("baseline must be a JSON array: {e}"))?;
    let mut out = Vec::new();
    for (i, entry) in arr.iter().enumerate() {
        let rule = entry.str_or("rule", "");
        let file = entry.str_or("file", "");
        let subject = entry.str_or("subject", "");
        if rule.is_empty() || file.is_empty() || subject.is_empty() {
            bail!("baseline entry {i} needs non-empty rule, file, and subject");
        }
        out.push(BaselineEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            subject: subject.to_string(),
            reason: entry.str_or("reason", "").to_string(),
        });
    }
    Ok(out)
}

/// Default baseline location, relative to the repo root.
pub const DEFAULT_BASELINE: &str = "rust/lint-baseline.json";

/// Walk `rust/src`, `rust/tests`, and `rust/benches` under `root`, lex
/// every `.rs` file, and run the full pass. `baseline_path` overrides the
/// default `rust/lint-baseline.json` (which is optional; an explicit path
/// must exist).
pub fn run(root: &Path, baseline_path: Option<&Path>) -> Result<LintOutcome> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        collect_rs(&root.join("rust").join(sub), &mut paths)?;
    }
    paths.sort();
    let mut files = Vec::new();
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile { rel, lexed: lexer::lex(&text) });
    }
    let mut docs = String::new();
    for d in ["DESIGN.md", "README.md"] {
        if let Ok(t) = std::fs::read_to_string(root.join(d)) {
            docs.push_str(&t);
            docs.push('\n');
        }
    }
    let baseline = match baseline_path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading baseline {}", p.display()))?;
            parse_baseline(&text).with_context(|| format!("parsing baseline {}", p.display()))?
        }
        None => {
            let p = root.join(DEFAULT_BASELINE);
            match std::fs::read_to_string(&p) {
                Ok(text) => parse_baseline(&text)
                    .with_context(|| format!("parsing baseline {}", p.display()))?,
                Err(_) => Vec::new(),
            }
        }
    };
    Ok(lint_sources(&files, &docs, &baseline))
}

/// Recursively collect `.rs` files, skipping `fixtures` (lint test data
/// is deliberately violating), `vendor`, and `target` directories.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // optional dir (e.g. no benches/)
    };
    for entry in entries {
        let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if matches!(name.as_ref(), "fixtures" | "vendor" | "target") {
                continue;
            }
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root (the directory containing `rust/src/lib.rs`) by
/// walking up from the current directory, so the subcommand works from
/// the repo root, from `rust/`, or anywhere below.
pub fn find_repo_root() -> Result<PathBuf> {
    let mut dir = std::env::current_dir().context("resolving current directory")?;
    loop {
        if dir.join("rust").join("src").join("lib.rs").is_file() {
            return Ok(dir);
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => bail!("could not find the repo root (a directory containing rust/src/lib.rs) above the current directory"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src_file(rel: &str, text: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), lexed: lexer::lex(text) }
    }

    #[test]
    fn inline_allow_suppresses_same_line_and_next_line() {
        let text = "\
fn f() {
    // lint:allow(determinism): telemetry is wall-clock by design
    let t = Instant::now();
    let u = Instant::now(); // lint:allow(determinism): also telemetry
    let v = Instant::now();
}
";
        let out = lint_sources(&[src_file("rust/src/sched/x.rs", text)], "", &[]);
        assert_eq!(out.suppressed.len(), 2, "{:?}", out.violations);
        assert_eq!(out.violations.len(), 1);
        assert_eq!(out.violations[0].line, 5);
    }

    #[test]
    fn allow_without_reason_is_a_violation() {
        let text = "let t = Instant::now(); // lint:allow(determinism)\n";
        let out = lint_sources(&[src_file("rust/src/sched/x.rs", text)], "", &[]);
        assert!(out.violations.iter().any(|d| d.rule == "lint-allow"), "{:?}", out.violations);
        // The underlying violation is NOT suppressed by a reasonless allow.
        assert!(out.violations.iter().any(|d| d.rule == "determinism"));
    }

    #[test]
    fn unused_allow_is_flagged() {
        let text = "// lint:allow(determinism): nothing here actually violates\nfn f() {}\n";
        let out = lint_sources(&[src_file("rust/src/sched/x.rs", text)], "", &[]);
        assert_eq!(out.violations.len(), 1);
        assert_eq!(out.violations[0].rule, "unused-allow");
    }

    #[test]
    fn baseline_suppresses_and_goes_stale() {
        let text = "let t = Instant::now();\n";
        let entry = |subject: &str| BaselineEntry {
            rule: "determinism".to_string(),
            file: "rust/src/sched/x.rs".to_string(),
            subject: subject.to_string(),
            reason: "triaged legacy debt".to_string(),
        };
        let files = [src_file("rust/src/sched/x.rs", text)];
        let out = lint_sources(&files, "", &[entry("Instant::now")]);
        assert!(out.clean(), "{:?}", out.violations);
        assert_eq!(out.suppressed.len(), 1);
        let out = lint_sources(&files, "", &[entry("Instant::now"), entry("SystemTime::now")]);
        assert!(!out.clean());
        assert!(out.violations.iter().any(|d| d.rule == "baseline"
            && d.subject == "SystemTime::now"
            && d.message.contains("stale")));
    }

    #[test]
    fn baseline_parses_and_rejects_incomplete_entries() {
        let parsed = parse_baseline(
            r#"[{"rule":"determinism","file":"rust/src/a.rs","subject":"HashMap","reason":"r"}]"#,
        )
        .expect("valid baseline");
        assert_eq!(parsed.len(), 1);
        assert!(parse_baseline(r#"[{"rule":"determinism"}]"#).is_err());
        assert!(parse_baseline("{}").is_err());
    }
}
