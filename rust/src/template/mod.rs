//! Mini-Jinja prompt templates (stage 1: prompt preparation).
//!
//! Supports the subset the evaluation workflows actually use:
//!
//! - `{{ var }}` substitution with dotted access into nested objects
//! - filters: `{{ var | lower }}`, `upper`, `trim`, `truncate(n)`, `title`
//! - conditionals: `{% if var %} ... {% else %} ... {% endif %}`
//!   (truthiness: missing/empty string/0/false are falsy)
//! - loops: `{% for item in list %} ... {{ item }} ... {% endfor %}`
//!
//! Values come from a [`Json`] object per example (one DataFrame row).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// A parsed template, reusable across rows.
#[derive(Debug, Clone)]
pub struct Template {
    nodes: Vec<Node>,
    pub source: String,
}

#[derive(Debug, Clone)]
enum Node {
    Text(String),
    /// Variable path + filter chain.
    Var(Vec<String>, Vec<Filter>),
    If {
        cond: Vec<String>,
        negate: bool,
        then_nodes: Vec<Node>,
        else_nodes: Vec<Node>,
    },
    For {
        var: String,
        list: Vec<String>,
        body: Vec<Node>,
    },
}

#[derive(Debug, Clone)]
enum Filter {
    Lower,
    Upper,
    Trim,
    Title,
    Truncate(usize),
}

impl Template {
    pub fn parse(source: &str) -> Result<Template> {
        let tokens = tokenize(source)?;
        let mut pos = 0;
        let nodes = parse_nodes(&tokens, &mut pos, None)?;
        if pos != tokens.len() {
            bail!("unexpected block tag at token {pos}");
        }
        Ok(Template { nodes, source: source.to_string() })
    }

    /// Render against one row (a JSON object).
    pub fn render(&self, row: &Json) -> Result<String> {
        let mut out = String::with_capacity(self.source.len() * 2);
        render_nodes(&self.nodes, row, &mut out)?;
        Ok(out)
    }

    /// Variable paths referenced by the template (for validation).
    pub fn referenced_vars(&self) -> Vec<String> {
        let mut vars = Vec::new();
        collect_vars(&self.nodes, &mut vars);
        vars.sort();
        vars.dedup();
        vars
    }
}

fn collect_vars(nodes: &[Node], out: &mut Vec<String>) {
    for n in nodes {
        match n {
            Node::Var(path, _) => out.push(path.join(".")),
            Node::If { cond, then_nodes, else_nodes, .. } => {
                out.push(cond.join("."));
                collect_vars(then_nodes, out);
                collect_vars(else_nodes, out);
            }
            Node::For { list, body, .. } => {
                out.push(list.join("."));
                collect_vars(body, out);
            }
            Node::Text(_) => {}
        }
    }
}

// -- tokenizer ---------------------------------------------------------------

#[derive(Debug, Clone)]
enum Token {
    Text(String),
    Expr(String),  // {{ ... }}
    Block(String), // {% ... %}
}

fn tokenize(src: &str) -> Result<Vec<Token>> {
    let mut tokens = Vec::new();
    let mut rest = src;
    loop {
        let next_expr = rest.find("{{");
        let next_block = rest.find("{%");
        let (idx, is_expr) = match (next_expr, next_block) {
            (None, None) => {
                if !rest.is_empty() {
                    tokens.push(Token::Text(rest.to_string()));
                }
                return Ok(tokens);
            }
            (Some(e), None) => (e, true),
            (None, Some(b)) => (b, false),
            (Some(e), Some(b)) => {
                if e < b {
                    (e, true)
                } else {
                    (b, false)
                }
            }
        };
        if idx > 0 {
            tokens.push(Token::Text(rest[..idx].to_string()));
        }
        let (close, mk): (&str, fn(String) -> Token) = if is_expr {
            ("}}", Token::Expr)
        } else {
            ("%}", Token::Block)
        };
        let body_start = idx + 2;
        let end = rest[body_start..]
            .find(close)
            .ok_or_else(|| anyhow!("unterminated tag starting at byte {idx}"))?;
        let body = rest[body_start..body_start + end].trim().to_string();
        tokens.push(mk(body));
        rest = &rest[body_start + end + 2..];
    }
}

// -- parser ------------------------------------------------------------------

fn parse_path(s: &str) -> Vec<String> {
    s.split('.').map(|p| p.trim().to_string()).collect()
}

fn parse_filters(parts: &[&str]) -> Result<Vec<Filter>> {
    parts
        .iter()
        .map(|raw| {
            let f = raw.trim();
            Ok(if f == "lower" {
                Filter::Lower
            } else if f == "upper" {
                Filter::Upper
            } else if f == "trim" {
                Filter::Trim
            } else if f == "title" {
                Filter::Title
            } else if let Some(arg) = f.strip_prefix("truncate(").and_then(|x| x.strip_suffix(')')) {
                Filter::Truncate(arg.trim().parse()?)
            } else {
                bail!("unknown filter: {f}")
            })
        })
        .collect()
}

/// Parse until `stop` block tag (e.g. Some("endif")); returns nodes.
fn parse_nodes(tokens: &[Token], pos: &mut usize, stop: Option<&[&str]>) -> Result<Vec<Node>> {
    let mut nodes = Vec::new();
    while *pos < tokens.len() {
        match &tokens[*pos] {
            Token::Text(t) => {
                nodes.push(Node::Text(t.clone()));
                *pos += 1;
            }
            Token::Expr(e) => {
                let mut parts = e.split('|');
                let var = parts.next().unwrap().trim();
                let filters = parse_filters(&parts.collect::<Vec<_>>())?;
                nodes.push(Node::Var(parse_path(var), filters));
                *pos += 1;
            }
            Token::Block(b) => {
                let first = b.split_whitespace().next().unwrap_or("");
                if let Some(stops) = stop {
                    if stops.contains(&first) {
                        return Ok(nodes); // caller consumes the stop tag
                    }
                }
                *pos += 1;
                match first {
                    "if" => {
                        let rest = b["if".len()..].trim();
                        let (negate, cond) = if let Some(c) = rest.strip_prefix("not ") {
                            (true, c.trim())
                        } else {
                            (false, rest)
                        };
                        let then_nodes = parse_nodes(tokens, pos, Some(&["else", "endif"]))?;
                        let mut else_nodes = Vec::new();
                        if let Token::Block(tag) = &tokens[*pos] {
                            if tag.trim() == "else" {
                                *pos += 1;
                                else_nodes = parse_nodes(tokens, pos, Some(&["endif"]))?;
                            }
                        }
                        // consume endif
                        match &tokens[*pos] {
                            Token::Block(t) if t.trim() == "endif" => *pos += 1,
                            _ => bail!("expected endif"),
                        }
                        nodes.push(Node::If {
                            cond: parse_path(cond),
                            negate,
                            then_nodes,
                            else_nodes,
                        });
                    }
                    "for" => {
                        let rest = b["for".len()..].trim();
                        let (var, list) = rest
                            .split_once(" in ")
                            .ok_or_else(|| anyhow!("bad for syntax: {b}"))?;
                        let body = parse_nodes(tokens, pos, Some(&["endfor"]))?;
                        match &tokens[*pos] {
                            Token::Block(t) if t.trim() == "endfor" => *pos += 1,
                            _ => bail!("expected endfor"),
                        }
                        nodes.push(Node::For {
                            var: var.trim().to_string(),
                            list: parse_path(list.trim()),
                            body,
                        });
                    }
                    other => bail!("unexpected block tag: {other}"),
                }
            }
        }
    }
    if stop.is_some() {
        bail!("unterminated block (missing endif/endfor)");
    }
    Ok(nodes)
}

// -- renderer ------------------------------------------------------------------

fn lookup<'a>(row: &'a Json, path: &[String]) -> Option<&'a Json> {
    let mut cur = row;
    for seg in path {
        cur = cur.opt(seg)?;
    }
    Some(cur)
}

fn to_text(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        Json::Null => String::new(),
        other => other.to_string(),
    }
}

fn truthy(v: Option<&Json>) -> bool {
    match v {
        None | Some(Json::Null) => false,
        Some(Json::Bool(b)) => *b,
        Some(Json::Num(n)) => *n != 0.0,
        Some(Json::Str(s)) => !s.is_empty(),
        Some(Json::Arr(a)) => !a.is_empty(),
        Some(Json::Obj(o)) => !o.is_empty(),
    }
}

fn apply_filters(mut s: String, filters: &[Filter]) -> String {
    for f in filters {
        s = match f {
            Filter::Lower => s.to_lowercase(),
            Filter::Upper => s.to_uppercase(),
            Filter::Trim => s.trim().to_string(),
            Filter::Title => s
                .split_whitespace()
                .map(|w| {
                    let mut c = w.chars();
                    match c.next() {
                        Some(first) => first.to_uppercase().collect::<String>() + c.as_str(),
                        None => String::new(),
                    }
                })
                .collect::<Vec<_>>()
                .join(" "),
            Filter::Truncate(n) => {
                if s.chars().count() > *n {
                    let cut: String = s.chars().take(*n).collect();
                    format!("{cut}...")
                } else {
                    s
                }
            }
        };
    }
    s
}

fn render_nodes(nodes: &[Node], row: &Json, out: &mut String) -> Result<()> {
    for node in nodes {
        match node {
            Node::Text(t) => out.push_str(t),
            Node::Var(path, filters) => {
                let v = lookup(row, path)
                    .ok_or_else(|| anyhow!("template variable not found: {}", path.join(".")))?;
                out.push_str(&apply_filters(to_text(v), filters));
            }
            Node::If { cond, negate, then_nodes, else_nodes } => {
                let mut t = truthy(lookup(row, cond));
                if *negate {
                    t = !t;
                }
                render_nodes(if t { then_nodes } else { else_nodes }, row, out)?;
            }
            Node::For { var, list, body } => {
                let items = lookup(row, list)
                    .ok_or_else(|| anyhow!("template list not found: {}", list.join(".")))?
                    .as_arr()
                    .map_err(|_| anyhow!("{} is not a list", list.join(".")))?;
                for item in items {
                    // Shadow the loop variable in a copied row scope.
                    let mut scope = row.as_obj()?.clone();
                    scope.insert(var.clone(), item.clone());
                    render_nodes(body, &Json::Obj(scope), out)?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(pairs: Vec<(&str, Json)>) -> Json {
        Json::obj(pairs)
    }

    #[test]
    fn plain_substitution() {
        let t = Template::parse("Answer: {{ question }}").unwrap();
        let r = t.render(&row(vec![("question", Json::str("why?"))])).unwrap();
        assert_eq!(r, "Answer: why?");
    }

    #[test]
    fn dotted_access() {
        let t = Template::parse("{{ meta.domain }}").unwrap();
        let r = t
            .render(&row(vec![(
                "meta",
                Json::obj(vec![("domain", Json::str("qa"))]),
            )]))
            .unwrap();
        assert_eq!(r, "qa");
    }

    #[test]
    fn filters() {
        let t = Template::parse("{{ x | upper }} {{ x | title }} {{ y | truncate(3) }}").unwrap();
        let r = t
            .render(&row(vec![
                ("x", Json::str("hello world")),
                ("y", Json::str("abcdef")),
            ]))
            .unwrap();
        assert_eq!(r, "HELLO WORLD Hello World abc...");
    }

    #[test]
    fn if_else() {
        let t =
            Template::parse("{% if ctx %}Context: {{ ctx }}{% else %}No context{% endif %}").unwrap();
        assert_eq!(
            t.render(&row(vec![("ctx", Json::str("docs"))])).unwrap(),
            "Context: docs"
        );
        assert_eq!(t.render(&row(vec![("ctx", Json::str(""))])).unwrap(), "No context");
        assert_eq!(t.render(&row(vec![])).unwrap(), "No context");
    }

    #[test]
    fn if_not() {
        let t = Template::parse("{% if not ctx %}empty{% endif %}").unwrap();
        assert_eq!(t.render(&row(vec![])).unwrap(), "empty");
        assert_eq!(t.render(&row(vec![("ctx", Json::str("x"))])).unwrap(), "");
    }

    #[test]
    fn for_loop() {
        let t = Template::parse("{% for c in chunks %}[{{ c }}]{% endfor %}").unwrap();
        let r = t
            .render(&row(vec![(
                "chunks",
                Json::arr(vec![Json::str("a"), Json::str("b")]),
            )]))
            .unwrap();
        assert_eq!(r, "[a][b]");
    }

    #[test]
    fn nested_blocks() {
        let t = Template::parse(
            "{% for d in docs %}{% if d %}<{{ d | upper }}>{% endif %}{% endfor %}",
        )
        .unwrap();
        let r = t
            .render(&row(vec![(
                "docs",
                Json::arr(vec![Json::str("x"), Json::str(""), Json::str("y")]),
            )]))
            .unwrap();
        assert_eq!(r, "<X><Y>");
    }

    #[test]
    fn missing_variable_errors() {
        let t = Template::parse("{{ nope }}").unwrap();
        assert!(t.render(&row(vec![])).is_err());
    }

    #[test]
    fn parse_errors() {
        assert!(Template::parse("{{ x ").is_err());
        assert!(Template::parse("{% if x %}no end").is_err());
        assert!(Template::parse("{% frobnicate %}").is_err());
        assert!(Template::parse("{{ x | nonsense }}").is_err());
    }

    #[test]
    fn referenced_vars() {
        let t = Template::parse("{{ a }} {% if b %}{{ c.d }}{% endif %}").unwrap();
        assert_eq!(t.referenced_vars(), vec!["a", "b", "c.d"]);
    }

    #[test]
    fn numeric_rendering() {
        let t = Template::parse("n={{ n }}").unwrap();
        assert_eq!(t.render(&row(vec![("n", Json::num(5.0))])).unwrap(), "n=5");
    }

    #[test]
    fn listing2_style_template() {
        // The paper's prompt-preparation usage: instruction + optional input.
        let t = Template::parse(
            "Instruction: {{ instruction }}\n{% if input %}Input: {{ input }}\n{% endif %}Response:",
        )
        .unwrap();
        let with = t
            .render(&row(vec![
                ("instruction", Json::str("Summarize")),
                ("input", Json::str("long text")),
            ]))
            .unwrap();
        assert!(with.contains("Input: long text"));
        let without = t
            .render(&row(vec![("instruction", Json::str("Summarize"))]))
            .unwrap();
        assert!(!without.contains("Input:"));
    }
}
