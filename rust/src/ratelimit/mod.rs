//! Per-executor token-bucket rate limiting (paper §3.1, Algorithm 1).
//!
//! Providers impose limits on both requests/minute (RPM) and tokens/minute
//! (TPM). Each executor gets a 1/E share of the global budget; within an
//! executor a dual token bucket (request bucket + token bucket) computes
//! the wait time before each call.
//!
//! Time is abstracted behind [`Clock`] so the same bucket logic runs in
//! wall-clock mode (real evaluation) and in virtual time (the
//! discrete-event simulator that regenerates Figure 2 / Table 3 in
//! seconds instead of hours).

pub mod adaptive;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Time source. `now()` is in seconds.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
    /// Sleep for `secs`; virtual clocks advance instead of blocking.
    fn sleep(&self, secs: f64);
    /// True when `sleep` advances simulated time instead of blocking the
    /// calling thread. Concurrent sleepers on such a clock *serialize*
    /// their advances (each `sleep` moves shared time forward), so code
    /// that overlaps latency across threads — the pipelined provider
    /// client ([`crate::providers::pipeline`]) — must coordinate waits
    /// instead of sleeping independently.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Wall clock backed by `std::time`.
#[derive(Debug, Default)]
pub struct RealClock {
    start: std::sync::OnceLock<std::time::Instant>,
}

impl RealClock {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.get_or_init(std::time::Instant::now).elapsed().as_secs_f64()
    }

    fn sleep(&self, secs: f64) {
        if secs > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }
}

/// Virtual clock for simulation and fast tests: `sleep` advances time.
/// Shared across threads via atomics (stored as f64 bits).
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_bits: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self { now_bits: AtomicU64::new(0f64.to_bits()) })
    }

    pub fn advance(&self, secs: f64) {
        // CAS loop: add secs to the stored f64.
        loop {
            let cur = self.now_bits.load(Ordering::SeqCst);
            let next = (f64::from_bits(cur) + secs).to_bits();
            if self
                .now_bits
                .compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return;
            }
        }
    }

    pub fn set(&self, t: f64) {
        self.now_bits.store(t.to_bits(), Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.now_bits.load(Ordering::SeqCst))
    }

    fn sleep(&self, secs: f64) {
        if secs > 0.0 {
            self.advance(secs);
        }
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

/// Dual token bucket implementing Algorithm 1 exactly:
/// refill at `limit/60` per second up to `limit`, wait when short.
#[derive(Debug)]
pub struct TokenBucket {
    /// Per-executor request limit `r` (requests/minute).
    r: f64,
    /// Per-executor token limit `t` (tokens/minute).
    t: f64,
    request_tokens: f64,
    token_tokens: f64,
    last_update: f64,
    /// Total time spent waiting (for utilization accounting).
    pub total_wait: f64,
    /// Requests admitted.
    pub admitted: u64,
}

impl TokenBucket {
    /// Build a bucket holding a 1/`executors` share of the global limits.
    pub fn per_executor(global_rpm: f64, global_tpm: f64, executors: usize, clock: &dyn Clock) -> Self {
        let e = executors.max(1) as f64;
        Self::new(global_rpm / e, global_tpm / e, clock)
    }

    pub fn new(rpm: f64, tpm: f64, clock: &dyn Clock) -> Self {
        // Algorithm 1 initializes the bucket full (lines 3–4).
        Self::with_fill(rpm, tpm, 1.0, clock)
    }

    /// Construct with a partial initial fill. Real endpoints do not grant
    /// a fresh client a full minute of burst; the simulator uses a small
    /// fill to measure steady-state throughput.
    pub fn with_fill(rpm: f64, tpm: f64, fill: f64, clock: &dyn Clock) -> Self {
        assert!(rpm > 0.0 && tpm > 0.0, "limits must be positive");
        let fill = fill.clamp(0.0, 1.0);
        Self {
            r: rpm,
            t: tpm,
            request_tokens: rpm * fill,
            token_tokens: tpm * fill,
            last_update: clock.now(),
            total_wait: 0.0,
            admitted: 0,
        }
    }

    /// Current per-executor limits (rpm, tpm).
    pub fn limits(&self) -> (f64, f64) {
        (self.r, self.t)
    }

    /// Replace the limits (adaptive redistribution). Clamps stored tokens
    /// to the new capacity.
    pub fn set_limits(&mut self, rpm: f64, tpm: f64) {
        assert!(rpm > 0.0 && tpm > 0.0);
        self.r = rpm;
        self.t = tpm;
        self.request_tokens = self.request_tokens.min(rpm);
        self.token_tokens = self.token_tokens.min(tpm);
    }

    fn refill(&mut self, now: f64) {
        let elapsed = (now - self.last_update).max(0.0);
        self.request_tokens = (self.request_tokens + elapsed * self.r / 60.0).min(self.r);
        self.token_tokens = (self.token_tokens + elapsed * self.t / 60.0).min(self.t);
        self.last_update = now;
    }

    /// Wait time needed *right now* for a request of `estimated_tokens`,
    /// without consuming (Algorithm 1 lines 11–17).
    pub fn required_wait(&mut self, estimated_tokens: f64, now: f64) -> f64 {
        self.refill(now);
        let mut wait: f64 = 0.0;
        if self.request_tokens < 1.0 {
            wait = wait.max((1.0 - self.request_tokens) * 60.0 / self.r);
        }
        if self.token_tokens < estimated_tokens {
            wait = wait.max((estimated_tokens - self.token_tokens) * 60.0 / self.t);
        }
        wait
    }

    /// Algorithm 1 `Acquire`: block (via the clock) until the request is
    /// admissible, then consume. Returns the time waited.
    pub fn acquire(&mut self, estimated_tokens: f64, clock: &dyn Clock) -> f64 {
        let wait = self.required_wait(estimated_tokens, clock.now());
        if wait > 0.0 {
            clock.sleep(wait);
            self.refill(clock.now());
        }
        self.request_tokens -= 1.0;
        self.token_tokens -= estimated_tokens;
        self.total_wait += wait;
        self.admitted += 1;
        wait
    }

    /// Fraction of capacity currently available (diagnostics).
    pub fn occupancy(&self) -> (f64, f64) {
        (self.request_tokens / self.r, self.token_tokens / self.t)
    }

    /// Discrete-event variant of `acquire`: given the current virtual time
    /// `now`, return the admission time of a request of `estimated_tokens`
    /// and consume the budget at that time. Used by the simulator, which
    /// manages time explicitly instead of sleeping on a clock.
    pub fn acquire_at(&mut self, estimated_tokens: f64, now: f64) -> f64 {
        let wait = self.required_wait(estimated_tokens, now);
        let admission = now + wait;
        if wait > 0.0 {
            self.refill(admission);
        }
        self.request_tokens -= 1.0;
        self.token_tokens -= estimated_tokens;
        self.total_wait += wait;
        self.admitted += 1;
        admission
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_full_and_admits_burst() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(60.0, 6000.0, clock.as_ref());
        // 60 requests admissible immediately (bucket starts full).
        for _ in 0..60 {
            let w = b.acquire(10.0, clock.as_ref());
            assert_eq!(w, 0.0);
        }
        assert_eq!(clock.now(), 0.0);
    }

    #[test]
    fn enforces_rpm_rate_after_burst() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(60.0, 1e9, clock.as_ref());
        for _ in 0..60 {
            b.acquire(1.0, clock.as_ref());
        }
        // Bucket drained: the next request must wait 60/r = 1s.
        let w = b.acquire(1.0, clock.as_ref());
        assert!((w - 1.0).abs() < 1e-9, "wait {w}");
        assert!((clock.now() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn enforces_tpm_rate() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(1e9, 600.0, clock.as_ref());
        // One request of 600 tokens drains TPM; the next 600-token request
        // must wait a full minute.
        b.acquire(600.0, clock.as_ref());
        let w = b.acquire(600.0, clock.as_ref());
        assert!((w - 60.0).abs() < 1e-6, "wait {w}");
    }

    #[test]
    fn binding_constraint_wins() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(60.0, 60.0, clock.as_ref());
        b.acquire(60.0, clock.as_ref()); // drains token bucket, 59 reqs left
        // Next request needs 30 tokens: token wait = 30*60/60 = 30s; request
        // wait = 0. Token constraint binds.
        let w = b.acquire(30.0, clock.as_ref());
        assert!((w - 30.0).abs() < 1e-6, "wait {w}");
    }

    #[test]
    fn per_executor_split() {
        let clock = VirtualClock::new();
        let b = TokenBucket::per_executor(10_000.0, 2_000_000.0, 8, clock.as_ref());
        let (rpm, tpm) = b.limits();
        assert!((rpm - 1250.0).abs() < 1e-9);
        assert!((tpm - 250_000.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_converges_to_limit() {
        // Sustained load at rpm=600 must admit ~600 requests per virtual
        // minute (after the initial burst).
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(600.0, 1e12, clock.as_ref());
        let mut admitted_after_burst = 0u64;
        while clock.now() < 120.0 {
            b.acquire(100.0, clock.as_ref());
            if clock.now() > 60.0 {
                admitted_after_burst += 1;
            }
        }
        // Second minute should admit ≈600.
        assert!(
            (550..=650).contains(&(admitted_after_burst as i64)),
            "admitted {admitted_after_burst}"
        );
    }

    #[test]
    fn set_limits_clamps() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(1000.0, 100_000.0, clock.as_ref());
        b.set_limits(10.0, 100.0);
        let (occ_r, occ_t) = b.occupancy();
        assert!(occ_r <= 1.0 && occ_t <= 1.0);
        let (rpm, tpm) = b.limits();
        assert_eq!((rpm, tpm), (10.0, 100.0));
    }

    #[test]
    fn virtual_clock_threadsafe_advance() {
        let clock = VirtualClock::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = clock.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.advance(0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((clock.now() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn required_wait_does_not_consume() {
        let clock = VirtualClock::new();
        let mut b = TokenBucket::new(60.0, 6000.0, clock.as_ref());
        let w1 = b.required_wait(10.0, clock.now());
        let w2 = b.required_wait(10.0, clock.now());
        assert_eq!(w1, w2);
        assert_eq!(w1, 0.0);
        assert_eq!(b.admitted, 0);
    }
}
