//! Adaptive rate-limit redistribution (paper §6.1 "Limitations" —
//! implemented here as the extension the authors defer).
//!
//! The static 1/E split wastes budget when partitions are skewed: an
//! executor that finishes early leaves its share idle while loaded
//! executors throttle. The [`RateCoordinator`] periodically rebalances:
//! each executor reports demand (recent admit + wait statistics); shares
//! are reassigned proportionally to demand with a floor so no executor
//! starves. The global sum never exceeds the provider budget — that is the
//! invariant `rebalance` maintains and the property tests check.

use std::sync::Mutex;

/// Demand report from one executor for the last window.
#[derive(Debug, Clone, Copy, Default)]
pub struct DemandReport {
    /// Requests admitted in the window.
    pub admitted: u64,
    /// Seconds spent waiting on the bucket in the window.
    pub waited: f64,
    /// Whether the executor still has work queued.
    pub backlog: bool,
}

/// Assigned per-executor share.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Share {
    pub rpm: f64,
    pub tpm: f64,
}

/// Coordinator state: global budget + last assignment.
#[derive(Debug)]
pub struct RateCoordinator {
    global_rpm: f64,
    global_tpm: f64,
    executors: usize,
    /// Minimum fraction of the even split each executor keeps.
    floor_frac: f64,
    shares: Mutex<Vec<Share>>,
}

impl RateCoordinator {
    pub fn new(global_rpm: f64, global_tpm: f64, executors: usize) -> Self {
        assert!(executors > 0);
        let even = Share { rpm: global_rpm / executors as f64, tpm: global_tpm / executors as f64 };
        Self {
            global_rpm,
            global_tpm,
            executors,
            floor_frac: 0.25,
            shares: Mutex::new(vec![even; executors]),
        }
    }

    pub fn shares(&self) -> Vec<Share> {
        self.shares.lock().unwrap().clone()
    }

    /// Recompute shares from demand reports.
    ///
    /// Demand weight = admitted + wait-pressure bonus; executors with no
    /// backlog fall to the floor share, and the freed budget is spread over
    /// backlogged executors proportionally to weight.
    pub fn rebalance(&self, reports: &[DemandReport]) -> Vec<Share> {
        assert_eq!(reports.len(), self.executors);
        let even_rpm = self.global_rpm / self.executors as f64;
        let even_tpm = self.global_tpm / self.executors as f64;
        let floor_rpm = even_rpm * self.floor_frac;
        let floor_tpm = even_tpm * self.floor_frac;

        let weights: Vec<f64> = reports
            .iter()
            .map(|r| {
                if !r.backlog {
                    0.0
                } else {
                    // Wait pressure: an executor that waited the whole
                    // window wants ~2x; scale bonus into [1, 3].
                    1.0 + (r.admitted as f64) + 2.0 * r.waited.clamp(0.0, 60.0) / 60.0
                }
            })
            .collect();
        let total_w: f64 = weights.iter().sum();

        let mut new_shares = Vec::with_capacity(self.executors);
        if total_w <= 0.0 {
            // Nobody has a backlog: reset to the even split.
            for _ in 0..self.executors {
                new_shares.push(Share { rpm: even_rpm, tpm: even_tpm });
            }
        } else {
            // Everyone keeps the floor; the remainder is demand-weighted.
            let pool_rpm = self.global_rpm - floor_rpm * self.executors as f64;
            let pool_tpm = self.global_tpm - floor_tpm * self.executors as f64;
            for w in &weights {
                let frac = w / total_w;
                new_shares.push(Share {
                    rpm: floor_rpm + pool_rpm * frac,
                    tpm: floor_tpm + pool_tpm * frac,
                });
            }
        }

        debug_assert!(
            (new_shares.iter().map(|s| s.rpm).sum::<f64>() - self.global_rpm).abs()
                < 1e-6 * self.global_rpm
        );
        *self.shares.lock().unwrap() = new_shares.clone();
        new_shares
    }

    pub fn global_limits(&self) -> (f64, f64) {
        (self.global_rpm, self.global_tpm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn sum_rpm(shares: &[Share]) -> f64 {
        shares.iter().map(|s| s.rpm).sum()
    }

    #[test]
    fn even_split_initially() {
        let c = RateCoordinator::new(8000.0, 800_000.0, 8);
        for s in c.shares() {
            assert!((s.rpm - 1000.0).abs() < 1e-9);
            assert!((s.tpm - 100_000.0).abs() < 1e-9);
        }
    }

    #[test]
    fn idle_executors_release_budget() {
        let c = RateCoordinator::new(8000.0, 800_000.0, 4);
        let reports = vec![
            DemandReport { admitted: 100, waited: 30.0, backlog: true },
            DemandReport { admitted: 100, waited: 30.0, backlog: true },
            DemandReport { admitted: 5, waited: 0.0, backlog: false },
            DemandReport { admitted: 0, waited: 0.0, backlog: false },
        ];
        let shares = c.rebalance(&reports);
        // Busy executors get more than the even split; idle get the floor.
        assert!(shares[0].rpm > 2000.0);
        assert!(shares[2].rpm < 2000.0);
        assert!((sum_rpm(&shares) - 8000.0).abs() < 1e-6);
    }

    #[test]
    fn all_idle_resets_to_even() {
        let c = RateCoordinator::new(6000.0, 600_000.0, 3);
        let reports = vec![DemandReport::default(); 3];
        let shares = c.rebalance(&reports);
        for s in &shares {
            assert!((s.rpm - 2000.0).abs() < 1e-9);
        }
    }

    #[test]
    fn property_budget_conserved_and_floored() {
        check("rebalance conserves global budget", 200, |rng| {
            let e = 1 + rng.below(16);
            let rpm = 100.0 + rng.f64() * 100_000.0;
            let tpm = 1000.0 + rng.f64() * 10_000_000.0;
            let c = RateCoordinator::new(rpm, tpm, e);
            let reports: Vec<DemandReport> = (0..e)
                .map(|_| DemandReport {
                    admitted: rng.below(1000) as u64,
                    waited: rng.f64() * 60.0,
                    backlog: rng.chance(0.7),
                })
                .collect();
            let shares = c.rebalance(&reports);
            let total: f64 = shares.iter().map(|s| s.rpm).sum();
            ensure((total - rpm).abs() < 1e-6 * rpm, format!("sum {total} != {rpm}"))?;
            let floor = rpm / e as f64 * 0.25;
            for (i, s) in shares.iter().enumerate() {
                ensure(s.rpm >= floor - 1e-9, format!("executor {i} below floor"))?;
                ensure(s.tpm > 0.0, "tpm positive")?;
            }
            Ok(())
        });
    }
}
