//! Data-parallel execution engine — the Spark substrate (paper §3.1).
//!
//! Mirrors the subset of Spark the evaluation pipeline uses:
//!
//! - a DataFrame is **range-partitioned** across `executors`;
//! - each executor thread owns **executor-local state** created once per
//!   executor (Listing 1's `_ENGINE_CACHE`: inference engine + token
//!   bucket);
//! - partitions are processed in **batches** of `batch_size` rows
//!   (Pandas-UDF batch semantics);
//! - per-row outputs are collected back **in row order** (result
//!   collection), with per-executor telemetry.

use crate::data::DataFrame;
use crate::sched::SchedulerConfig;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-executor telemetry returned with the job results.
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    pub executor_id: usize,
    pub rows_processed: usize,
    pub batches: usize,
    /// Wall-clock seconds this executor spent inside the UDF — **pipeline
    /// occupancy**, not summed per-request latency: a batch that overlaps
    /// eight in-flight requests accrues its elapsed wall time once, so
    /// `busy_secs` never exceeds the executor's share of job wall time.
    pub busy_secs: f64,
    /// Peak number of simultaneously in-flight provider requests observed
    /// in this executor's pipelined batches (0 for stages that do not
    /// pipeline; 1 on the sequential path). Populated by pipelined UDFs
    /// ([`crate::coordinator::EvalRunner::run_inference`]); the scheduler
    /// itself does not track it.
    pub peak_in_flight: usize,
}

/// Job-level outcome: per-row outputs in row order + telemetry.
#[derive(Debug)]
pub struct JobOutput<T> {
    pub rows: Vec<T>,
    pub executors: Vec<ExecutorStats>,
}

/// One batch handed to the UDF: the owning partition's row range within
/// the source frame.
#[derive(Debug, Clone, Copy)]
pub struct BatchSlice {
    pub executor_id: usize,
    pub start: usize,
    pub end: usize,
}

impl BatchSlice {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    pub fn indices(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }
}

/// Run a batch UDF over `df` with `executors` threads.
///
/// `init(executor_id)` builds the executor-local state once per executor.
/// `process(state, df, slice)` maps one batch to one output per row
/// (must return exactly `slice.len()` values).
///
/// This is now a thin compatibility wrapper over the task scheduler
/// ([`crate::sched::run_scheduled`]) with [`SchedulerConfig::legacy`]: one
/// pinned task per executor, no stealing, no speculation, no retry —
/// exactly the original static range-partitioning semantics (each executor
/// processes its own contiguous partition, errors propagate on first
/// failure). Callers that want dynamic scheduling call the scheduler
/// directly with a real [`SchedulerConfig`].
pub fn run_partitioned<T, S, FI, FP>(
    df: &DataFrame,
    executors: usize,
    batch_size: usize,
    init: FI,
    process: FP,
) -> Result<JobOutput<T>>
where
    T: Send,
    S: Send,
    FI: Fn(usize) -> Result<S> + Sync,
    FP: Fn(&mut S, &DataFrame, BatchSlice) -> Result<Vec<T>> + Sync,
{
    let out = crate::sched::run_scheduled(
        df,
        executors,
        batch_size,
        &SchedulerConfig::legacy(),
        None,
        init,
        process,
    )?;
    Ok(JobOutput { rows: out.rows, executors: out.executors })
}

/// Shared progress counter for long jobs (driver-side reporting).
#[derive(Debug, Default)]
pub struct Progress {
    done: AtomicUsize,
    total: AtomicUsize,
}

impl Progress {
    pub fn new(total: usize) -> Self {
        Self { done: AtomicUsize::new(0), total: AtomicUsize::new(total) }
    }

    pub fn add(&self, n: usize) {
        self.done.fetch_add(n, Ordering::Relaxed);
    }

    pub fn fraction(&self) -> f64 {
        let total = self.total.load(Ordering::Relaxed);
        if total == 0 {
            return 1.0;
        }
        self.done.load(Ordering::Relaxed) as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Value;
    use crate::util::proptest::{check, ensure};

    fn frame(n: usize) -> DataFrame {
        DataFrame::from_columns(vec![(
            "x",
            (0..n as i64).map(Value::Int).collect::<Vec<_>>(),
        )])
        .unwrap()
    }

    #[test]
    fn results_in_row_order() {
        let df = frame(103);
        let out = run_partitioned(
            &df,
            7,
            10,
            |_eid| Ok(()),
            |_s, df, slice| {
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap() * 2.0)
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows.len(), 103);
        for (i, v) in out.rows.iter().enumerate() {
            assert_eq!(*v, i as f64 * 2.0);
        }
    }

    #[test]
    fn init_called_once_per_executor() {
        let df = frame(60);
        let out = run_partitioned(
            &df,
            4,
            5,
            |eid| Ok((eid, 0usize)),
            |state, _df, slice| {
                state.1 += 1;
                Ok(vec![state.0; slice.len()])
            },
        )
        .unwrap();
        // Each row is tagged with its executor id; 4 distinct ids, each
        // covering a contiguous 15-row partition.
        for eid in 0..4 {
            let rows: Vec<usize> = out.rows.iter().copied().filter(|&e| e == eid).collect();
            assert_eq!(rows.len(), 15);
        }
        // Telemetry: 3 batches each (15 rows / batch 5).
        for st in &out.executors {
            assert_eq!(st.batches, 3);
            assert_eq!(st.rows_processed, 15);
        }
    }

    #[test]
    fn more_executors_than_rows() {
        let df = frame(3);
        let out = run_partitioned(&df, 8, 10, |_| Ok(()), |_, _, s| Ok(vec![1u8; s.len()])).unwrap();
        assert_eq!(out.rows.len(), 3);
    }

    #[test]
    fn empty_frame() {
        let df = frame(0);
        let out = run_partitioned(&df, 4, 10, |_| Ok(()), |_, _, s| Ok(vec![0u8; s.len()])).unwrap();
        assert!(out.rows.is_empty());
    }

    #[test]
    fn udf_error_propagates() {
        let df = frame(10);
        let r = run_partitioned(
            &df,
            2,
            5,
            |_| Ok(()),
            |_, _, slice| {
                if slice.start >= 5 {
                    anyhow::bail!("boom");
                }
                Ok(vec![0u8; slice.len()])
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn wrong_output_length_detected() {
        let df = frame(10);
        let r = run_partitioned(&df, 1, 10, |_| Ok(()), |_, _, _| Ok(vec![0u8; 3]));
        assert!(r.is_err());
    }

    #[test]
    fn property_cover_disjoint_ordered() {
        check("partitioned map is identity-preserving", 40, |rng| {
            let n = rng.below(200);
            let execs = 1 + rng.below(12);
            let batch = 1 + rng.below(20);
            let df = frame(n);
            let out = run_partitioned(
                &df,
                execs,
                batch,
                |_| Ok(()),
                |_, df, slice| {
                    Ok(slice
                        .indices()
                        .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                        .collect())
                },
            )
            .unwrap();
            ensure(out.rows.len() == n, "length")?;
            for (i, v) in out.rows.iter().enumerate() {
                ensure(*v == i as f64, format!("row {i} = {v}"))?;
            }
            let total: usize = out.executors.iter().map(|e| e.rows_processed).sum();
            ensure(total == n, "telemetry sums to n")?;
            Ok(())
        });
    }

    #[test]
    fn progress_counter() {
        let p = Progress::new(10);
        assert_eq!(p.fraction(), 0.0);
        p.add(5);
        assert_eq!(p.fraction(), 0.5);
        let p0 = Progress::new(0);
        assert_eq!(p0.fraction(), 1.0);
    }
}
