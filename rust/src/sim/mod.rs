//! Discrete-event simulator for the distributed inference stage.
//!
//! Figure 2 / Table 3 / Table 4 measure *throughput under API rate limits*
//! — a queueing phenomenon. Running them in wall-clock time would take
//! hours per sweep point; the DES reproduces the same dynamics in virtual
//! time using the **identical token-bucket implementation**
//! ([`crate::ratelimit::TokenBucket::acquire_at`]) and the same latency
//! profiles as the live provider simulation.
//!
//! Model (matching the live engine's executor semantics):
//! - `executors` independent workers, each owning a 1/E share of the
//!   global RPM/TPM budget;
//! - each worker drives up to `concurrency` in-flight requests (the async
//!   batch client inside one Pandas-UDF executor);
//! - per-request latency is lognormal (median/sigma from the model
//!   profile);
//! - cache hits bypass the network and cost `local_ms` of local work;
//! - job startup and per-batch scheduling overheads model Spark's job
//!   scheduling cost (visible at small dataset sizes, Table 3).

use crate::providers::pricing::ModelProfile;
use crate::ratelimit::TokenBucket;
use crate::stats::describe::quantile_sorted;
use crate::util::rng::Rng;
use std::collections::BinaryHeap;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub n_examples: usize,
    pub executors: usize,
    /// Concurrent in-flight requests per executor.
    pub concurrency: usize,
    pub batch_size: usize,
    pub global_rpm: f64,
    pub global_tpm: f64,
    /// Latency profile (median ms + lognormal sigma).
    pub latency_p50_ms: f64,
    pub latency_sigma: f64,
    /// Tokens metered against TPM per request.
    pub tokens_per_request: f64,
    /// Fraction of requests served from cache.
    pub cache_hit_rate: f64,
    /// Local processing per cached/processed example (ms).
    pub local_ms: f64,
    /// One-off job scheduling overhead (s).
    pub startup_secs: f64,
    /// Scheduling overhead per batch (s).
    pub per_batch_overhead_secs: f64,
    /// Average input/output tokens (cost accounting).
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Partition skew: fraction of all examples assigned to the first
    /// half of the executors (0.5 = balanced).
    pub skew: f64,
    /// Adaptive rate-limit redistribution (§6.1 extension): shares
    /// proportional to partition size instead of the static 1/E split.
    pub adaptive_shares: bool,
    pub seed: u64,
}

impl Default for SimParams {
    fn default() -> Self {
        Self {
            n_examples: 10_000,
            executors: 8,
            concurrency: 8,
            batch_size: 50,
            global_rpm: 10_000.0,
            global_tpm: 2_000_000.0,
            latency_p50_ms: 320.0,
            latency_sigma: 0.45,
            tokens_per_request: 180.0,
            cache_hit_rate: 0.0,
            local_ms: 0.3,
            startup_secs: 2.0,
            per_batch_overhead_secs: 0.01,
            input_tokens: 400,
            output_tokens: 150,
            skew: 0.5,
            adaptive_shares: false,
            seed: 0,
        }
    }
}

impl SimParams {
    pub fn from_profile(mut self, profile: &ModelProfile) -> Self {
        self.latency_p50_ms = profile.latency_p50_ms;
        self.latency_sigma = profile.latency_sigma;
        self
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub total_secs: f64,
    pub throughput_per_min: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub api_calls: u64,
    pub cache_hits: u64,
    pub cost_usd: f64,
    /// Mean fraction of executor wall time spent waiting on the bucket.
    pub rate_wait_frac: f64,
}

/// Min-heap entry: in-flight request completion time.
#[derive(PartialEq)]
struct Slot(f64);

impl Eq for Slot {}

impl Ord for Slot {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for min-heap.
        other.0.partial_cmp(&self.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}

impl PartialOrd for Slot {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Run the simulation.
pub fn simulate(params: &SimParams, profile: Option<&ModelProfile>) -> SimOutcome {
    let p = params;
    let executors = p.executors.max(1);
    // Partition the examples, optionally with skew: the first half of the
    // executors receives `skew` of the dataset.
    let n_per_executor: Vec<usize> = if executors < 2 || (p.skew - 0.5).abs() < 1e-12 {
        // Even range partitioning.
        let base = p.n_examples / executors;
        let extra = p.n_examples % executors;
        (0..executors).map(|eid| base + usize::from(eid < extra)).collect()
    } else {
        let half = executors / 2;
        let heavy_total = (p.n_examples as f64 * p.skew).round() as usize;
        let light_total = p.n_examples - heavy_total;
        let mut out = Vec::with_capacity(executors);
        for eid in 0..executors {
            let (pool, pool_size, idx) = if eid < half {
                (heavy_total, half, eid)
            } else {
                (light_total, executors - half, eid - half)
            };
            let base = pool / pool_size;
            let extra = pool % pool_size;
            out.push(base + usize::from(idx < extra));
        }
        out
    };

    let mut all_latencies: Vec<f64> = Vec::new();
    let mut api_calls = 0u64;
    let mut cache_hits = 0u64;
    let mut makespan: f64 = 0.0;
    let mut total_wait = 0.0;
    let mut total_busy = 0.0;

    let mut root_rng = Rng::new(p.seed);
    for eid in 0..executors {
        let n_local = n_per_executor[eid];
        if n_local == 0 {
            continue;
        }
        let mut rng = root_rng.fork(eid as u64);
        // Share of the global budget: static 1/E split (Algorithm 1), or
        // demand-proportional when adaptive redistribution is on (the
        // steady state the RateCoordinator converges to).
        let share = if p.adaptive_shares {
            (n_local as f64 / p.n_examples.max(1) as f64).max(1e-9)
        } else {
            1.0 / executors as f64
        };
        // Small initial fill: endpoints don't grant a fresh client a full
        // minute of burst, and Figure 2 reports steady-state throughput.
        let mut bucket = TokenBucket::with_fill(
            (p.global_rpm * share).max(1e-9),
            (p.global_tpm * share).max(1e-9),
            1.0 / 60.0,
            &NullClock,
        );

        let mut slots: BinaryHeap<Slot> = BinaryHeap::new();
        // Executor-local cursor: when the dispatcher is free.
        let mut t = p.startup_secs;
        let mut done_t = p.startup_secs;
        let mut issued_in_batch = 0usize;

        for _ in 0..n_local {
            // Per-batch scheduling overhead.
            if issued_in_batch == p.batch_size {
                t += p.per_batch_overhead_secs;
                issued_in_batch = 0;
            }
            issued_in_batch += 1;

            if rng.chance(p.cache_hit_rate) {
                cache_hits += 1;
                t += p.local_ms / 1000.0;
                done_t = done_t.max(t);
                continue;
            }

            // Wait for a concurrency slot.
            if slots.len() >= p.concurrency.max(1) {
                let Slot(free_at) = slots.pop().unwrap();
                t = t.max(free_at);
            }
            // Admission through the rate limiter (virtual time).
            let admission = bucket.acquire_at(p.tokens_per_request, t);
            t = admission;
            // Latency draw.
            let mu = (p.latency_p50_ms / 1000.0).ln();
            let latency = rng.lognormal(mu, p.latency_sigma);
            all_latencies.push(latency * 1000.0);
            api_calls += 1;
            let completion = admission + latency;
            slots.push(Slot(completion));
            done_t = done_t.max(completion);
        }
        makespan = makespan.max(done_t);
        total_wait += bucket.total_wait;
        total_busy += done_t - p.startup_secs;
    }

    let total_secs = makespan.max(p.startup_secs + 1e-9);
    let cost = profile
        .map(|m| m.workload_cost(api_calls as usize, p.input_tokens, p.output_tokens).2)
        .unwrap_or(0.0);
    let (p50, p99) = if all_latencies.is_empty() {
        (0.0, 0.0)
    } else {
        all_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (quantile_sorted(&all_latencies, 0.5), quantile_sorted(&all_latencies, 0.99))
    };

    SimOutcome {
        total_secs,
        throughput_per_min: p.n_examples as f64 / total_secs * 60.0,
        latency_p50_ms: p50,
        latency_p99_ms: p99,
        api_calls,
        cache_hits,
        cost_usd: cost,
        rate_wait_frac: if total_busy > 0.0 { (total_wait / total_busy).min(1.0) } else { 0.0 },
    }
}

/// Sequential single-thread baseline (paper §5.2): one request at a time,
/// no concurrency — throughput limited by round-trip latency.
pub fn simulate_sequential(params: &SimParams) -> SimOutcome {
    let mut p = params.clone();
    p.executors = 1;
    p.concurrency = 1;
    p.startup_secs = 0.0;
    p.per_batch_overhead_secs = 0.0;
    simulate(&p, None)
}

/// Stub clock for bucket construction (the DES drives time explicitly).
struct NullClock;

impl crate::ratelimit::Clock for NullClock {
    fn now(&self) -> f64 {
        0.0
    }

    fn sleep(&self, _secs: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::providers::pricing::lookup;

    #[test]
    fn single_executor_latency_bound() {
        // 1 executor × concurrency 8 @ ~346ms mean → ≈ 1,200–1,400/min,
        // far below the 10k RPM budget (latency-bound region of Fig 2).
        let p = SimParams { executors: 1, n_examples: 3000, ..Default::default() };
        let out = simulate(&p, None);
        assert!(
            (900.0..1800.0).contains(&out.throughput_per_min),
            "throughput {}",
            out.throughput_per_min
        );
        assert!(out.rate_wait_frac < 0.05, "should not be rate-limited");
    }

    #[test]
    fn plateau_at_global_rate_limit() {
        // 16 executors would do ~19k/min unconstrained; the 10k RPM budget
        // caps near 10k (paper: 9,800/min plateau).
        let p = SimParams { executors: 16, n_examples: 40_000, ..Default::default() };
        let out = simulate(&p, None);
        assert!(
            (8_500.0..10_200.0).contains(&out.throughput_per_min),
            "throughput {}",
            out.throughput_per_min
        );
        assert!(out.rate_wait_frac > 0.2, "rate limit should bind: {}", out.rate_wait_frac);
    }

    #[test]
    fn scaling_is_monotone_then_saturates() {
        let mut last = 0.0;
        let mut tp = Vec::new();
        for executors in [1, 2, 4, 8, 16] {
            let p = SimParams { executors, n_examples: 20_000, ..Default::default() };
            let out = simulate(&p, None);
            assert!(out.throughput_per_min > last * 0.95, "monotone-ish");
            last = out.throughput_per_min;
            tp.push(out.throughput_per_min);
        }
        // Near-linear from 1→4 executors.
        assert!(tp[2] > tp[0] * 3.0, "1→4 executors should ~4x: {tp:?}");
        // Saturation: 8→16 gains little.
        assert!(tp[4] < tp[3] * 1.35, "8→16 should saturate: {tp:?}");
    }

    #[test]
    fn small_jobs_pay_scheduling_overhead() {
        let small = simulate(&SimParams { n_examples: 1_000, ..Default::default() }, None);
        let large = simulate(&SimParams { n_examples: 50_000, ..Default::default() }, None);
        assert!(
            small.throughput_per_min < large.throughput_per_min,
            "small {} large {}",
            small.throughput_per_min,
            large.throughput_per_min
        );
    }

    #[test]
    fn cache_hits_accelerate_and_zero_cost() {
        let warm = simulate(
            &SimParams { cache_hit_rate: 1.0, n_examples: 50_000, ..Default::default() },
            lookup("openai", "gpt-4o"),
        );
        assert_eq!(warm.api_calls, 0);
        assert_eq!(warm.cost_usd, 0.0);
        assert_eq!(warm.cache_hits, 50_000);
        let cold = simulate(
            &SimParams { n_examples: 50_000, ..Default::default() },
            lookup("openai", "gpt-4o"),
        );
        assert!(warm.total_secs < cold.total_secs / 5.0);
        assert!(cold.cost_usd > 50.0, "cost {}", cold.cost_usd);
    }

    #[test]
    fn sequential_baseline_much_slower() {
        // Paper §5.2: sequential ≈ 450/min (round-trip bound).
        let p = SimParams { n_examples: 2_000, ..Default::default() };
        let seq = simulate_sequential(&p);
        assert!(
            (120.0..500.0).contains(&seq.throughput_per_min),
            "sequential {}",
            seq.throughput_per_min
        );
        let dist = simulate(&SimParams { n_examples: 20_000, ..Default::default() }, None);
        let speedup = dist.throughput_per_min / seq.throughput_per_min;
        assert!(speedup > 10.0, "speedup {speedup}");
    }

    #[test]
    fn adaptive_shares_help_under_skew() {
        // 80% of examples on half the executors; rate limit binding.
        let base = SimParams {
            executors: 8,
            n_examples: 60_000,
            skew: 0.8,
            global_rpm: 6_000.0,
            ..Default::default()
        };
        let static_split = simulate(&base, None);
        let adaptive = simulate(&SimParams { adaptive_shares: true, ..base.clone() }, None);
        assert!(
            adaptive.total_secs < static_split.total_secs * 0.92,
            "adaptive {:.1}s vs static {:.1}s",
            adaptive.total_secs,
            static_split.total_secs
        );
        // Balanced load: adaptive ≈ static (no harm).
        let balanced = SimParams { skew: 0.5, ..base };
        let s = simulate(&balanced, None);
        let a = simulate(&SimParams { adaptive_shares: true, ..balanced }, None);
        assert!((s.total_secs - a.total_secs).abs() < s.total_secs * 0.05);
    }

    #[test]
    fn skew_conserves_examples() {
        for skew in [0.5, 0.7, 0.95] {
            let p = SimParams { executors: 7, n_examples: 9_999, skew, ..Default::default() };
            let out = simulate(&p, None);
            assert_eq!(out.api_calls + out.cache_hits, 9_999, "skew {skew}");
        }
    }

    #[test]
    fn deterministic() {
        let p = SimParams::default();
        let a = simulate(&p, None);
        let b = simulate(&p, None);
        assert_eq!(a.total_secs, b.total_secs);
        assert_eq!(a.api_calls, b.api_calls);
    }

    #[test]
    fn latency_percentiles_sane() {
        let out = simulate(&SimParams::default(), None);
        assert!(out.latency_p50_ms > 200.0 && out.latency_p50_ms < 500.0);
        assert!(out.latency_p99_ms > out.latency_p50_ms * 1.5);
    }
}
