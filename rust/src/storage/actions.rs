//! Delta transaction-protocol actions (paper §3.2: "cache backed by Delta
//! Lake").
//!
//! Every commit file under `_delta_log/` is newline-delimited JSON, one
//! action object per line, each wrapped in a single-key envelope naming the
//! action type — exactly the shape the Delta reference implementations
//! parse:
//!
//! ```text
//! {"protocol":{"minReaderVersion":1,"minWriterVersion":2}}
//! {"metaData":{"id":"...","schemaString":"...","partitionValues":...}}
//! {"add":{"path":"data/part-...jsonl.gz","stats":"{\"numRecords\":12,...}"}}
//! {"remove":{"path":"...","deletionTimestamp":1700000000000,...}}
//! {"commitInfo":{"operation":"OPTIMIZE","operationMetrics":{...}}}
//! ```
//!
//! Field names are the spec's camelCase, timestamps are epoch milliseconds,
//! and `stats` is a JSON *string* embedding `numRecords`/`minValues`/
//! `maxValues`/`nullCount` — the per-file index that data skipping reads.
//! Unknown envelope keys (`txn`, `cdc`, ...) are skipped on parse so logs
//! written by richer engines still replay.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Reader/writer feature gates we emit. minReaderVersion 1 / minWriterVersion
/// 2 is the plain append/remove protocol every Delta client supports.
pub const MIN_READER_VERSION: u64 = 1;
pub const MIN_WRITER_VERSION: u64 = 2;

/// `{"protocol": ...}` — the feature-gate action, first line of commit 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Protocol {
    pub min_reader_version: u64,
    pub min_writer_version: u64,
}

impl Protocol {
    pub fn current() -> Protocol {
        Protocol { min_reader_version: MIN_READER_VERSION, min_writer_version: MIN_WRITER_VERSION }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("minReaderVersion", Json::num(self.min_reader_version as f64)),
            ("minWriterVersion", Json::num(self.min_writer_version as f64)),
        ])
    }

    fn from_json(v: &Json) -> Protocol {
        Protocol {
            min_reader_version: v.f64_or("minReaderVersion", 1.0) as u64,
            min_writer_version: v.f64_or("minWriterVersion", 2.0) as u64,
        }
    }
}

/// `{"metaData": ...}` — table identity, schema, and configuration.
///
/// `schema_string` is a Spark `StructType` JSON document (the spec stores it
/// pre-serialized, as a string field). `configuration` carries the
/// `slleval.statsColumns` key so reopening the table recovers which columns
/// its files are indexed on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MetaData {
    pub id: String,
    pub name: String,
    pub schema_string: String,
    pub partition_columns: Vec<String>,
    pub configuration: BTreeMap<String, String>,
    pub created_time_ms: u64,
}

impl MetaData {
    /// Columns this table computes per-file stats over, from configuration.
    pub fn stats_columns(&self) -> Vec<String> {
        self.configuration
            .get("slleval.statsColumns")
            .map(|s| s.split(',').filter(|c| !c.is_empty()).map(String::from).collect())
            .unwrap_or_default()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("name", Json::str(&self.name)),
            (
                "format",
                Json::obj(vec![
                    ("provider", Json::str("jsonl")),
                    ("options", Json::obj(vec![("compression", Json::str("gzip"))])),
                ]),
            ),
            ("schemaString", Json::str(&self.schema_string)),
            (
                "partitionColumns",
                Json::arr(self.partition_columns.iter().map(Json::str).collect()),
            ),
            (
                "configuration",
                Json::Obj(
                    self.configuration
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v)))
                        .collect(),
                ),
            ),
            ("createdTime", Json::num(self.created_time_ms as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<MetaData> {
        let configuration = match v.opt("configuration") {
            Some(Json::Obj(o)) => o
                .iter()
                .map(|(k, val)| (k.clone(), val.as_str().unwrap_or("").to_string()))
                .collect(),
            _ => BTreeMap::new(),
        };
        Ok(MetaData {
            id: v.str_or("id", "").to_string(),
            name: v.str_or("name", "").to_string(),
            schema_string: v.str_or("schemaString", "").to_string(),
            partition_columns: match v.opt("partitionColumns") {
                Some(Json::Arr(a)) => {
                    a.iter().filter_map(|c| c.as_str().ok().map(String::from)).collect()
                }
                _ => Vec::new(),
            },
            configuration,
            created_time_ms: v.f64_or("createdTime", 0.0) as u64,
        })
    }
}

/// Per-file column statistics, serialized into `add.stats` as a JSON string.
///
/// This is the data-skipping index: a lookup for key `k` on column `c` can
/// skip any file where `k < minValues[c]` or `k > maxValues[c]`.
#[derive(Debug, Clone, PartialEq)]
pub struct FileStats {
    pub num_records: u64,
    pub min_values: BTreeMap<String, Json>,
    pub max_values: BTreeMap<String, Json>,
    pub null_count: BTreeMap<String, u64>,
}

/// Total order over the Json scalars stats track: numbers numerically,
/// strings lexicographically. Mixed/other types are incomparable (None) —
/// the caller then widens the file's range to "may contain anything".
fn scalar_cmp(a: &Json, b: &Json) -> Option<std::cmp::Ordering> {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => x.partial_cmp(y),
        (Json::Str(x), Json::Str(y)) => Some(x.as_str().cmp(y.as_str())),
        _ => None,
    }
}

impl FileStats {
    /// Compute stats for `rows` over `columns`. A column whose values are
    /// not consistently comparable scalars gets a null count but no
    /// min/max (so skipping treats the file as a candidate for it).
    pub fn compute(rows: &[Json], columns: &[String]) -> FileStats {
        let mut stats = FileStats {
            num_records: rows.len() as u64,
            min_values: BTreeMap::new(),
            max_values: BTreeMap::new(),
            null_count: BTreeMap::new(),
        };
        for col in columns {
            let mut nulls = 0u64;
            let mut min: Option<Json> = None;
            let mut max: Option<Json> = None;
            let mut comparable = true;
            for row in rows {
                let val = match row.opt(col) {
                    None | Some(Json::Null) => {
                        nulls += 1;
                        continue;
                    }
                    Some(v) => v,
                };
                match &min {
                    None => {
                        min = Some(val.clone());
                        max = Some(val.clone());
                        comparable = matches!(val, Json::Num(_) | Json::Str(_));
                    }
                    Some(m) => {
                        let hi_bound = max.as_ref().unwrap_or(m);
                        match (scalar_cmp(val, m), scalar_cmp(val, hi_bound)) {
                            (Some(lo), Some(hi)) => {
                                if lo == std::cmp::Ordering::Less {
                                    min = Some(val.clone());
                                }
                                if hi == std::cmp::Ordering::Greater {
                                    max = Some(val.clone());
                                }
                            }
                            _ => comparable = false,
                        }
                    }
                }
            }
            stats.null_count.insert(col.clone(), nulls);
            if comparable {
                if let (Some(lo), Some(hi)) = (min, max) {
                    stats.min_values.insert(col.clone(), lo);
                    stats.max_values.insert(col.clone(), hi);
                }
            }
        }
        stats
    }

    /// Can this file contain a row whose `col` equals the string `probe`?
    /// Missing stats for the column mean "maybe" — skipping must never skip
    /// a file it cannot prove empty for the probe.
    pub fn may_contain_str(&self, col: &str, probe: &str) -> bool {
        let (Some(lo), Some(hi)) = (self.min_values.get(col), self.max_values.get(col)) else {
            return true;
        };
        let (Ok(lo), Ok(hi)) = (lo.as_str(), hi.as_str()) else {
            return true;
        };
        lo <= probe && probe <= hi
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("numRecords", Json::num(self.num_records as f64)),
            ("minValues", Json::Obj(self.min_values.clone().into_iter().collect())),
            ("maxValues", Json::Obj(self.max_values.clone().into_iter().collect())),
            (
                "nullCount",
                Json::Obj(
                    self.null_count
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    /// The spec serializes stats as a JSON string inside the add action.
    pub fn to_stats_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(text: &str) -> Result<FileStats> {
        let v = Json::parse(text).context("parsing add.stats")?;
        let scalar_map = |key: &str| -> BTreeMap<String, Json> {
            match v.opt(key) {
                Some(Json::Obj(o)) => o.clone().into_iter().collect(),
                _ => BTreeMap::new(),
            }
        };
        let null_count = match v.opt("nullCount") {
            Some(Json::Obj(o)) => o
                .iter()
                .map(|(k, val)| (k.clone(), val.as_f64().unwrap_or(0.0) as u64))
                .collect(),
            _ => BTreeMap::new(),
        };
        Ok(FileStats {
            num_records: v.f64_or("numRecords", 0.0) as u64,
            min_values: scalar_map("minValues"),
            max_values: scalar_map("maxValues"),
            null_count,
        })
    }
}

/// `{"add": ...}` — a data file entering the table at this version.
#[derive(Debug, Clone, PartialEq)]
pub struct Add {
    /// Path relative to the table root, e.g. `data/part-...jsonl.gz`.
    pub path: String,
    pub size: u64,
    pub modification_time_ms: u64,
    pub data_change: bool,
    pub stats: Option<FileStats>,
}

impl Add {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("path", Json::str(&self.path)),
            ("partitionValues", Json::Obj(BTreeMap::new())),
            ("size", Json::num(self.size as f64)),
            ("modificationTime", Json::num(self.modification_time_ms as f64)),
            ("dataChange", Json::Bool(self.data_change)),
        ];
        if let Some(stats) = &self.stats {
            pairs.push(("stats", Json::str(stats.to_stats_string())));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<Add> {
        let stats = match v.opt("stats") {
            Some(Json::Str(s)) if !s.is_empty() => Some(FileStats::parse(s)?),
            _ => None,
        };
        Ok(Add {
            path: v.get("path")?.as_str()?.to_string(),
            size: v.f64_or("size", 0.0) as u64,
            modification_time_ms: v.f64_or("modificationTime", 0.0) as u64,
            data_change: v.bool_or("dataChange", true),
            stats,
        })
    }
}

/// `{"remove": ...}` — a data file leaving the table at this version. The
/// file stays on disk as a tombstone (time travel) until `vacuum` reclaims
/// it after the retention window.
#[derive(Debug, Clone, PartialEq)]
pub struct Remove {
    pub path: String,
    pub deletion_timestamp_ms: u64,
    pub data_change: bool,
    pub size: Option<u64>,
}

impl Remove {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("path", Json::str(&self.path)),
            ("deletionTimestamp", Json::num(self.deletion_timestamp_ms as f64)),
            ("dataChange", Json::Bool(self.data_change)),
        ];
        if let Some(size) = self.size {
            pairs.push(("size", Json::num(size as f64)));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<Remove> {
        Ok(Remove {
            path: v.get("path")?.as_str()?.to_string(),
            deletion_timestamp_ms: v.f64_or("deletionTimestamp", 0.0) as u64,
            data_change: v.bool_or("dataChange", true),
            size: v.opt("size").and_then(|s| s.as_f64().ok()).map(|s| s as u64),
        })
    }
}

/// `{"commitInfo": ...}` — provenance: operation name, parameters, metrics.
/// Informational in the spec (replay ignores it); `history` and the
/// maintenance commands read it back.
#[derive(Debug, Clone, PartialEq)]
pub struct CommitInfo {
    pub timestamp_ms: u64,
    pub operation: String,
    pub operation_parameters: Json,
    pub operation_metrics: Option<Json>,
}

impl CommitInfo {
    pub fn new(timestamp_ms: u64, operation: &str, parameters: Vec<(&str, Json)>) -> CommitInfo {
        CommitInfo {
            timestamp_ms,
            operation: operation.to_string(),
            operation_parameters: Json::obj(parameters),
            operation_metrics: None,
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("timestamp", Json::num(self.timestamp_ms as f64)),
            ("operation", Json::str(&self.operation)),
            ("operationParameters", self.operation_parameters.clone()),
        ];
        if let Some(metrics) = &self.operation_metrics {
            pairs.push(("operationMetrics", metrics.clone()));
        }
        pairs.push(("engineInfo", Json::str("slleval")));
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> CommitInfo {
        CommitInfo {
            timestamp_ms: v.f64_or("timestamp", 0.0) as u64,
            operation: v.str_or("operation", "").to_string(),
            operation_parameters: v
                .opt("operationParameters")
                .cloned()
                .unwrap_or_else(|| Json::Obj(BTreeMap::new())),
            operation_metrics: v.opt("operationMetrics").cloned(),
        }
    }
}

/// One line of a `_delta_log` file.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    Protocol(Protocol),
    MetaData(MetaData),
    Add(Add),
    Remove(Remove),
    CommitInfo(CommitInfo),
}

impl Action {
    /// The single-key envelope form, serialized compact (one line).
    pub fn to_line(&self) -> String {
        let (key, body) = match self {
            Action::Protocol(p) => ("protocol", p.to_json()),
            Action::MetaData(m) => ("metaData", m.to_json()),
            Action::Add(a) => ("add", a.to_json()),
            Action::Remove(r) => ("remove", r.to_json()),
            Action::CommitInfo(c) => ("commitInfo", c.to_json()),
        };
        Json::obj(vec![(key, body)]).to_string()
    }

    /// Parse one log line. Unknown envelope keys return `Ok(None)` so logs
    /// with `txn`/`cdc`/checkpoint-only actions written by other engines
    /// still replay; a malformed line is a hard error.
    pub fn parse_line(line: &str) -> Result<Option<Action>> {
        let v = Json::parse(line).context("parsing _delta_log line")?;
        let obj = v.as_obj().context("_delta_log line is not an object")?;
        let Some((key, body)) = obj.iter().next() else {
            bail!("_delta_log line is an empty object");
        };
        if obj.len() != 1 {
            bail!("_delta_log line must wrap exactly one action, got {}", obj.len());
        }
        Ok(match key.as_str() {
            "protocol" => Some(Action::Protocol(Protocol::from_json(body))),
            "metaData" => Some(Action::MetaData(MetaData::from_json(body)?)),
            "add" => Some(Action::Add(Add::from_json(body)?)),
            "remove" => Some(Action::Remove(Remove::from_json(body)?)),
            "commitInfo" => Some(Action::CommitInfo(CommitInfo::from_json(body))),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_rows() -> Vec<Json> {
        vec![
            Json::obj(vec![("k", Json::str("banana")), ("n", Json::num(3.0))]),
            Json::obj(vec![("k", Json::str("apple")), ("n", Json::num(7.0))]),
            Json::obj(vec![("k", Json::str("cherry")), ("n", Json::Null)]),
        ]
    }

    #[test]
    fn stats_compute_min_max_null() {
        let s = FileStats::compute(&sample_rows(), &["k".into(), "n".into(), "missing".into()]);
        assert_eq!(s.num_records, 3);
        assert_eq!(s.min_values["k"].as_str().unwrap(), "apple");
        assert_eq!(s.max_values["k"].as_str().unwrap(), "cherry");
        assert_eq!(s.min_values["n"].as_f64().unwrap(), 3.0);
        assert_eq!(s.max_values["n"].as_f64().unwrap(), 7.0);
        assert_eq!(s.null_count["n"], 1);
        assert_eq!(s.null_count["missing"], 3);
        assert!(!s.min_values.contains_key("missing"));
    }

    #[test]
    fn stats_round_trip_through_string() {
        let s = FileStats::compute(&sample_rows(), &["k".into(), "n".into()]);
        let parsed = FileStats::parse(&s.to_stats_string()).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn may_contain_respects_range_and_absence() {
        let s = FileStats::compute(&sample_rows(), &["k".into()]);
        assert!(s.may_contain_str("k", "apple"));
        assert!(s.may_contain_str("k", "baobab"));
        assert!(!s.may_contain_str("k", "aardvark"));
        assert!(!s.may_contain_str("k", "durian"));
        // No stats for the column ⇒ must be a candidate.
        assert!(s.may_contain_str("unindexed", "anything"));
    }

    #[test]
    fn action_lines_use_spec_field_names() {
        let add = Action::Add(Add {
            path: "data/part-0.jsonl.gz".into(),
            size: 128,
            modification_time_ms: 1_700_000_000_000,
            data_change: true,
            stats: Some(FileStats::compute(&sample_rows(), &["k".into()])),
        });
        let line = add.to_line();
        for field in [
            "\"add\":",
            "\"partitionValues\":{}",
            "\"modificationTime\":1700000000000",
            "\"dataChange\":true",
            "\"stats\":\"{",
        ] {
            assert!(line.contains(field), "{field} missing from {line}");
        }
        assert!(!line.contains('\n'));
        let proto = Action::Protocol(Protocol::current()).to_line();
        assert_eq!(proto, "{\"protocol\":{\"minReaderVersion\":1,\"minWriterVersion\":2}}");
        let remove = Action::Remove(Remove {
            path: "data/old.jsonl.gz".into(),
            deletion_timestamp_ms: 1_700_000_000_001,
            data_change: true,
            size: Some(64),
        })
        .to_line();
        assert!(remove.contains("\"deletionTimestamp\":1700000000001"), "{remove}");
    }

    #[test]
    fn parse_round_trip_and_unknown_actions() {
        let actions = vec![
            Action::Protocol(Protocol::current()),
            Action::Add(Add {
                path: "data/a.jsonl.gz".into(),
                size: 10,
                modification_time_ms: 5,
                data_change: true,
                stats: None,
            }),
            Action::Remove(Remove {
                path: "data/a.jsonl.gz".into(),
                deletion_timestamp_ms: 9,
                data_change: true,
                size: None,
            }),
            Action::CommitInfo(CommitInfo::new(7, "WRITE", vec![("mode", Json::str("Append"))])),
        ];
        for a in &actions {
            let back = Action::parse_line(&a.to_line()).unwrap().unwrap();
            assert_eq!(&back, a);
        }
        // Foreign engines may write txn/cdc actions: skipped, not fatal.
        assert!(Action::parse_line("{\"txn\":{\"appId\":\"x\",\"version\":1}}").unwrap().is_none());
        assert!(Action::parse_line("not json").is_err());
    }
}
