//! One-way migration from the legacy "deltalite" private log format.
//!
//! Before this subsystem, cache tables kept their transaction log in
//! `_log/<version %08d>.json` files holding flat `add`/`remove` filename
//! arrays — a format no external tool could read. [`migrate_legacy_log`]
//! (invoked by every `DeltaTable::open`) detects such a table, replays the
//! old log to its live file set, and republishes that state as `_delta_log`
//! commit 0 — protocol, metaData, and one stats-bearing `add` per live file.
//! Data files are NOT rewritten: the old `data/` files are referenced
//! as-is, so migration costs one read pass (for stats) and no data IO.
//!
//! The migration is one-way and collapses history: old versions predate
//! the new log, so time travel starts at the migrated commit 0. The legacy
//! log is renamed to `_log.migrated` (kept for forensics), and because the
//! rename happens only *after* commit 0 is durable, a crash mid-migration
//! re-runs it idempotently on the next open; a concurrent open racing on
//! commit 0 loses the link-claim and treats the table as migrated.

use super::actions::{Action, Add, CommitInfo, FileStats};
use super::delta::{is_commit_conflict, DeltaTable};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeSet;

/// Migrate `root` if it holds a legacy `_log/` table and no `_delta_log`
/// commits yet. Returns the number of rows migrated, None when there was
/// nothing to migrate.
pub(crate) fn migrate_legacy_log(table: &DeltaTable) -> Result<Option<u64>> {
    let legacy_dir = table.root().join("_log");
    if !legacy_dir.is_dir() || table.current_version()?.is_some() {
        return Ok(None);
    }

    // Replay the legacy log: removes then adds per commit, version order.
    let mut versions: Vec<u64> = Vec::new();
    for entry in std::fs::read_dir(&legacy_dir)? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(stem) = name.strip_suffix(".json") {
            if let Ok(v) = stem.parse::<u64>() {
                versions.push(v);
            }
        }
    }
    if versions.is_empty() {
        return Ok(None);
    }
    versions.sort_unstable();
    let mut live: BTreeSet<String> = BTreeSet::new();
    for v in versions {
        let path = legacy_dir.join(format!("{v:08}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading legacy commit {path:?}"))?;
        let commit = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        for r in commit.get("remove")?.as_arr()? {
            live.remove(r.as_str()?);
        }
        for a in commit.get("add")?.as_arr()? {
            live.insert(a.as_str()?.to_string());
        }
    }

    // One stats-bearing add per live file; rows read once for stats and
    // schema inference, files left untouched.
    let cols = table.effective_stats_columns(None);
    let mut adds = Vec::new();
    let mut all_rows = Vec::new();
    let now = table.now_ms();
    for name in &live {
        let rel = format!("data/{name}");
        let rows = table
            .read_file(&rel)
            .with_context(|| format!("reading legacy data file {rel} during migration"))?;
        let size = std::fs::metadata(table.root().join(&rel))?.len();
        adds.push(Add {
            path: rel,
            size,
            modification_time_ms: now,
            data_change: true,
            stats: Some(FileStats::compute(&rows, &cols)),
        });
        all_rows.extend(rows);
    }
    let num_rows = all_rows.len() as u64;

    let mut actions = table.creation_actions(&all_rows, &cols);
    let num_files = adds.len();
    actions.extend(adds.into_iter().map(Action::Add));
    let mut info =
        CommitInfo::new(now, "MIGRATE", vec![("source", Json::str("deltalite-log-v0"))]);
    info.operation_metrics = Some(Json::obj(vec![
        ("numFiles", Json::str(format!("{num_files}"))),
        ("numRows", Json::str(format!("{num_rows}"))),
    ]));
    actions.push(Action::CommitInfo(info));

    match table.commit(0, &actions) {
        Ok(_) => {}
        // Another process migrated the same table first: its commit 0 is
        // equivalent (same live set), ours is discarded.
        Err(e) if is_commit_conflict(&e) => {}
        Err(e) => return Err(e),
    }
    // Only after commit 0 is durable: retire the legacy log so the next
    // open skips migration. Best-effort — a failed rename just means one
    // redundant (conflicting, harmless) migration attempt later.
    let _ = std::fs::rename(&legacy_dir, table.root().join("_log.migrated"));
    Ok(Some(num_rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use flate2::write::GzEncoder;
    use flate2::Compression;
    use std::io::Write;
    use std::path::Path;

    fn write_legacy_data_file(root: &Path, name: &str, rows: &[Json]) {
        let file = std::fs::File::create(root.join("data").join(name)).unwrap();
        let mut enc = GzEncoder::new(file, Compression::fast());
        for row in rows {
            writeln!(enc, "{row}").unwrap();
        }
        enc.finish().unwrap();
    }

    fn write_legacy_commit(root: &Path, version: u64, adds: &[&str], removes: &[&str]) {
        let entry = Json::obj(vec![
            ("version", Json::num(version as f64)),
            ("op", Json::str("append")),
            ("timestamp", Json::num(1.0)),
            ("add", Json::arr(adds.iter().map(|a| Json::str(*a)).collect())),
            ("remove", Json::arr(removes.iter().map(|r| Json::str(*r)).collect())),
        ]);
        std::fs::write(root.join("_log").join(format!("{version:08}.json")), entry.to_pretty())
            .unwrap();
    }

    fn row(k: &str, v: f64) -> Json {
        Json::obj(vec![("key", Json::str(k)), ("value", Json::num(v))])
    }

    /// A legacy table: v0 adds two files, v1 upserts (removes one file,
    /// adds its rewrite) — exactly the shape deltalite wrote.
    fn legacy_table(name: &str) -> std::path::PathBuf {
        let root = std::env::temp_dir()
            .join("slleval-migrate-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("_log")).unwrap();
        std::fs::create_dir_all(root.join("data")).unwrap();
        write_legacy_data_file(&root, "00000000-0000-1-0.jsonl.gz", &[row("a", 1.0)]);
        write_legacy_data_file(&root, "00000000-0001-1-1.jsonl.gz", &[row("b", 2.0)]);
        write_legacy_commit(
            &root,
            0,
            &["00000000-0000-1-0.jsonl.gz", "00000000-0001-1-1.jsonl.gz"],
            &[],
        );
        write_legacy_data_file(&root, "00000001-0000-1-2.jsonl.gz", &[row("a", 9.0)]);
        write_legacy_commit(
            &root,
            1,
            &["00000001-0000-1-2.jsonl.gz"],
            &["00000000-0000-1-0.jsonl.gz"],
        );
        root
    }

    #[test]
    fn migrates_legacy_table_to_v0_commit() {
        let root = legacy_table("basic");
        let t = DeltaTable::open_with_stats(&root, &["key"]).unwrap();
        // The migrated table reports exactly the legacy live rows.
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(snap["b"].get("value").unwrap().as_f64().unwrap(), 2.0);
        // Spec-shaped v0: protocol + metaData + stats-bearing adds.
        assert_eq!(t.current_version().unwrap(), Some(0));
        let state = t.state(None).unwrap().unwrap();
        assert!(state.metadata.is_some());
        assert_eq!(state.files.len(), 2);
        for f in &state.files {
            let stats = f.stats.as_ref().expect("migrated adds carry stats");
            assert_eq!(stats.num_records, 1);
            assert!(stats.min_values.contains_key("key"));
        }
        // Legacy log retired, data files untouched in place.
        assert!(root.join("_log.migrated").is_dir());
        assert!(!root.join("_log").exists());
        assert!(root.join("data/00000001-0000-1-2.jsonl.gz").exists());
        // History shows the migration provenance.
        let ops: Vec<String> = t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert_eq!(ops, vec!["MIGRATE"]);
    }

    #[test]
    fn reopen_after_migration_is_stable() {
        let root = legacy_table("reopen");
        let first = DeltaTable::open_with_stats(&root, &["key"]).unwrap();
        let snap1 = first.snapshot_by_key("key", None).unwrap();
        drop(first);
        let again = DeltaTable::open_with_stats(&root, &["key"]).unwrap();
        assert_eq!(again.current_version().unwrap(), Some(0), "no second migration commit");
        assert_eq!(again.snapshot_by_key("key", None).unwrap(), snap1);
        // And the table keeps working as a normal Delta table afterwards.
        again.upsert(&[row("a", 100.0)], "key").unwrap();
        let snap = again.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(snap.len(), 2);
    }

    #[test]
    fn plain_new_table_is_untouched_by_migration_probe() {
        let root = std::env::temp_dir()
            .join("slleval-migrate-test")
            .join(format!("fresh-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let t = DeltaTable::open_with_stats(&root, &["key"]).unwrap();
        t.append(&[row("x", 1.0)]).unwrap();
        assert!(!root.join("_log.migrated").exists());
        assert_eq!(t.snapshot(None).unwrap().len(), 1);
    }
}
