//! Delta-protocol storage subsystem (paper §3.2: the response cache is
//! "backed by Delta Lake").
//!
//! - [`actions`] — the spec-shaped transaction-log actions: `protocol`,
//!   `metaData`, `add` (with per-file min/max/nullCount `stats`),
//!   `remove` (with `deletionTimestamp`), `commitInfo`.
//! - [`delta`] — [`delta::DeltaTable`]: `_delta_log/<version>.json`
//!   commits under the `util/fsx` link-claim scheme, log-replay
//!   snapshots, time travel, periodic log compaction, and stats-based
//!   data skipping via [`delta::TableState::candidates`].
//! - [`maintain`] — `OPTIMIZE` (bin-pack small files) and `VACUUM`
//!   (reclaim dead files), with Delta-shaped operation metrics.
//! - [`migrate`] — one-way migration of legacy deltalite `_log/` tables
//!   into a v0 `_delta_log` commit, run transparently on open.
//!
//! Because the log is the real Delta transaction protocol, external
//! readers (Spark, delta-rs, or the stdlib-only `python/read_delta_log.py`
//! interop checker in CI) can replay our cache tables directly.

pub mod actions;
pub mod delta;
pub mod maintain;
pub mod migrate;

pub use actions::{Action, Add, CommitInfo, FileStats, MetaData, Protocol, Remove};
pub use delta::{is_commit_conflict, DeltaTable, FileMeta, TableState, DEFAULT_STATS_COLUMNS};
pub use maintain::{optimize, vacuum, OptimizeOutcome, VacuumOutcome, DEFAULT_RETAIN_HOURS};
