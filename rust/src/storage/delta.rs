//! Delta-protocol table: `_delta_log` commits, stats-indexed data files,
//! log-replay snapshots, and periodic log compaction.
//!
//! On-disk layout (readable by any Delta log replayer; data files are
//! gzip JSONL, declared as such in `metaData.format`):
//!
//! ```text
//! <table>/
//!   _delta_log/00000000000000000000.json     commit 0: protocol, metaData,
//!   _delta_log/00000000000000000001.json       add/remove/commitInfo actions
//!   _delta_log/00000000000000000000.00000000000000000015.compacted.json
//!   data/part-<version>-<part>-<writer>.jsonl.gz
//! ```
//!
//! Commits are claimed with [`crate::util::fsx::publish_exclusive`] —
//! `link(2)` first-writer-wins — so exactly one of any number of racing
//! writers owns each version and losers get a retryable "commit conflict"
//! (the TOCTOU discipline the checkpoint store also uses). Every
//! [`LOG_COMPACT_EVERY`] commits the writer additionally publishes a
//! `<start>.<end>.compacted.json` file holding the folded state of that
//! commit range (protocol + metaData + live adds + still-relevant remove
//! tombstones), so opening a 10k-commit table replays one compacted file
//! plus at most [`LOG_COMPACT_EVERY`] tail commits instead of 10k files.
//! Commit files themselves are never deleted (they serve time travel and
//! `history`); compaction only short-circuits replay.

use super::actions::{Action, Add, CommitInfo, FileStats, MetaData, Protocol, Remove};
use crate::util::fsx::{self, Publish};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;
use sha2::{Digest, Sha256};
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

/// Columns the response cache indexes per-file stats on: the content
/// address (skipping key), the model (semantic-cache rebuild scoping), and
/// the write time (freshness diagnostics).
pub const DEFAULT_STATS_COLUMNS: &[&str] = &["prompt_hash", "model_name", "created_at"];

/// A compacted log file is published after every commit whose version is
/// the last of a block this long.
pub const LOG_COMPACT_EVERY: u64 = 16;

/// Does `err` denote a commit conflict — a writer losing the optimistic-
/// concurrency race for its version? Callers retry these (the next attempt
/// re-reads the log and targets the next free version); any other error is
/// a real failure. The vendored `anyhow` shim has no `downcast`, so
/// conflicts travel as a message marker — this helper is the one place
/// allowed to know that.
pub fn is_commit_conflict(err: &anyhow::Error) -> bool {
    err.chain().any(|m| m.contains("commit conflict"))
}

/// A live data file in a [`TableState`], with its skipping index.
#[derive(Debug, Clone, PartialEq)]
pub struct FileMeta {
    /// Path relative to the table root (`data/part-...jsonl.gz`).
    pub path: String,
    pub size: u64,
    pub stats: Option<FileStats>,
}

impl FileMeta {
    /// Can this file contain a row with `col == probe`? Files without
    /// stats (foreign writers) are always candidates.
    pub fn may_contain_str(&self, col: &str, probe: &str) -> bool {
        self.stats.as_ref().map_or(true, |s| s.may_contain_str(col, probe))
    }
}

/// The folded table state at one version: what log replay produces.
#[derive(Debug, Clone)]
pub struct TableState {
    pub version: u64,
    pub protocol: Protocol,
    pub metadata: Option<MetaData>,
    /// Live files, path-sorted (paths embed the version, so this is also
    /// commit order — insertion order for snapshot reads).
    pub files: Vec<FileMeta>,
    /// Files removed at or before this version whose remove action is
    /// still in the replayed log (vacuum's work list).
    pub tombstones: Vec<Remove>,
}

impl TableState {
    /// Live files whose stats admit `probe` on `col`, in path order.
    pub fn candidates(&self, col: &str, probe: &str) -> Vec<&FileMeta> {
        self.files.iter().filter(|f| f.may_contain_str(col, probe)).collect()
    }

    /// Total live rows, if every live file carries stats (the one-file-
    /// per-key upsert invariant makes this the live key count too).
    pub fn num_records(&self) -> Option<u64> {
        self.files
            .iter()
            .map(|f| f.stats.as_ref().map(|s| s.num_records))
            .sum::<Option<u64>>()
    }

    /// Live bytes (log-recorded sizes; no filesystem stat calls).
    pub fn live_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.size).sum()
    }
}

/// A versioned Delta-protocol table rooted at a directory.
pub struct DeltaTable {
    root: PathBuf,
    /// Stats columns used when *creating* a table (persisted into
    /// `metaData.configuration`); an existing table's persisted choice
    /// wins on reopen.
    stats_columns: Vec<String>,
    /// Fixture hooks: when set, commit timestamps and data-file writer
    /// discriminators are pinned so the golden `_delta_log` fixture is
    /// byte-reproducible. Never set on production paths.
    pinned_clock_ms: Option<u64>,
    pinned_writer: Option<String>,
}

impl DeltaTable {
    /// Open or create the table with the cache's default stats columns.
    /// An old deltalite `_log/` table found at `root` is migrated to a v0
    /// `_delta_log` commit first (one-way; see [`super::migrate`]).
    pub fn open(root: &Path) -> Result<DeltaTable> {
        DeltaTable::open_with_stats(root, DEFAULT_STATS_COLUMNS)
    }

    /// Open or create with explicit stats columns (tables whose key column
    /// is not `prompt_hash`, e.g. tests and benches).
    pub fn open_with_stats(root: &Path, stats_columns: &[&str]) -> Result<DeltaTable> {
        std::fs::create_dir_all(root.join("_delta_log"))
            .with_context(|| format!("creating {root:?}/_delta_log"))?;
        std::fs::create_dir_all(root.join("data"))?;
        let table = DeltaTable {
            root: root.to_path_buf(),
            stats_columns: stats_columns.iter().map(|c| c.to_string()).collect(),
            pinned_clock_ms: None,
            pinned_writer: None,
        };
        super::migrate::migrate_legacy_log(&table)?;
        Ok(table)
    }

    /// Pin the clock and writer discriminator for byte-reproducible
    /// fixtures. Test/fixture infrastructure only: pinning the writer
    /// forfeits the unique-temp-name guarantee concurrent writers rely on.
    pub fn pin_for_fixtures(&mut self, clock_ms: u64, writer: &str) {
        self.pinned_clock_ms = Some(clock_ms);
        self.pinned_writer = Some(writer.to_string());
    }

    /// The table's root directory (cache relocation, worker handoff).
    pub fn root(&self) -> &Path {
        &self.root
    }

    pub(crate) fn log_dir(&self) -> PathBuf {
        self.root.join("_delta_log")
    }

    pub(crate) fn data_dir(&self) -> PathBuf {
        self.root.join("data")
    }

    pub(crate) fn now_ms(&self) -> u64 {
        self.pinned_clock_ms.unwrap_or_else(|| (crate::util::unix_ts() * 1000.0) as u64)
    }

    fn writer_suffix(&self) -> String {
        self.pinned_writer.clone().unwrap_or_else(fsx::unique_suffix)
    }

    fn commit_path(&self, version: u64) -> PathBuf {
        self.log_dir().join(format!("{version:020}.json"))
    }

    /// One directory listing: committed versions (sorted) and compacted
    /// ranges. Temp files and foreign names parse-fail and are ignored.
    fn list_log(&self) -> Result<(Vec<u64>, Vec<(u64, u64)>)> {
        let mut commits = Vec::new();
        let mut compacted = Vec::new();
        for entry in std::fs::read_dir(self.log_dir())? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            let Some(stem) = name.strip_suffix(".json") else { continue };
            if let Some(range) = stem.strip_suffix(".compacted") {
                let parts: Vec<&str> = range.split('.').collect();
                if let [start, end] = parts[..] {
                    if let (Ok(s), Ok(e)) = (start.parse::<u64>(), end.parse::<u64>()) {
                        compacted.push((s, e));
                    }
                }
            } else if let Ok(v) = stem.parse::<u64>() {
                commits.push(v);
            }
        }
        commits.sort_unstable();
        Ok((commits, compacted))
    }

    /// Latest committed version, or None for an empty table.
    pub fn current_version(&self) -> Result<Option<u64>> {
        Ok(self.list_log()?.0.last().copied())
    }

    pub(crate) fn next_version(&self) -> Result<u64> {
        Ok(self.current_version()?.map_or(0, |v| v + 1))
    }

    fn read_actions(&self, path: &Path) -> Result<Vec<Action>> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading log file {path:?}"))?;
        let mut actions = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            if let Some(action) =
                Action::parse_line(line).with_context(|| format!("in log file {path:?}"))?
            {
                actions.push(action);
            }
        }
        Ok(actions)
    }

    /// Fold the log into the table state at `version` (None = latest).
    /// Returns None for a table with no commits. Replay starts from the
    /// newest compacted file covering `0..=e` with `e <= version`, then
    /// applies tail commits — the "don't read 10k files" path.
    pub fn state(&self, version: Option<u64>) -> Result<Option<TableState>> {
        let (commits, compacted) = self.list_log()?;
        let Some(&latest) = commits.last() else {
            return Ok(None);
        };
        let upper = match version {
            Some(v) if v > latest => bail!("version {v} does not exist (latest {latest})"),
            Some(v) => v,
            None => latest,
        };
        let mut actions = Vec::new();
        let mut start = 0u64;
        if let Some(&(s, e)) =
            compacted.iter().filter(|(s, e)| *s == 0 && *e <= upper).max_by_key(|(_, e)| *e)
        {
            let path = self.log_dir().join(format!("{s:020}.{e:020}.compacted.json"));
            actions.extend(self.read_actions(&path)?);
            start = e + 1;
        }
        for v in start..=upper {
            actions.extend(self.read_actions(&self.commit_path(v))?);
        }

        let mut protocol = Protocol::current();
        let mut metadata = None;
        let mut files: BTreeMap<String, FileMeta> = BTreeMap::new();
        let mut tombstones: BTreeMap<String, Remove> = BTreeMap::new();
        for action in actions {
            match action {
                Action::Protocol(p) => protocol = p,
                Action::MetaData(m) => metadata = Some(m),
                Action::Add(a) => {
                    tombstones.remove(&a.path);
                    files.insert(
                        a.path.clone(),
                        FileMeta { path: a.path, size: a.size, stats: a.stats },
                    );
                }
                Action::Remove(r) => {
                    files.remove(&r.path);
                    tombstones.insert(r.path.clone(), r);
                }
                Action::CommitInfo(_) => {}
            }
        }
        if protocol.min_reader_version > super::actions::MIN_READER_VERSION {
            bail!(
                "table requires reader protocol {} (this reader supports {})",
                protocol.min_reader_version,
                super::actions::MIN_READER_VERSION
            );
        }
        Ok(Some(TableState {
            version: upper,
            protocol,
            metadata,
            files: files.into_values().collect(),
            tombstones: tombstones.into_values().collect(),
        }))
    }

    /// Read one data file (path relative to the table root).
    pub fn read_file(&self, rel_path: &str) -> Result<Vec<Json>> {
        let path = self.root.join(rel_path);
        let file = std::fs::File::open(&path).with_context(|| format!("reading {path:?}"))?;
        let reader = BufReader::new(GzDecoder::new(file));
        let mut rows = Vec::new();
        for line in reader.lines() {
            let line = line?;
            if !line.trim().is_empty() {
                rows.push(Json::parse(&line)?);
            }
        }
        Ok(rows)
    }

    /// Stats columns in effect: the persisted table configuration when
    /// present, else this handle's (creation-time) choice. The first
    /// column is the table's primary key (`prompt_hash` for response
    /// caches) — the column upserts and point lookups key on.
    pub fn effective_stats_columns(&self, metadata: Option<&MetaData>) -> Vec<String> {
        match metadata.map(|m| m.stats_columns()) {
            Some(cols) if !cols.is_empty() => cols,
            _ => self.stats_columns.clone(),
        }
    }

    /// Write rows as a new `data/` file and return its add action. The
    /// name carries the version, a part index, and a per-writer
    /// discriminator so racing writers never clobber each other's files;
    /// a losing commit leaves an orphan the next vacuum reclaims.
    pub(crate) fn write_data_file(
        &self,
        version: u64,
        part: usize,
        rows: &[Json],
        stats_columns: &[String],
    ) -> Result<Add> {
        let name = format!("part-{version:020}-{part:04}-{}.jsonl.gz", self.writer_suffix());
        let path = self.data_dir().join(&name);
        let file = std::fs::File::create(&path)?;
        let mut enc = GzEncoder::new(file, Compression::fast());
        for row in rows {
            writeln!(enc, "{row}")?;
        }
        enc.finish()?;
        let size = std::fs::metadata(&path)?.len();
        Ok(Add {
            path: format!("data/{name}"),
            size,
            modification_time_ms: self.now_ms(),
            data_change: true,
            stats: Some(FileStats::compute(rows, stats_columns)),
        })
    }

    /// Commit `actions` at exactly `version` via first-writer-wins
    /// `link(2)` publication: exactly one racing writer wins the slot,
    /// losers get a hard "commit conflict". The version is computed once
    /// by the calling operation — never between naming a data file and
    /// claiming the log slot — so a commit can only reference data files
    /// written for that same version.
    pub(crate) fn commit(&self, version: u64, actions: &[Action]) -> Result<u64> {
        let mut body = String::new();
        for action in actions {
            body.push_str(&action.to_line());
            body.push('\n');
        }
        match fsx::publish_exclusive(&self.commit_path(version), body.as_bytes())? {
            Publish::Committed => {
                self.maybe_compact_log(version);
                Ok(version)
            }
            Publish::Conflict => bail!("commit conflict at version {version}"),
        }
    }

    /// After winning the last commit of a [`LOG_COMPACT_EVERY`] block,
    /// publish `0.<version>.compacted.json`: the folded state (protocol,
    /// metaData, live adds, tombstones whose files still exist on disk).
    /// Best-effort — the commit itself is already durable, and a reader
    /// that never sees a compacted file just replays more commits.
    fn maybe_compact_log(&self, version: u64) {
        if (version + 1) % LOG_COMPACT_EVERY != 0 {
            return;
        }
        let Ok(Some(state)) = self.state(Some(version)) else {
            return;
        };
        let mut body = String::new();
        body.push_str(&Action::Protocol(state.protocol).to_line());
        body.push('\n');
        if let Some(meta) = state.metadata {
            body.push_str(&Action::MetaData(meta).to_line());
            body.push('\n');
        }
        for f in state.files {
            let add = Add {
                path: f.path,
                size: f.size,
                modification_time_ms: self.now_ms(),
                data_change: false,
                stats: f.stats,
            };
            body.push_str(&Action::Add(add).to_line());
            body.push('\n');
        }
        for t in state.tombstones {
            // Tombstones for files vacuum already deleted are dropped —
            // that is what bounds compacted-file growth.
            if self.root.join(&t.path).exists() {
                body.push_str(&Action::Remove(t).to_line());
                body.push('\n');
            }
        }
        let path = self.log_dir().join(format!("{:020}.{version:020}.compacted.json", 0));
        let _ = fsx::write_atomic(&path, body.as_bytes());
    }

    /// Protocol + metaData actions for commit 0, with schema inferred
    /// from the first batch and stats columns persisted in configuration.
    pub(crate) fn creation_actions(&self, rows: &[Json], stats_columns: &[String]) -> Vec<Action> {
        let created = self.now_ms();
        let schema = infer_schema_string(rows);
        let mut hasher = Sha256::new();
        hasher.update(schema.as_bytes());
        hasher.update(created.to_le_bytes());
        hasher.update(self.writer_suffix().as_bytes());
        let digest = hasher.finalize();
        let hex: String = digest.iter().take(16).map(|b| format!("{b:02x}")).collect();
        let id = format!(
            "{}-{}-{}-{}-{}",
            &hex[0..8],
            &hex[8..12],
            &hex[12..16],
            &hex[16..20],
            &hex[20..32]
        );
        let name = self
            .root
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "slleval-table".to_string());
        let mut configuration = BTreeMap::new();
        configuration.insert("slleval.statsColumns".to_string(), stats_columns.join(","));
        vec![
            Action::Protocol(Protocol::current()),
            Action::MetaData(MetaData {
                id,
                name,
                schema_string: schema,
                partition_columns: Vec::new(),
                configuration,
                created_time_ms: created,
            }),
        ]
    }

    /// Append rows as a new version. Returns the version. A concurrent
    /// writer claiming the same version first surfaces as a
    /// "commit conflict"; retrying re-reads the log and targets the next
    /// free version.
    pub fn append(&self, rows: &[Json]) -> Result<u64> {
        let version = self.next_version()?;
        let state = self.state(None)?;
        let cols = self.effective_stats_columns(state.as_ref().and_then(|s| s.metadata.as_ref()));
        let mut actions = Vec::new();
        if version == 0 {
            actions.extend(self.creation_actions(rows, &cols));
        }
        let add = self.write_data_file(version, 0, rows, &cols)?;
        let records = rows.len() as u64;
        let bytes = add.size;
        actions.push(Action::Add(add));
        let mut info =
            CommitInfo::new(self.now_ms(), "WRITE", vec![("mode", Json::str("Append"))]);
        info.operation_metrics = Some(Json::obj(vec![
            ("numFiles", Json::str("1")),
            ("numOutputRows", Json::str(format!("{records}"))),
            ("numOutputBytes", Json::str(format!("{bytes}"))),
        ]));
        actions.push(Action::CommitInfo(info));
        self.commit(version, &actions)
    }

    /// Upsert rows keyed on `key_col`: rows with existing keys replace the
    /// old rows (files containing them are rewritten minus those rows),
    /// new keys append. Stats prune the rewrite scan: only files whose
    /// `key_col` range intersects the incoming keys are decompressed.
    pub fn upsert(&self, rows: &[Json], key_col: &str) -> Result<u64> {
        // Claim the target version *before* scanning live files: any
        // commit landing mid-rewrite makes our claim conflict instead of
        // us committing a rewrite based on a stale snapshot.
        let version = self.next_version()?;
        let new_keys: BTreeSet<String> = rows
            .iter()
            .filter_map(|r| r.opt(key_col).and_then(|k| k.as_str().ok()).map(String::from))
            .collect();
        if new_keys.len() != rows.len() {
            bail!("upsert rows must all carry a unique string '{key_col}'");
        }

        let state = self.state(None)?;
        let cols = self.effective_stats_columns(state.as_ref().and_then(|s| s.metadata.as_ref()));
        let mut removes = Vec::new();
        let mut rewritten: Vec<Json> = Vec::new();
        let deletion_ts = self.now_ms();
        if let Some(state) = &state {
            for meta in &state.files {
                if !new_keys.iter().any(|k| meta.may_contain_str(key_col, k)) {
                    continue;
                }
                let file_rows = self.read_file(&meta.path)?;
                let has_conflict = file_rows.iter().any(|r| {
                    r.opt(key_col)
                        .and_then(|k| k.as_str().ok())
                        .map(|k| new_keys.contains(k))
                        .unwrap_or(false)
                });
                if has_conflict {
                    removes.push(Remove {
                        path: meta.path.clone(),
                        deletion_timestamp_ms: deletion_ts,
                        data_change: true,
                        size: Some(meta.size),
                    });
                    rewritten.extend(file_rows.into_iter().filter(|r| {
                        r.opt(key_col)
                            .and_then(|k| k.as_str().ok())
                            .map(|k| !new_keys.contains(k))
                            .unwrap_or(true)
                    }));
                }
            }
        }

        let mut actions = Vec::new();
        if version == 0 {
            actions.extend(self.creation_actions(rows, &cols));
        }
        let mut adds = Vec::new();
        if !rewritten.is_empty() {
            adds.push(self.write_data_file(version, 1, &rewritten, &cols)?);
        }
        adds.push(self.write_data_file(version, 0, rows, &cols)?);
        let out_rows: u64 = rows.len() as u64 + rewritten.len() as u64;
        let num_removed = removes.len();
        let num_added = adds.len();
        actions.extend(adds.into_iter().map(Action::Add));
        actions.extend(removes.into_iter().map(Action::Remove));
        let mut info = CommitInfo::new(
            self.now_ms(),
            "MERGE",
            vec![("predicate", Json::str(format!("target.{key_col} = source.{key_col}")))],
        );
        info.operation_metrics = Some(Json::obj(vec![
            ("numTargetFilesAdded", Json::str(format!("{num_added}"))),
            ("numTargetFilesRemoved", Json::str(format!("{num_removed}"))),
            ("numOutputRows", Json::str(format!("{out_rows}"))),
        ]));
        actions.push(Action::CommitInfo(info));
        self.commit(version, &actions)
    }

    /// Full snapshot at `version` (None = latest): rows from all live
    /// files in path (= commit) order.
    pub fn snapshot(&self, version: Option<u64>) -> Result<Vec<Json>> {
        let mut rows = Vec::new();
        if let Some(state) = self.state(version)? {
            for f in &state.files {
                rows.extend(self.read_file(&f.path)?);
            }
        }
        Ok(rows)
    }

    /// Snapshot as a key → row map (last write wins within file order).
    pub fn snapshot_by_key(
        &self,
        key_col: &str,
        version: Option<u64>,
    ) -> Result<BTreeMap<String, Json>> {
        let mut map = BTreeMap::new();
        for row in self.snapshot(version)? {
            if let Some(k) = row.opt(key_col).and_then(|k| k.as_str().ok()) {
                map.insert(k.to_string(), row.clone());
            }
        }
        Ok(map)
    }

    /// Rewrite all live data into a single file: `optimize` with an
    /// unbounded target. Kept for the cache's legacy `compact()` surface.
    pub fn compact(&self) -> Result<u64> {
        let outcome = super::maintain::optimize(self, u64::MAX)?;
        match outcome.version {
            Some(v) => Ok(v),
            // Nothing to bin-pack (zero or one live file): report the
            // current version unchanged.
            None => Ok(self.current_version()?.unwrap_or(0)),
        }
    }

    /// Total bytes of live data files, from log-recorded sizes
    /// (storage-overhead accounting, §5.3).
    pub fn storage_bytes(&self) -> Result<u64> {
        Ok(self.state(None)?.map_or(0, |s| s.live_bytes()))
    }

    /// History of (version, operation, timestamp-seconds) from commitInfo
    /// actions, oldest first. Reads every commit file — diagnostics only.
    pub fn history(&self) -> Result<Vec<(u64, String, f64)>> {
        let (commits, _) = self.list_log()?;
        let mut out = Vec::new();
        for v in commits {
            let mut op = String::new();
            let mut ts = 0.0;
            for action in self.read_actions(&self.commit_path(v))? {
                if let Action::CommitInfo(info) = action {
                    op = info.operation;
                    ts = info.timestamp_ms as f64 / 1000.0;
                }
            }
            out.push((v, op, ts));
        }
        Ok(out)
    }
}

/// Spark `StructType` JSON for the union of columns in `rows`. Integer-
/// valued numbers are `long`, others `double` (widened on conflict);
/// non-scalar values fall back to `string` (they are stored as JSON text
/// either way). Schema is inferred once at table creation.
fn infer_schema_string(rows: &[Json]) -> String {
    let mut types: BTreeMap<String, &'static str> = BTreeMap::new();
    for row in rows {
        if let Ok(obj) = row.as_obj() {
            for (k, v) in obj {
                let t = match v {
                    Json::Str(_) => "string",
                    Json::Bool(_) => "boolean",
                    Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => "long",
                    Json::Num(_) => "double",
                    _ => "string",
                };
                let slot = types.entry(k.clone()).or_insert(t);
                if *slot != t {
                    *slot = match (*slot, t) {
                        ("long", "double") | ("double", "long") => "double",
                        _ => "string",
                    };
                }
            }
        }
    }
    let fields: Vec<Json> = types
        .into_iter()
        .map(|(name, ty)| {
            Json::obj(vec![
                ("name", Json::str(name)),
                ("type", Json::str(ty)),
                ("nullable", Json::Bool(true)),
                ("metadata", Json::Obj(BTreeMap::new())),
            ])
        })
        .collect();
    Json::obj(vec![("type", Json::str("struct")), ("fields", Json::arr(fields))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tmp_table(name: &str) -> DeltaTable {
        let dir = std::env::temp_dir()
            .join("slleval-storage-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DeltaTable::open_with_stats(&dir, &["key", "value"]).unwrap()
    }

    pub(crate) fn row(k: &str, v: f64) -> Json {
        Json::obj(vec![("key", Json::str(k)), ("value", Json::num(v))])
    }

    #[test]
    fn append_and_snapshot() {
        let t = tmp_table("append");
        assert_eq!(t.current_version().unwrap(), None);
        t.append(&[row("a", 1.0), row("b", 2.0)]).unwrap();
        t.append(&[row("c", 3.0)]).unwrap();
        assert_eq!(t.current_version().unwrap(), Some(1));
        assert_eq!(t.snapshot(None).unwrap().len(), 3);
    }

    #[test]
    fn commit_zero_declares_protocol_and_metadata() {
        let t = tmp_table("creation");
        t.append(&[row("a", 1.0)]).unwrap();
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.protocol, Protocol::current());
        let meta = state.metadata.unwrap();
        assert_eq!(meta.stats_columns(), vec!["key", "value"]);
        assert!(meta.schema_string.contains("\"name\":\"key\""));
        assert!(meta.schema_string.contains("\"type\":\"struct\""));
        assert_eq!(meta.partition_columns, Vec::<String>::new());
    }

    #[test]
    fn time_travel() {
        let t = tmp_table("timetravel");
        t.append(&[row("a", 1.0)]).unwrap(); // v0
        t.append(&[row("b", 2.0)]).unwrap(); // v1
        t.upsert(&[row("a", 99.0)], "key").unwrap(); // v2
        assert_eq!(t.snapshot(Some(0)).unwrap().len(), 1);
        assert_eq!(t.snapshot(Some(1)).unwrap().len(), 2);
        let v1 = t.snapshot_by_key("key", Some(1)).unwrap();
        assert_eq!(v1["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
        let v2 = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(v2["a"].get("value").unwrap().as_f64().unwrap(), 99.0);
        assert!(t.snapshot(Some(99)).is_err());
    }

    #[test]
    fn upsert_replaces_and_appends() {
        let t = tmp_table("upsert");
        t.append(&[row("a", 1.0), row("b", 2.0)]).unwrap();
        t.upsert(&[row("b", 20.0), row("c", 3.0)], "key").unwrap();
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap["b"].get("value").unwrap().as_f64().unwrap(), 20.0);
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn upsert_requires_unique_keys() {
        let t = tmp_table("upsert-dup");
        assert!(t.upsert(&[row("a", 1.0), row("a", 2.0)], "key").is_err());
    }

    #[test]
    fn adds_carry_stats_and_removes_carry_deletion_timestamps() {
        let t = tmp_table("actions");
        t.append(&[row("m", 1.0), row("a", 2.0), row("z", 3.0)]).unwrap();
        let state = t.state(None).unwrap().unwrap();
        let stats = state.files[0].stats.as_ref().unwrap();
        assert_eq!(stats.num_records, 3);
        assert_eq!(stats.min_values["key"].as_str().unwrap(), "a");
        assert_eq!(stats.max_values["key"].as_str().unwrap(), "z");
        t.upsert(&[row("m", 9.0)], "key").unwrap();
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.tombstones.len(), 1);
        assert!(state.tombstones[0].deletion_timestamp_ms > 0);
        assert_eq!(state.num_records(), Some(3));
    }

    #[test]
    fn candidates_prune_by_key_range() {
        let t = tmp_table("candidates");
        t.append(&[row("a", 1.0), row("c", 2.0)]).unwrap();
        t.append(&[row("m", 3.0), row("p", 4.0)]).unwrap();
        t.append(&[row("x", 5.0), row("z", 6.0)]).unwrap();
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.files.len(), 3);
        assert_eq!(state.candidates("key", "n").len(), 1);
        assert_eq!(state.candidates("key", "a").len(), 1);
        // Out of every range: no candidates at all.
        assert_eq!(state.candidates("key", "zz").len(), 0);
        // Unindexed column: every file is a candidate.
        assert_eq!(state.candidates("other", "q").len(), 3);
    }

    #[test]
    fn log_compaction_short_circuits_replay() {
        let t = tmp_table("logcompact");
        let total = LOG_COMPACT_EVERY + 4;
        for i in 0..total {
            t.append(&[row(&format!("k{i:03}"), i as f64)]).unwrap();
        }
        let compacted =
            t.log_dir().join(format!("{:020}.{:020}.compacted.json", 0, LOG_COMPACT_EVERY - 1));
        assert!(compacted.exists(), "compacted log file must be published");
        // Deleting the compacted range's commit files proves replay uses
        // the compacted file (this is what external log cleanup would do).
        for v in 0..LOG_COMPACT_EVERY {
            std::fs::remove_file(t.commit_path(v)).unwrap();
        }
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), total as usize);
        // Metadata survives compaction too.
        let state = t.state(None).unwrap().unwrap();
        assert!(state.metadata.is_some());
    }

    #[test]
    fn same_version_commit_conflicts_hard() {
        let t = tmp_table("conflict");
        t.append(&[row("a", 1.0)]).unwrap(); // claims version 0
        // A stale writer that still believes version 0 is free must get a
        // hard conflict, not silently clobber the committed entry.
        let add = t
            .write_data_file(0, 0, &[row("stale", 9.0)], &["key".to_string()])
            .unwrap();
        let err = t.commit(0, &[Action::Add(add)]).unwrap_err();
        assert!(is_commit_conflict(&err), "{err:#}");
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
        assert!(!snap.contains_key("stale"));
    }

    #[test]
    fn two_racing_writers_exactly_one_wins_each_version() {
        let dir = std::env::temp_dir()
            .join("slleval-storage-test")
            .join(format!("race-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DeltaTable::open_with_stats(&dir, &["key"]).unwrap();

        const PER_WRITER: usize = 12;
        let committed: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|w| {
                    let dir = dir.clone();
                    scope.spawn(move || {
                        // Each writer has its own table handle (two
                        // processes in miniature) and retries conflicts.
                        let t = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
                        let mut versions = Vec::new();
                        for i in 0..PER_WRITER {
                            let r = [row(&format!("w{w}-{i}"), i as f64)];
                            loop {
                                match t.append(&r) {
                                    Ok(v) => {
                                        versions.push(v);
                                        break;
                                    }
                                    Err(e) => {
                                        assert!(
                                            is_commit_conflict(&e),
                                            "only conflicts are expected: {e:#}"
                                        );
                                    }
                                }
                            }
                        }
                        versions
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

        let mut versions = committed;
        versions.sort_unstable();
        let expected: Vec<u64> = (0..2 * PER_WRITER as u64).collect();
        assert_eq!(versions, expected, "each version must have exactly one winner");

        let t = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
        assert_eq!(t.current_version().unwrap(), Some(2 * PER_WRITER as u64 - 1));
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), 2 * PER_WRITER);
        let ops: Vec<String> =
            t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert!(ops.iter().all(|op| op == "WRITE"), "{ops:?}");
    }

    #[test]
    fn reopen_sees_committed_state() {
        let dir = std::env::temp_dir()
            .join("slleval-storage-test")
            .join(format!("reopen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let t = DeltaTable::open_with_stats(&dir, &["key"]).unwrap();
            t.append(&[row("a", 1.0)]).unwrap();
        }
        // Reopening with different creation-time stats columns must not
        // matter: the persisted configuration wins.
        let t2 = DeltaTable::open(&dir).unwrap();
        assert_eq!(t2.snapshot(None).unwrap().len(), 1);
        t2.append(&[row("b", 2.0)]).unwrap();
        let state = t2.state(None).unwrap().unwrap();
        let newest = state.files.iter().max_by_key(|f| f.path.clone()).unwrap();
        let stats = newest.stats.as_ref().unwrap();
        assert!(stats.min_values.contains_key("key"), "persisted stats columns must win");
    }

    #[test]
    fn history_records_operations() {
        let t = tmp_table("history");
        t.append(&[row("a", 1.0)]).unwrap();
        t.upsert(&[row("a", 2.0)], "key").unwrap();
        t.compact().unwrap();
        let ops: Vec<String> = t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert_eq!(ops, vec!["WRITE", "MERGE", "OPTIMIZE"]);
    }

    #[test]
    fn storage_bytes_positive_and_shrinks_on_compact() {
        let t = tmp_table("storage");
        for i in 0..10 {
            let rows: Vec<Json> = (0..20).map(|j| row(&format!("k{i}-{j}"), j as f64)).collect();
            t.append(&rows).unwrap();
        }
        let before = t.storage_bytes().unwrap();
        assert!(before > 0);
        t.compact().unwrap();
        let after = t.storage_bytes().unwrap();
        assert!(after <= before, "compaction must not grow live storage");
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.files.len(), 1, "compact folds everything into one file");
        // Old snapshots stay readable after compaction (time travel).
        assert_eq!(t.snapshot(Some(2)).unwrap().len(), 60);
    }
}
