//! Table maintenance: `OPTIMIZE` (range-cluster small files) and `VACUUM`
//! (reclaim dead files), with Delta-shaped operation metrics.
//!
//! Optimize does not merely concatenate: it sorts the rewritten rows by
//! the table's first stats column (the primary key — `prompt_hash` for
//! response caches) and splits them into `target_bytes` files. Freshly
//! flushed files each span nearly the whole key space (content-address
//! keys are uniform), so their min/max stats prune nothing; after
//! clustering, file ranges are narrow and disjoint and stats-based data
//! skipping answers a point lookup from one file. This is the same reason
//! Delta pairs OPTIMIZE with Z-ordering.
//!
//! Safety under concurrent writers:
//!
//! - **optimize** claims its version before scanning, rewrites only files
//!   live at that scan, and publishes adds+removes in ONE commit under the
//!   link-claim scheme — a concurrent append/upsert that wins the version
//!   first turns the whole optimize into a retryable "commit conflict";
//!   nothing was deleted, nothing is lost.
//! - **vacuum** only ever deletes two classes of file: (a) *tombstoned*
//!   files — paths with a `remove` action in the log. Data-file names are
//!   never reused (they embed version + writer discriminator), so a
//!   tombstoned path can never become live again: deleting it past the
//!   retention window is always safe, it only forfeits time travel to
//!   versions older than the remove. (b) *orphans* — files no log entry
//!   references (losers of commit races, crashed writers, fsx temp
//!   litter). An orphan might be an in-flight writer's data file whose
//!   commit has not landed yet, so orphans are only deleted once older
//!   than `max(retention, ORPHAN_GRACE_MS)`; a writer that takes an hour
//!   between writing a data file and committing it has lost the race in
//!   any case (its commit conflicts and retries with a fresh file).

use super::actions::{Action, CommitInfo, Remove};
use super::delta::{is_commit_conflict, DeltaTable, FileMeta};
use crate::util::json::Json;
use anyhow::{bail, Result};

/// Default vacuum retention, matching Delta's 7-day default.
pub const DEFAULT_RETAIN_HOURS: f64 = 168.0;

/// Orphaned (never-referenced) files younger than this are never deleted,
/// regardless of retention: they may belong to an in-flight commit.
pub const ORPHAN_GRACE_MS: u64 = 3_600_000;

/// Default optimize target file size.
pub const DEFAULT_TARGET_BYTES: u64 = 64 * 1024 * 1024;

/// `DeltaOperationMetricsOptimize`: the metrics object embedded in the
/// OPTIMIZE commitInfo (and printed by `slleval cache optimize`).
/// `filesAdded`/`filesRemoved` are JSON strings holding a size histogram,
/// as Spark emits them.
#[derive(Debug, Clone, Default)]
pub struct OptimizeMetrics {
    pub added_sizes: Vec<u64>,
    pub removed_sizes: Vec<u64>,
    pub num_batches: u64,
    pub total_considered_files: u64,
    pub total_files_skipped: u64,
}

fn size_histogram(sizes: &[u64]) -> String {
    let total: u64 = sizes.iter().sum();
    let n = sizes.len() as u64;
    Json::obj(vec![
        ("avg", Json::num(if n == 0 { 0.0 } else { total as f64 / n as f64 })),
        ("max", Json::num(sizes.iter().max().copied().unwrap_or(0) as f64)),
        ("min", Json::num(sizes.iter().min().copied().unwrap_or(0) as f64)),
        ("totalFiles", Json::num(n as f64)),
        ("totalSize", Json::num(total as f64)),
    ])
    .to_string()
}

impl OptimizeMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("filesAdded", Json::str(size_histogram(&self.added_sizes))),
            ("filesRemoved", Json::str(size_histogram(&self.removed_sizes))),
            ("numBatches", Json::num(self.num_batches as f64)),
            ("numFilesAdded", Json::num(self.added_sizes.len() as f64)),
            ("numFilesRemoved", Json::num(self.removed_sizes.len() as f64)),
            ("partitionsOptimized", Json::num(0.0)),
            // Rows are re-sorted by the cluster column, like Z-ordered
            // OPTIMIZE in Spark.
            ("preserveInsertionOrder", Json::Bool(false)),
            ("totalConsideredFiles", Json::num(self.total_considered_files as f64)),
            ("totalFilesSkipped", Json::num(self.total_files_skipped as f64)),
        ])
    }
}

/// Result of an optimize pass. `version` is None when nothing needed
/// rewriting (no commit was made).
#[derive(Debug)]
pub struct OptimizeOutcome {
    pub version: Option<u64>,
    pub metrics: OptimizeMetrics,
}

/// Rewrite live files smaller than `target_bytes` into range-clustered
/// files of up to `target_bytes`: rows are sorted by the table's first
/// stats column and split at the target size, and the rewrite is
/// published as one add+remove commit. A concurrent commit winning the
/// version surfaces as "commit conflict" — retry from scratch.
pub fn optimize(table: &DeltaTable, target_bytes: u64) -> Result<OptimizeOutcome> {
    // Claim the target version before scanning (same TOCTOU discipline as
    // upsert): a commit landing mid-rewrite conflicts our claim.
    let version = table.next_version()?;
    let Some(state) = table.state(None)? else {
        return Ok(OptimizeOutcome { version: None, metrics: OptimizeMetrics::default() });
    };
    let cols = table.effective_stats_columns(state.metadata.as_ref());

    let mut metrics = OptimizeMetrics {
        total_considered_files: state.files.len() as u64,
        ..OptimizeMetrics::default()
    };
    let mut small: Vec<&FileMeta> = Vec::new();
    for f in &state.files {
        if f.size >= target_bytes {
            metrics.total_files_skipped += 1;
        } else {
            small.push(f);
        }
    }
    // A lone small file is already optimal — rewriting it would churn.
    if small.len() < 2 {
        metrics.total_files_skipped += small.len() as u64;
        return Ok(OptimizeOutcome { version: None, metrics });
    }

    let deletion_ts = table.now_ms();
    let mut rows = Vec::new();
    let mut removes = Vec::new();
    for f in &small {
        rows.extend(table.read_file(&f.path)?);
        metrics.removed_sizes.push(f.size);
        removes.push(Remove {
            path: f.path.clone(),
            deletion_timestamp_ms: deletion_ts,
            data_change: false,
            size: Some(f.size),
        });
    }
    // Cluster on the primary stats column so output file ranges are
    // narrow and disjoint; stable sort keeps insertion order within ties.
    if let Some(cluster_col) = cols.first() {
        rows.sort_by(|a, b| {
            let ka = a.opt(cluster_col).and_then(|v| v.as_str().ok()).unwrap_or("");
            let kb = b.opt(cluster_col).and_then(|v| v.as_str().ok()).unwrap_or("");
            ka.cmp(kb)
        });
    }
    // Split at target size, estimated from the uncompressed JSONL bytes
    // (the gzip container stores deflate blocks uncompressed, so the
    // on-disk size tracks this within a few header bytes per file).
    let mut chunks: Vec<Vec<Json>> = Vec::new();
    let mut chunk: Vec<Json> = Vec::new();
    let mut chunk_bytes = 0u64;
    for row in rows {
        let row_bytes = row.to_string().len() as u64 + 1;
        if !chunk.is_empty() && chunk_bytes.saturating_add(row_bytes) > target_bytes {
            chunks.push(std::mem::take(&mut chunk));
            chunk_bytes = 0;
        }
        chunk.push(row);
        chunk_bytes = chunk_bytes.saturating_add(row_bytes);
    }
    if !chunk.is_empty() {
        chunks.push(chunk);
    }

    let mut actions = Vec::new();
    metrics.num_batches = chunks.len() as u64;
    for (part, chunk) in chunks.iter().enumerate() {
        let add = table.write_data_file(version, part, chunk, &cols)?;
        metrics.added_sizes.push(add.size);
        actions.push(Action::Add(super::actions::Add { data_change: false, ..add }));
    }
    actions.extend(removes.into_iter().map(Action::Remove));
    let mut info = CommitInfo::new(
        table.now_ms(),
        "OPTIMIZE",
        vec![("targetSize", Json::str(format!("{target_bytes}")))],
    );
    info.operation_metrics = Some(metrics.to_json());
    actions.push(Action::CommitInfo(info));
    let version = table.commit(version, &actions)?;
    Ok(OptimizeOutcome { version: Some(version), metrics })
}

/// Result of a vacuum pass.
#[derive(Debug)]
pub struct VacuumOutcome {
    pub dry_run: bool,
    /// (table-relative path, size) of every file eligible for deletion.
    pub to_delete: Vec<(String, u64)>,
    /// Files actually unlinked (0 on dry runs).
    pub deleted_files: u64,
    pub reclaimed_bytes: u64,
}

impl VacuumOutcome {
    /// `DeltaOperationMetricsVacuumStart` shape.
    pub fn start_metrics(&self) -> Json {
        let bytes: u64 = self.to_delete.iter().map(|(_, s)| s).sum();
        Json::obj(vec![
            ("numFilesToDelete", Json::str(format!("{}", self.to_delete.len()))),
            ("sizeOfDataToDelete", Json::str(format!("{bytes}"))),
        ])
    }

    /// `DeltaOperationMetricsVacuumEnd` shape.
    pub fn end_metrics(&self) -> Json {
        Json::obj(vec![
            ("numDeletedFiles", Json::str(format!("{}", self.deleted_files))),
            ("numVacuumedDirectories", Json::str("0")),
        ])
    }
}

/// Delete dead data files older than the retention window. Writes
/// `VACUUM START` / `VACUUM END` commits (with Delta-shaped metrics)
/// around the deletions unless `dry_run` or nothing qualifies. Retention
/// below the table's time-travel needs trades old snapshots for space —
/// exactly Delta's own vacuum contract.
pub fn vacuum(table: &DeltaTable, retain_ms: u64, dry_run: bool) -> Result<VacuumOutcome> {
    let now = table.now_ms();
    let state = table.state(None)?;
    let mut live = std::collections::BTreeSet::new();
    let mut tombstones = std::collections::BTreeMap::new();
    if let Some(state) = &state {
        for f in &state.files {
            live.insert(f.path.clone());
        }
        for t in &state.tombstones {
            tombstones.insert(t.path.clone(), t.deletion_timestamp_ms);
        }
    }

    let mut outcome =
        VacuumOutcome { dry_run, to_delete: Vec::new(), deleted_files: 0, reclaimed_bytes: 0 };
    for entry in std::fs::read_dir(table.data_dir())? {
        let entry = entry?;
        if !entry.file_type()?.is_file() {
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        let rel = format!("data/{name}");
        if live.contains(&rel) {
            continue;
        }
        let meta = entry.metadata()?;
        let eligible = match tombstones.get(&rel) {
            // Tombstoned: the path can never become live again (names are
            // never reused), so age it from its deletionTimestamp.
            Some(deleted_at) => now.saturating_sub(*deleted_at) >= retain_ms,
            // Orphan: possibly an in-flight commit's data file — grace
            // period applies on top of retention.
            None => {
                let age_ms = file_age_ms(&meta, now);
                age_ms >= retain_ms.max(ORPHAN_GRACE_MS)
            }
        };
        if eligible {
            outcome.to_delete.push((rel, meta.len()));
        }
    }
    outcome.to_delete.sort();
    if dry_run || outcome.to_delete.is_empty() {
        return Ok(outcome);
    }

    // Bracket the deletions with START/END commits when the log exists
    // (an uninitialized table has no protocol action to follow, and a
    // commitInfo-only commit 0 would be spec-invalid).
    let log_exists = state.is_some();
    if log_exists {
        commit_info_only(table, "VACUUM START", outcome.start_metrics())?;
    }
    for (rel, size) in &outcome.to_delete {
        if std::fs::remove_file(table.root().join(rel)).is_ok() {
            outcome.deleted_files += 1;
            outcome.reclaimed_bytes += size;
        }
    }
    if log_exists {
        commit_info_only(table, "VACUUM END", outcome.end_metrics())?;
    }
    Ok(outcome)
}

/// Age of a file from its mtime. The wall clock is the right clock here:
/// vacuum reasons about real elapsed time for foreign writers, not the
/// virtual evaluation clock.
fn file_age_ms(meta: &std::fs::Metadata, now_ms: u64) -> u64 {
    let mtime_ms = meta
        .modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| d.as_millis() as u64)
        .unwrap_or(now_ms);
    now_ms.saturating_sub(mtime_ms)
}

/// Publish a commitInfo-only commit, retrying version conflicts: racing
/// appends can keep claiming versions ahead of us, but each retry targets
/// the next free slot, so this terminates unless the table is under
/// pathological sustained write pressure.
fn commit_info_only(table: &DeltaTable, operation: &str, metrics: Json) -> Result<u64> {
    for _ in 0..64 {
        let version = table.next_version()?;
        let mut info = CommitInfo::new(table.now_ms(), operation, vec![]);
        info.operation_metrics = Some(metrics.clone());
        match table.commit(version, &[Action::CommitInfo(info)]) {
            Ok(v) => return Ok(v),
            Err(e) if is_commit_conflict(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    bail!("{operation} could not claim a log version after 64 attempts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_table(name: &str) -> DeltaTable {
        let dir = std::env::temp_dir()
            .join("slleval-maintain-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DeltaTable::open_with_stats(&dir, &["key"]).unwrap()
    }

    fn row(k: &str, v: f64) -> Json {
        Json::obj(vec![("key", Json::str(k)), ("value", Json::num(v))])
    }

    #[test]
    fn optimize_binpacks_small_files_into_one_commit() {
        let t = tmp_table("optimize");
        for i in 0..6 {
            t.append(&[row(&format!("k{i}"), i as f64)]).unwrap();
        }
        let before = t.snapshot_by_key("key", None).unwrap();
        let outcome = optimize(&t, u64::MAX).unwrap();
        assert!(outcome.version.is_some());
        assert_eq!(outcome.metrics.removed_sizes.len(), 6);
        assert_eq!(outcome.metrics.added_sizes.len(), 1);
        assert_eq!(outcome.metrics.num_batches, 1);
        assert_eq!(outcome.metrics.total_considered_files, 6);
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.files.len(), 1);
        assert_eq!(t.snapshot_by_key("key", None).unwrap(), before);
        // Metrics land in the commitInfo, histogram fields as JSON strings.
        let (_, op, _) = t.history().unwrap().into_iter().last().unwrap();
        assert_eq!(op, "OPTIMIZE");
        let parsed = Json::parse(&size_histogram(&outcome.metrics.added_sizes)).unwrap();
        assert_eq!(parsed.f64_or("totalFiles", 0.0), 1.0);
    }

    #[test]
    fn optimize_skips_files_at_or_above_target() {
        let t = tmp_table("optimize-skip");
        let big: Vec<Json> = (0..200).map(|i| row(&format!("big{i:04}"), i as f64)).collect();
        t.append(&big).unwrap();
        t.append(&[row("s1", 1.0)]).unwrap();
        t.append(&[row("s2", 2.0)]).unwrap();
        let big_size = t.state(None).unwrap().unwrap().files.iter().map(|f| f.size).max().unwrap();
        let outcome = optimize(&t, big_size).unwrap();
        assert!(outcome.version.is_some());
        assert_eq!(outcome.metrics.total_files_skipped, 1, "large file left alone");
        assert_eq!(outcome.metrics.removed_sizes.len(), 2);
        assert_eq!(t.state(None).unwrap().unwrap().files.len(), 2);
    }

    #[test]
    fn optimize_range_clusters_rows_for_skipping() {
        let t = tmp_table("optimize-cluster");
        // Four files whose key ranges all overlap: stats prune nothing.
        t.append(&[row("a", 1.0), row("z", 2.0)]).unwrap();
        t.append(&[row("b", 3.0), row("y", 4.0)]).unwrap();
        t.append(&[row("c", 5.0), row("x", 6.0)]).unwrap();
        t.append(&[row("d", 7.0), row("w", 8.0)]).unwrap();
        let before = t.snapshot_by_key("key", None).unwrap();
        let pre = t.state(None).unwrap().unwrap();
        assert_eq!(pre.candidates("key", "a").len(), 4, "unclustered: every file matches");

        // A target around half the table splits the sorted rows in two.
        let outcome = optimize(&t, 100).unwrap();
        assert!(outcome.version.is_some());
        assert_eq!(outcome.metrics.num_batches, 2);
        let state = t.state(None).unwrap().unwrap();
        assert_eq!(state.files.len(), 2);
        // Clustered: point lookups hit exactly one file, and probes
        // between the two ranges hit none.
        assert_eq!(state.candidates("key", "a").len(), 1);
        assert_eq!(state.candidates("key", "z").len(), 1);
        assert_ne!(
            state.candidates("key", "a")[0].path,
            state.candidates("key", "z")[0].path
        );
        assert_eq!(state.candidates("key", "m").len(), 0);
        assert_eq!(t.snapshot_by_key("key", None).unwrap(), before);
    }

    #[test]
    fn optimize_without_packable_files_commits_nothing() {
        let t = tmp_table("optimize-noop");
        t.append(&[row("a", 1.0)]).unwrap();
        let v_before = t.current_version().unwrap();
        let outcome = optimize(&t, u64::MAX).unwrap();
        assert!(outcome.version.is_none());
        assert_eq!(outcome.metrics.total_files_skipped, 1);
        assert_eq!(t.current_version().unwrap(), v_before);
    }

    #[test]
    fn vacuum_dry_run_deletes_nothing() {
        let t = tmp_table("vacuum-dry");
        t.append(&[row("a", 1.0)]).unwrap();
        t.upsert(&[row("a", 2.0)], "key").unwrap(); // tombstones v0's file
        let v_before = t.current_version().unwrap();
        let outcome = vacuum(&t, 0, true).unwrap();
        assert_eq!(outcome.to_delete.len(), 1);
        assert_eq!(outcome.deleted_files, 0);
        assert_eq!(t.current_version().unwrap(), v_before, "dry run must not commit");
        let dead = t.root().join(&outcome.to_delete[0].0);
        assert!(dead.exists());
    }

    #[test]
    fn vacuum_respects_retention_then_reclaims() {
        let t = tmp_table("vacuum-retention");
        t.append(&[row("a", 1.0)]).unwrap();
        t.upsert(&[row("a", 2.0)], "key").unwrap();
        // Retention far in the future: the fresh tombstone survives.
        let kept = vacuum(&t, u64::MAX, false).unwrap();
        assert_eq!(kept.to_delete.len(), 0);
        // Retention zero: the tombstoned file goes; live data unaffected.
        let before = t.snapshot_by_key("key", None).unwrap();
        let outcome = vacuum(&t, 0, false).unwrap();
        assert_eq!(outcome.deleted_files, 1);
        assert!(outcome.reclaimed_bytes > 0);
        assert_eq!(t.snapshot_by_key("key", None).unwrap(), before);
        // START/END commits with metrics are in the history.
        let ops: Vec<String> = t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert_eq!(ops[ops.len() - 2..], ["VACUUM START".to_string(), "VACUUM END".to_string()]);
        assert_eq!(outcome.start_metrics().str_or("numFilesToDelete", ""), "1");
        assert_eq!(outcome.end_metrics().str_or("numDeletedFiles", ""), "1");
    }

    #[test]
    fn vacuum_protects_fresh_orphans() {
        let t = tmp_table("vacuum-orphan");
        t.append(&[row("a", 1.0)]).unwrap();
        // An in-flight writer's data file: referenced by no commit yet.
        let orphan = t.data_dir().join("part-inflight-0000.jsonl.gz");
        std::fs::write(&orphan, b"not yet committed").unwrap();
        let outcome = vacuum(&t, 0, false).unwrap();
        assert_eq!(outcome.to_delete.len(), 0, "fresh orphan is inside the grace window");
        assert!(orphan.exists());
    }

    #[test]
    fn vacuum_forfeits_time_travel_past_retention() {
        let t = tmp_table("vacuum-tt");
        t.append(&[row("a", 1.0)]).unwrap(); // v0
        t.upsert(&[row("a", 2.0)], "key").unwrap(); // v1 rewrites v0's file
        assert_eq!(t.snapshot(Some(0)).unwrap().len(), 1);
        vacuum(&t, 0, false).unwrap();
        // v0's data file is gone: time travel to v0 now errors (documented
        // Delta semantics of sub-retention vacuums).
        assert!(t.snapshot(Some(0)).is_err());
        assert_eq!(t.snapshot(None).unwrap().len(), 1);
    }
}
