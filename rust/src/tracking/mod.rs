//! MLflow-style experiment tracking (paper §A.5).
//!
//! File-backed run store:
//!
//! ```text
//! <root>/<run_id>/
//!   meta.json        run id, name, timestamps, status
//!   params.json      full configuration (nested)
//!   metrics.json     metric values incl. ci_lower / ci_upper companions
//!   tags.json        model name, provider, task id, ...
//!   artifacts/       raw results (JSONL), config file, anything else
//! ```

use crate::config::EvalTask;
use crate::coordinator::EvalResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A tracking store rooted at a directory.
pub struct TrackingStore {
    root: PathBuf,
}

/// One active run.
pub struct Run {
    pub run_id: String,
    dir: PathBuf,
    metrics: BTreeMap<String, f64>,
    params: BTreeMap<String, Json>,
    tags: BTreeMap<String, String>,
}

impl TrackingStore {
    pub fn open(root: &Path) -> Result<TrackingStore> {
        std::fs::create_dir_all(root)?;
        Ok(TrackingStore { root: root.to_path_buf() })
    }

    /// Start a run with a unique id derived from the name + timestamp.
    pub fn start_run(&self, name: &str) -> Result<Run> {
        let ts = crate::util::unix_ts();
        let mut run_id = format!("{name}-{}", ts as u64);
        let mut n = 0;
        while self.root.join(&run_id).exists() {
            n += 1;
            run_id = format!("{name}-{}-{n}", ts as u64);
        }
        let dir = self.root.join(&run_id);
        std::fs::create_dir_all(dir.join("artifacts"))?;
        let meta = Json::obj(vec![
            ("run_id", Json::str(&run_id)),
            ("name", Json::str(name)),
            ("start_time", Json::num(ts)),
            ("status", Json::str("RUNNING")),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_pretty())?;
        Ok(Run {
            run_id,
            dir,
            metrics: BTreeMap::new(),
            params: BTreeMap::new(),
            tags: BTreeMap::new(),
        })
    }

    /// List run ids (newest last by name ordering).
    pub fn list_runs(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            if entry.path().join("meta.json").exists() {
                out.push(entry.file_name().to_string_lossy().to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    /// Load a run's metrics.json.
    pub fn load_metrics(&self, run_id: &str) -> Result<BTreeMap<String, f64>> {
        let path = self.root.join(run_id).join("metrics.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("{path:?}"))?;
        let v = Json::parse(&text)?;
        let mut out = BTreeMap::new();
        for (k, val) in v.as_obj()? {
            out.insert(k.clone(), val.as_f64()?);
        }
        Ok(out)
    }
}

impl Run {
    pub fn log_param(&mut self, key: &str, value: Json) {
        self.params.insert(key.to_string(), value);
    }

    pub fn log_metric(&mut self, key: &str, value: f64) {
        self.metrics.insert(key.to_string(), value);
    }

    pub fn set_tag(&mut self, key: &str, value: &str) {
        self.tags.insert(key.to_string(), value.to_string());
    }

    /// Log everything the paper's integration logs for one evaluation:
    /// params (full config), metrics with CI bounds, tags, and the raw
    /// result JSON as an artifact.
    pub fn log_evaluation(&mut self, task: &EvalTask, result: &EvalResult) -> Result<()> {
        self.log_param("config", task.to_json());
        for m in &result.metrics {
            self.log_metric(&m.name, m.value);
            self.log_metric(&format!("{}_ci_lower", m.name), m.ci.lo);
            self.log_metric(&format!("{}_ci_upper", m.name), m.ci.hi);
            self.log_metric(&format!("{}_n", m.name), m.n as f64);
        }
        self.log_metric("throughput_per_min", result.inference.throughput_per_min);
        self.log_metric("total_cost_usd", result.inference.total_cost_usd);
        self.log_metric("cache_hit_rate", {
            let h = result.inference.cache_hits as f64;
            let t = (result.inference.cache_hits + result.inference.cache_misses) as f64;
            if t > 0.0 {
                h / t
            } else {
                0.0
            }
        });
        self.set_tag("model", &result.model);
        self.set_tag("provider", &result.provider);
        self.set_tag("task_id", &result.task_id);
        self.log_artifact_text("result.json", &result.to_json().to_pretty())?;
        self.log_artifact_text("config.json", &task.to_json().to_pretty())?;
        Ok(())
    }

    /// Write a text artifact into the run's artifact directory.
    pub fn log_artifact_text(&self, name: &str, content: &str) -> Result<PathBuf> {
        let path = self.dir.join("artifacts").join(name);
        std::fs::write(&path, content)?;
        Ok(path)
    }

    /// Persist params/metrics/tags and mark the run finished.
    pub fn finish(self) -> Result<()> {
        std::fs::write(
            self.dir.join("params.json"),
            Json::Obj(self.params.clone()).to_pretty(),
        )?;
        let metrics_json: BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        std::fs::write(self.dir.join("metrics.json"), Json::Obj(metrics_json).to_pretty())?;
        let tags_json: BTreeMap<String, Json> = self
            .tags
            .iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect();
        std::fs::write(self.dir.join("tags.json"), Json::Obj(tags_json).to_pretty())?;
        // Update meta status.
        let meta_path = self.dir.join("meta.json");
        let meta = Json::parse(&std::fs::read_to_string(&meta_path)?)?;
        let mut obj = meta.as_obj()?.clone();
        obj.insert("status".into(), Json::str("FINISHED"));
        obj.insert("end_time".into(), Json::num(crate::util::unix_ts()));
        std::fs::write(meta_path, Json::Obj(obj).to_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> TrackingStore {
        let dir = std::env::temp_dir()
            .join("slleval-tracking")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        TrackingStore::open(&dir).unwrap()
    }

    #[test]
    fn run_lifecycle() {
        let store = tmp_store("lifecycle");
        let mut run = store.start_run("exp").unwrap();
        run.log_metric("accuracy", 0.8);
        run.log_metric("accuracy_ci_lower", 0.75);
        run.set_tag("model", "gpt-4o");
        run.log_param("n", Json::num(100.0));
        let id = run.run_id.clone();
        run.finish().unwrap();

        assert_eq!(store.list_runs().unwrap(), vec![id.clone()]);
        let metrics = store.load_metrics(&id).unwrap();
        assert_eq!(metrics["accuracy"], 0.8);
        assert_eq!(metrics["accuracy_ci_lower"], 0.75);
    }

    #[test]
    fn unique_run_ids() {
        let store = tmp_store("unique");
        let a = store.start_run("same").unwrap();
        let b = store.start_run("same").unwrap();
        assert_ne!(a.run_id, b.run_id);
    }

    #[test]
    fn artifacts_written() {
        let store = tmp_store("artifacts");
        let run = store.start_run("art").unwrap();
        let path = run.log_artifact_text("note.txt", "hello").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "hello");
    }
}
