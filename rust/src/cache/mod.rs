//! Content-addressable response caching backed by the Delta-protocol
//! storage subsystem (paper §3.2).
//!
//! Cache key: `SHA256(prompt || model || provider || temperature ||
//! max_tokens)` — exact-match on the full inference configuration. Entries
//! follow the Table 1 schema. Policies: Enabled / ReadOnly / WriteOnly /
//! Replay / Disabled.
//!
//! Lookups are lazy with stats-based data skipping: instead of replaying
//! the whole table into memory at open (O(files) decompressions before
//! the first hit), a probe consults the per-file min/max `stats` on
//! `prompt_hash` from the `_delta_log` and decompresses only files whose
//! range can contain the key — O(candidate files), with each decompressed
//! file memoized for later probes. `slleval cache optimize` range-clusters
//! data files on `prompt_hash`, which is what makes those ranges narrow.

pub mod semantic;

use crate::config::CachePolicy;
use crate::providers::InferenceResponse;
use crate::storage::delta::{DeltaTable, TableState};
use crate::storage::{is_commit_conflict, maintain};
use crate::util::json::Json;
use anyhow::{bail, Result};
use sha2::{Digest, Sha256};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Deterministic cache key (paper §3.2).
pub fn cache_key(
    prompt: &str,
    model: &str,
    provider: &str,
    temperature: f64,
    max_tokens: usize,
) -> String {
    let mut h = Sha256::new();
    h.update(prompt.as_bytes());
    h.update(b"||");
    h.update(model.as_bytes());
    h.update(b"||");
    h.update(provider.as_bytes());
    h.update(b"||");
    h.update(format!("{temperature:.6}").as_bytes());
    h.update(b"||");
    h.update(format!("{max_tokens}").as_bytes());
    format!("{:x}", h.finalize())
}

/// One cache entry (Table 1 schema).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub prompt_hash: String,
    pub model_name: String,
    pub provider: String,
    pub prompt_text: String,
    pub response_text: String,
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub latency_ms: f64,
    pub created_at: f64,
    pub ttl_days: Option<f64>,
}

impl CacheEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prompt_hash", Json::str(&self.prompt_hash)),
            ("model_name", Json::str(&self.model_name)),
            ("provider", Json::str(&self.provider)),
            ("prompt_text", Json::str(&self.prompt_text)),
            ("response_text", Json::str(&self.response_text)),
            ("input_tokens", Json::num(self.input_tokens as f64)),
            ("output_tokens", Json::num(self.output_tokens as f64)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("created_at", Json::num(self.created_at)),
            (
                "ttl_days",
                self.ttl_days.map(Json::num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CacheEntry> {
        Ok(CacheEntry {
            prompt_hash: v.get("prompt_hash")?.as_str()?.to_string(),
            model_name: v.get("model_name")?.as_str()?.to_string(),
            provider: v.get("provider")?.as_str()?.to_string(),
            prompt_text: v.str_or("prompt_text", "").to_string(),
            response_text: v.get("response_text")?.as_str()?.to_string(),
            input_tokens: v.usize_or("input_tokens", 0),
            output_tokens: v.usize_or("output_tokens", 0),
            latency_ms: v.f64_or("latency_ms", 0.0),
            created_at: v.f64_or("created_at", 0.0),
            ttl_days: v.opt("ttl_days").and_then(|t| t.as_f64().ok()),
        })
    }

    /// Entry expired relative to `now` (unix seconds)?
    pub fn expired(&self, now: f64) -> bool {
        match self.ttl_days {
            Some(days) => now - self.created_at > days * 86_400.0,
            None => false,
        }
    }
}

/// Hit/miss accounting, plus the data-skipping ledger: how many live data
/// files lookups decompressed vs proved skippable from stats alone.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub expired: u64,
    /// Data files actually decompressed (each file counted once; repeat
    /// probes hit the in-memory memo).
    pub files_opened: u64,
    /// File probes answered from per-file min/max stats without
    /// decompression.
    pub files_skipped: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The response cache: Delta table + lazy skipping reader + policy.
///
/// Reads go overlay (this process's writes) → memoized files → stats-
/// filtered candidate files, newest file first. Writes buffer and flush
/// to the table in batches (one table version per flush, like the paper's
/// per-partition cache population).
pub struct ResponseCache {
    table: DeltaTable,
    policy: CachePolicy,
    /// Entries written by this process (freshest values; also serves
    /// read-your-writes before a flush).
    overlay: Mutex<BTreeMap<String, CacheEntry>>,
    /// Decompressed data files, keyed by table-relative path.
    loaded: Mutex<BTreeMap<String, Arc<BTreeMap<String, CacheEntry>>>>,
    /// Cached log replay; invalidated after our own commits. External
    /// commits made after open are picked up then too — same visibility
    /// the old open-time snapshot gave.
    state_cache: Mutex<Option<Arc<TableState>>>,
    /// Read the table at this pinned version (time travel); None = latest.
    version_pin: Option<u64>,
    /// Consult per-file stats before decompressing (`inference.
    /// cache_skipping`). Off = probe every live file, newest first.
    skipping: AtomicBool,
    pending: Mutex<Vec<CacheEntry>>,
    /// Serializes table commits from this process; see [`Self::flush`].
    commit_lock: Mutex<()>,
    stats: Mutex<CacheStats>,
    /// Default TTL for new entries.
    pub ttl_days: Option<f64>,
    /// Flush threshold (entries buffered before an automatic flush).
    pub flush_every: usize,
}

impl ResponseCache {
    pub fn open(dir: &Path, policy: CachePolicy) -> Result<ResponseCache> {
        Ok(ResponseCache {
            table: DeltaTable::open(dir)?,
            policy,
            overlay: Mutex::new(BTreeMap::new()),
            loaded: Mutex::new(BTreeMap::new()),
            state_cache: Mutex::new(None),
            version_pin: None,
            skipping: AtomicBool::new(true),
            pending: Mutex::new(Vec::new()),
            commit_lock: Mutex::new(()),
            stats: Mutex::new(CacheStats::default()),
            ttl_days: None,
            flush_every: 1000,
        })
    }

    /// Open at a historical version (time-travel reproduction of a past
    /// evaluation). Always read-only.
    pub fn open_at_version(dir: &Path, version: u64) -> Result<ResponseCache> {
        let mut cache = ResponseCache::open(dir, CachePolicy::ReadOnly)?;
        cache.version_pin = Some(version);
        // Surface a bad version at open, not on the first lookup.
        cache.table.state(Some(version))?;
        Ok(cache)
    }

    /// The backing table's directory: out-of-process executors open their
    /// own connection to the same store (commits are multi-writer safe),
    /// so the driver ships this path in task plans.
    pub fn dir(&self) -> &Path {
        self.table.root()
    }

    /// The backing Delta table (maintenance commands, diagnostics).
    pub fn table(&self) -> &DeltaTable {
        &self.table
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    /// Toggle stats-based data skipping (`inference.cache_skipping`).
    /// Lookup results are bit-identical either way; only the number of
    /// files decompressed changes.
    pub fn set_skipping(&self, enabled: bool) {
        self.skipping.store(enabled, Ordering::Relaxed);
    }

    pub fn skipping(&self) -> bool {
        self.skipping.load(Ordering::Relaxed)
    }

    /// Live distinct keys. Computed from per-file `numRecords` stats when
    /// every live file carries them (the upsert path keeps one live file
    /// per key, so rows == keys); falls back to a full scan otherwise.
    /// Flushes pending writes first so the log is the source of truth.
    pub fn len(&self) -> Result<usize> {
        if self.policy.writes() {
            self.flush()?;
        }
        let Some(state) = self.table_state()? else {
            return Ok(self.overlay.lock().unwrap().len());
        };
        if let Some(n) = state.num_records() {
            return Ok(n as usize);
        }
        let mut keys = std::collections::BTreeSet::new();
        for meta in &state.files {
            keys.extend(self.load_file(&meta.path)?.keys().cloned());
        }
        Ok(keys.len())
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Replay the log once and memoize; prune file memos that fell out of
    /// the live set (superseded by upserts/optimize).
    fn table_state(&self) -> Result<Option<Arc<TableState>>> {
        let mut guard = self.state_cache.lock().unwrap();
        if guard.is_none() {
            let state = self.table.state(self.version_pin)?.map(Arc::new);
            if let Some(state) = &state {
                let live: std::collections::BTreeSet<&String> =
                    state.files.iter().map(|f| &f.path).collect();
                self.loaded.lock().unwrap().retain(|path, _| live.contains(path));
            }
            *guard = state;
        }
        Ok(guard.clone())
    }

    /// Decompress a data file into a key → entry map, memoized. Rows that
    /// are not valid cache entries are ignored (foreign writers).
    fn load_file(&self, path: &str) -> Result<Arc<BTreeMap<String, CacheEntry>>> {
        if let Some(cached) = self.loaded.lock().unwrap().get(path) {
            return Ok(cached.clone());
        }
        let mut map = BTreeMap::new();
        for row in self.table.read_file(path)? {
            if let Ok(entry) = CacheEntry::from_json(&row) {
                map.insert(entry.prompt_hash.clone(), entry);
            }
        }
        let map = Arc::new(map);
        let mut loaded = self.loaded.lock().unwrap();
        if loaded.insert(path.to_string(), map.clone()).is_none() {
            self.stats.lock().unwrap().files_opened += 1;
        }
        Ok(map)
    }

    /// Find `key`: overlay, then live files newest-first, consulting
    /// per-file stats when skipping is on. Newest-first matches the old
    /// replay-everything semantics (last write wins) for any table where
    /// a key somehow lives in two files.
    fn lookup_key(&self, key: &str) -> Result<Option<CacheEntry>> {
        if let Some(entry) = self.overlay.lock().unwrap().get(key) {
            return Ok(Some(entry.clone()));
        }
        let Some(state) = self.table_state()? else {
            return Ok(None);
        };
        let skipping = self.skipping();
        let mut skipped = 0u64;
        let mut found = None;
        for meta in state.files.iter().rev() {
            if skipping && !meta.may_contain_str("prompt_hash", key) {
                skipped += 1;
                continue;
            }
            if let Some(entry) = self.load_file(&meta.path)?.get(key) {
                found = Some(entry.clone());
                break;
            }
        }
        self.stats.lock().unwrap().files_skipped += skipped;
        Ok(found)
    }

    /// All live entries for one model: the semantic cache's rebuild scan.
    /// Skipping prunes on the `model_name` stats column, so a multi-model
    /// table only decompresses the requested model's files.
    pub fn entries_for_model(&self, model: &str, provider: &str) -> Result<Vec<CacheEntry>> {
        let mut by_key: BTreeMap<String, CacheEntry> = BTreeMap::new();
        if let Some(state) = self.table_state()? {
            let skipping = self.skipping();
            let mut skipped = 0u64;
            for meta in &state.files {
                if skipping && !meta.may_contain_str("model_name", model) {
                    skipped += 1;
                    continue;
                }
                for entry in self.load_file(&meta.path)?.values() {
                    if entry.model_name == model && entry.provider == provider {
                        by_key.insert(entry.prompt_hash.clone(), entry.clone());
                    }
                }
            }
            self.stats.lock().unwrap().files_skipped += skipped;
        }
        for entry in self.overlay.lock().unwrap().values() {
            if entry.model_name == model && entry.provider == provider {
                by_key.insert(entry.prompt_hash.clone(), entry.clone());
            }
        }
        Ok(by_key.into_values().collect())
    }

    /// Lookup under the policy. `Replay` turns a miss into an error.
    pub fn get(
        &self,
        prompt: &str,
        model: &str,
        provider: &str,
        temperature: f64,
        max_tokens: usize,
    ) -> Result<Option<CacheEntry>> {
        if !self.policy.reads() {
            return Ok(None);
        }
        let key = cache_key(prompt, model, provider, temperature, max_tokens);
        let now = crate::util::unix_ts();
        let found = self.lookup_key(&key)?;
        let mut stats = self.stats.lock().unwrap();
        match found {
            Some(e) if e.expired(now) => {
                stats.expired += 1;
                stats.misses += 1;
                if self.policy == CachePolicy::Replay {
                    bail!("replay mode: cache entry expired for key {key}");
                }
                Ok(None)
            }
            Some(e) => {
                stats.hits += 1;
                Ok(Some(e))
            }
            None => {
                stats.misses += 1;
                if self.policy == CachePolicy::Replay {
                    bail!(
                        "replay mode: cache miss for prompt {:?}... (key {key})",
                        &prompt[..prompt.len().min(40)]
                    );
                }
                Ok(None)
            }
        }
    }

    /// Store a response under the policy (no-op for read-only policies).
    pub fn put(
        &self,
        prompt: &str,
        model: &str,
        provider: &str,
        temperature: f64,
        max_tokens: usize,
        response: &InferenceResponse,
    ) -> Result<()> {
        if !self.policy.writes() {
            return Ok(());
        }
        let key = cache_key(prompt, model, provider, temperature, max_tokens);
        let entry = CacheEntry {
            prompt_hash: key.clone(),
            model_name: model.to_string(),
            provider: provider.to_string(),
            prompt_text: prompt.to_string(),
            response_text: response.text.clone(),
            input_tokens: response.input_tokens,
            output_tokens: response.output_tokens,
            latency_ms: response.latency_ms,
            created_at: crate::util::unix_ts(),
            ttl_days: self.ttl_days,
        };
        self.overlay.lock().unwrap().insert(key, entry.clone());
        let should_flush = {
            let mut pending = self.pending.lock().unwrap();
            pending.push(entry);
            pending.len() >= self.flush_every
        };
        self.stats.lock().unwrap().writes += 1;
        if should_flush {
            self.flush()?;
        }
        Ok(())
    }

    /// Persist buffered writes as one table upsert.
    ///
    /// Commits are serialized through `commit_lock` so concurrent executor
    /// flushes from this process never race each other on a version, and
    /// commit conflicts from *other* processes sharing the table are
    /// retried with a freshly recomputed version a few times before
    /// giving up.
    pub fn flush(&self) -> Result<()> {
        let _commit_guard = self.commit_lock.lock().unwrap();
        let pending: Vec<CacheEntry> = {
            let mut p = self.pending.lock().unwrap();
            std::mem::take(&mut *p)
        };
        if pending.is_empty() {
            return Ok(());
        }
        // Deduplicate within the batch (last write wins) — upsert requires
        // unique keys.
        let mut by_key: BTreeMap<String, Json> = BTreeMap::new();
        for e in &pending {
            by_key.insert(e.prompt_hash.clone(), e.to_json());
        }
        let rows: Vec<Json> = by_key.into_values().collect();
        let mut last_err = None;
        for _ in 0..4 {
            match self.table.upsert(&rows, "prompt_hash") {
                Ok(_) => {
                    *self.state_cache.lock().unwrap() = None;
                    return Ok(());
                }
                Err(e) if is_commit_conflict(&e) => last_err = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap().context("flushing response cache"))
    }

    /// Storage footprint of live data (paper §5.3 accounting).
    pub fn storage_bytes(&self) -> Result<u64> {
        self.table.storage_bytes()
    }

    pub fn current_version(&self) -> Result<Option<u64>> {
        self.table.current_version()
    }

    /// Compact the underlying table into a single file (legacy surface;
    /// `optimize` with an unbounded target).
    pub fn compact(&self) -> Result<()> {
        self.flush()?;
        self.table.compact()?;
        *self.state_cache.lock().unwrap() = None;
        Ok(())
    }

    /// Range-cluster small live files into `target_bytes` files (the
    /// `slleval cache optimize` entry point for an open cache).
    pub fn optimize(&self, target_bytes: u64) -> Result<maintain::OptimizeOutcome> {
        self.flush()?;
        let outcome = maintain::optimize(&self.table, target_bytes)?;
        *self.state_cache.lock().unwrap() = None;
        Ok(outcome)
    }

    /// Reclaim dead data files past `retain_ms` (the `slleval cache
    /// vacuum` entry point for an open cache).
    pub fn vacuum(&self, retain_ms: u64, dry_run: bool) -> Result<maintain::VacuumOutcome> {
        let outcome = maintain::vacuum(&self.table, retain_ms, dry_run)?;
        *self.state_cache.lock().unwrap() = None;
        Ok(outcome)
    }
}

impl Drop for ResponseCache {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-cache-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn resp(text: &str) -> InferenceResponse {
        InferenceResponse {
            text: text.into(),
            input_tokens: 10,
            output_tokens: 5,
            latency_ms: 100.0,
            cost_usd: 0.001,
        }
    }

    #[test]
    fn key_sensitivity() {
        let base = cache_key("p", "m", "prov", 0.0, 100);
        assert_ne!(base, cache_key("q", "m", "prov", 0.0, 100));
        assert_ne!(base, cache_key("p", "m2", "prov", 0.0, 100));
        assert_ne!(base, cache_key("p", "m", "prov2", 0.0, 100));
        assert_ne!(base, cache_key("p", "m", "prov", 0.5, 100));
        assert_ne!(base, cache_key("p", "m", "prov", 0.0, 200));
        assert_eq!(base, cache_key("p", "m", "prov", 0.0, 100));
        assert_eq!(base.len(), 64);
    }

    #[test]
    fn get_after_put() {
        let cache = ResponseCache::open(&tmp_dir("getput"), CachePolicy::Enabled).unwrap();
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        cache.put("p", "m", "prov", 0.0, 100, &resp("hello")).unwrap();
        let hit = cache.get("p", "m", "prov", 0.0, 100).unwrap().unwrap();
        assert_eq!(hit.response_text, "hello");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 1, 1));
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = tmp_dir("persist");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("p", "m", "prov", 0.0, 100, &resp("persisted")).unwrap();
            cache.flush().unwrap();
        }
        let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        assert_eq!(cache.len().unwrap(), 1);
        let hit = cache.get("p", "m", "prov", 0.0, 100).unwrap().unwrap();
        assert_eq!(hit.response_text, "persisted");
    }

    #[test]
    fn replay_errors_on_miss() {
        let dir = tmp_dir("replay");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("known", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        }
        let cache = ResponseCache::open(&dir, CachePolicy::Replay).unwrap();
        assert!(cache.get("known", "m", "prov", 0.0, 100).unwrap().is_some());
        assert!(cache.get("unknown", "m", "prov", 0.0, 100).is_err());
        // Replay never writes.
        cache.put("new", "m", "prov", 0.0, 100, &resp("y")).unwrap();
        assert_eq!(cache.stats().writes, 0);
    }

    #[test]
    fn write_only_skips_lookup() {
        let cache = ResponseCache::open(&tmp_dir("writeonly"), CachePolicy::WriteOnly).unwrap();
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        // Lookup returns None even though the entry exists.
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        assert_eq!(cache.stats().writes, 1);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn disabled_does_nothing() {
        let cache = ResponseCache::open(&tmp_dir("disabled"), CachePolicy::Disabled).unwrap();
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes), (0, 0, 0));
    }

    #[test]
    fn ttl_expiry() {
        let dir = tmp_dir("ttl");
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.ttl_days = Some(1.0);
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        // Manually age the entry in the overlay.
        {
            let mut overlay = cache.overlay.lock().unwrap();
            for e in overlay.values_mut() {
                e.created_at -= 2.0 * 86_400.0;
            }
        }
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        assert_eq!(cache.stats().expired, 1);
    }

    #[test]
    fn time_travel_reproduces_old_state() {
        let dir = tmp_dir("timetravel");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("p", "m", "prov", 0.0, 100, &resp("v0")).unwrap();
            cache.flush().unwrap(); // version 0
            cache.put("p", "m", "prov", 0.0, 100, &resp("v1")).unwrap();
            cache.flush().unwrap(); // version 1
        }
        let old = ResponseCache::open_at_version(&dir, 0).unwrap();
        assert_eq!(
            old.get("p", "m", "prov", 0.0, 100).unwrap().unwrap().response_text,
            "v0"
        );
        let new = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        assert_eq!(
            new.get("p", "m", "prov", 0.0, 100).unwrap().unwrap().response_text,
            "v1"
        );
    }

    #[test]
    fn entry_json_round_trip() {
        let e = CacheEntry {
            prompt_hash: "abc".into(),
            model_name: "m".into(),
            provider: "p".into(),
            prompt_text: "prompt \"quoted\"".into(),
            response_text: "line1\nline2".into(),
            input_tokens: 42,
            output_tokens: 7,
            latency_ms: 123.4,
            created_at: 1000.0,
            ttl_days: Some(30.0),
        };
        let back = CacheEntry::from_json(&e.to_json()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn batch_flush_threshold() {
        let dir = tmp_dir("flush");
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.flush_every = 10;
        for i in 0..25 {
            cache.put(&format!("p{i}"), "m", "prov", 0.0, 100, &resp("x")).unwrap();
        }
        // Two automatic flushes happened (at 10 and 20); version >= 1.
        assert!(cache.current_version().unwrap() >= Some(1));
        cache.flush().unwrap();
        let reopened = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        assert_eq!(reopened.len().unwrap(), 25);
    }

    #[test]
    fn skipping_is_bit_identical_and_opens_fewer_files() {
        let dir = tmp_dir("skipping");
        let prompts: Vec<String> = (0..96).map(|i| format!("prompt-{i}")).collect();
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            for chunk in prompts.chunks(8) {
                for p in chunk {
                    cache.put(p, "m", "prov", 0.0, 100, &resp(&format!("resp:{p}"))).unwrap();
                }
                cache.flush().unwrap();
            }
            // Range-cluster into several files so hash ranges are narrow
            // (fresh flush files each span ~the whole hash space).
            let total = cache.storage_bytes().unwrap();
            cache.optimize(total / 8).unwrap();
        }

        // Bit identity over every key plus a guaranteed miss.
        let with = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        with.set_skipping(true);
        let without = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        without.set_skipping(false);
        let miss = "never-cached".to_string();
        for p in prompts.iter().chain([&miss]) {
            let a = with.get(p, "m", "prov", 0.0, 100).unwrap();
            let b = without.get(p, "m", "prov", 0.0, 100).unwrap();
            assert_eq!(a, b, "skipping must not change results for {p}");
        }
        assert_eq!(with.stats().hits, without.stats().hits);

        // A sparse probe set on fresh handles: skipping decompresses
        // strictly fewer files. (Probing every key would touch every file
        // in both modes — the memo hides the difference.)
        let sparse: Vec<&String> = prompts.iter().step_by(16).collect();
        let with = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        with.set_skipping(true);
        let without = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        without.set_skipping(false);
        for p in sparse.iter().chain([&&miss]) {
            let a = with.get(p, "m", "prov", 0.0, 100).unwrap();
            let b = without.get(p, "m", "prov", 0.0, 100).unwrap();
            assert_eq!(a, b);
        }
        let s_with = with.stats();
        let s_without = without.stats();
        assert!(s_with.files_skipped > 0, "stats must prune clustered files");
        assert!(
            s_with.files_opened < s_without.files_opened,
            "skipping opened {} files, disabled opened {}",
            s_with.files_opened,
            s_without.files_opened
        );
    }

    #[test]
    fn optimize_then_vacuum_preserves_every_lookup() {
        let dir = tmp_dir("maintenance");
        let prompts: Vec<String> = (0..40).map(|i| format!("m-prompt-{i}")).collect();
        let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        for chunk in prompts.chunks(5) {
            for p in chunk {
                cache.put(p, "m", "prov", 0.0, 100, &resp(&format!("resp:{p}"))).unwrap();
            }
            cache.flush().unwrap();
        }
        let before: Vec<_> = prompts
            .iter()
            .map(|p| cache.get(p, "m", "prov", 0.0, 100).unwrap().unwrap())
            .collect();

        let optimized = cache.optimize(u64::MAX).unwrap();
        assert!(optimized.version.is_some());
        assert_eq!(optimized.metrics.removed_sizes.len(), 8);
        let vacuumed = cache.vacuum(0, false).unwrap();
        assert_eq!(vacuumed.deleted_files as usize, 8, "superseded files reclaimed");

        // Same handle and a fresh handle both still answer identically.
        let reopened = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        for (p, old) in prompts.iter().zip(&before) {
            let again = cache.get(p, "m", "prov", 0.0, 100).unwrap().unwrap();
            assert_eq!(&again, old);
            let fresh = reopened.get(p, "m", "prov", 0.0, 100).unwrap().unwrap();
            assert_eq!(&fresh, old);
        }
        assert_eq!(reopened.len().unwrap(), prompts.len());
    }

    #[test]
    fn entries_for_model_scopes_by_stats() {
        let dir = tmp_dir("permodel");
        let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        for i in 0..6 {
            cache.put(&format!("a{i}"), "model-a", "prov", 0.0, 100, &resp("a")).unwrap();
        }
        cache.flush().unwrap();
        for i in 0..4 {
            cache.put(&format!("b{i}"), "model-b", "prov", 0.0, 100, &resp("b")).unwrap();
        }
        cache.flush().unwrap();
        let fresh = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        let a = fresh.entries_for_model("model-a", "prov").unwrap();
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|e| e.model_name == "model-a"));
        let s = fresh.stats();
        assert!(
            s.files_skipped >= 1,
            "model-b-only file should be pruned by model_name stats, stats: {s:?}"
        );
        assert_eq!(fresh.entries_for_model("model-b", "prov").unwrap().len(), 4);
    }
}
