//! Content-addressable response caching backed by deltalite (paper §3.2).
//!
//! Cache key: `SHA256(prompt || model || provider || temperature ||
//! max_tokens)` — exact-match on the full inference configuration. Entries
//! follow the Table 1 schema. Policies: Enabled / ReadOnly / WriteOnly /
//! Replay / Disabled.

pub mod deltalite;
pub mod semantic;

use crate::config::CachePolicy;
use crate::providers::InferenceResponse;
use crate::util::json::Json;
use anyhow::{bail, Result};
use deltalite::DeltaTable;
use sha2::{Digest, Sha256};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// Deterministic cache key (paper §3.2).
pub fn cache_key(
    prompt: &str,
    model: &str,
    provider: &str,
    temperature: f64,
    max_tokens: usize,
) -> String {
    let mut h = Sha256::new();
    h.update(prompt.as_bytes());
    h.update(b"||");
    h.update(model.as_bytes());
    h.update(b"||");
    h.update(provider.as_bytes());
    h.update(b"||");
    h.update(format!("{temperature:.6}").as_bytes());
    h.update(b"||");
    h.update(format!("{max_tokens}").as_bytes());
    format!("{:x}", h.finalize())
}

/// One cache entry (Table 1 schema).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub prompt_hash: String,
    pub model_name: String,
    pub provider: String,
    pub prompt_text: String,
    pub response_text: String,
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub latency_ms: f64,
    pub created_at: f64,
    pub ttl_days: Option<f64>,
}

impl CacheEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prompt_hash", Json::str(&self.prompt_hash)),
            ("model_name", Json::str(&self.model_name)),
            ("provider", Json::str(&self.provider)),
            ("prompt_text", Json::str(&self.prompt_text)),
            ("response_text", Json::str(&self.response_text)),
            ("input_tokens", Json::num(self.input_tokens as f64)),
            ("output_tokens", Json::num(self.output_tokens as f64)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("created_at", Json::num(self.created_at)),
            (
                "ttl_days",
                self.ttl_days.map(Json::num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CacheEntry> {
        Ok(CacheEntry {
            prompt_hash: v.get("prompt_hash")?.as_str()?.to_string(),
            model_name: v.get("model_name")?.as_str()?.to_string(),
            provider: v.get("provider")?.as_str()?.to_string(),
            prompt_text: v.str_or("prompt_text", "").to_string(),
            response_text: v.get("response_text")?.as_str()?.to_string(),
            input_tokens: v.usize_or("input_tokens", 0),
            output_tokens: v.usize_or("output_tokens", 0),
            latency_ms: v.f64_or("latency_ms", 0.0),
            created_at: v.f64_or("created_at", 0.0),
            ttl_days: v.opt("ttl_days").and_then(|t| t.as_f64().ok()),
        })
    }

    /// Entry expired relative to `now` (unix seconds)?
    pub fn expired(&self, now: f64) -> bool {
        match self.ttl_days {
            Some(days) => now - self.created_at > days * 86_400.0,
            None => false,
        }
    }
}

/// Hit/miss accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub expired: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The response cache: deltalite table + in-memory index + policy.
///
/// The in-memory index mirrors the live snapshot for O(1) lookups; writes
/// buffer and flush to the table in batches (one deltalite version per
/// flush, like the paper's per-partition cache population).
pub struct ResponseCache {
    table: DeltaTable,
    policy: CachePolicy,
    index: Mutex<BTreeMap<String, CacheEntry>>,
    pending: Mutex<Vec<CacheEntry>>,
    /// Serializes deltalite commits from this process; see [`Self::flush`].
    commit_lock: Mutex<()>,
    stats: Mutex<CacheStats>,
    /// Default TTL for new entries.
    pub ttl_days: Option<f64>,
    /// Flush threshold (entries buffered before an automatic flush).
    pub flush_every: usize,
}

impl ResponseCache {
    pub fn open(dir: &Path, policy: CachePolicy) -> Result<ResponseCache> {
        let table = DeltaTable::open(dir)?;
        let mut index = BTreeMap::new();
        if policy.reads() {
            for (k, v) in table.snapshot_by_key("prompt_hash", None)? {
                index.insert(k, CacheEntry::from_json(&v)?);
            }
        }
        Ok(ResponseCache {
            table,
            policy,
            index: Mutex::new(index),
            pending: Mutex::new(Vec::new()),
            commit_lock: Mutex::new(()),
            stats: Mutex::new(CacheStats::default()),
            ttl_days: None,
            flush_every: 1000,
        })
    }

    /// Open at a historical version (time-travel reproduction of a past
    /// evaluation). Always read-only.
    pub fn open_at_version(dir: &Path, version: u64) -> Result<ResponseCache> {
        let table = DeltaTable::open(dir)?;
        let mut index = BTreeMap::new();
        for (k, v) in table.snapshot_by_key("prompt_hash", Some(version))? {
            index.insert(k, CacheEntry::from_json(&v)?);
        }
        Ok(ResponseCache {
            table,
            policy: CachePolicy::ReadOnly,
            index: Mutex::new(index),
            pending: Mutex::new(Vec::new()),
            commit_lock: Mutex::new(()),
            stats: Mutex::new(CacheStats::default()),
            ttl_days: None,
            flush_every: 1000,
        })
    }

    /// The backing table's directory: out-of-process executors open their
    /// own connection to the same store (deltalite commits are
    /// multi-writer safe), so the driver ships this path in task plans.
    pub fn dir(&self) -> &Path {
        self.table.root()
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookup under the policy. `Replay` turns a miss into an error.
    pub fn get(
        &self,
        prompt: &str,
        model: &str,
        provider: &str,
        temperature: f64,
        max_tokens: usize,
    ) -> Result<Option<CacheEntry>> {
        if !self.policy.reads() {
            return Ok(None);
        }
        let key = cache_key(prompt, model, provider, temperature, max_tokens);
        let now = crate::util::unix_ts();
        let found = {
            let index = self.index.lock().unwrap();
            index.get(&key).cloned()
        };
        let mut stats = self.stats.lock().unwrap();
        match found {
            Some(e) if e.expired(now) => {
                stats.expired += 1;
                stats.misses += 1;
                if self.policy == CachePolicy::Replay {
                    bail!("replay mode: cache entry expired for key {key}");
                }
                Ok(None)
            }
            Some(e) => {
                stats.hits += 1;
                Ok(Some(e))
            }
            None => {
                stats.misses += 1;
                if self.policy == CachePolicy::Replay {
                    bail!(
                        "replay mode: cache miss for prompt {:?}... (key {key})",
                        &prompt[..prompt.len().min(40)]
                    );
                }
                Ok(None)
            }
        }
    }

    /// Store a response under the policy (no-op for read-only policies).
    pub fn put(
        &self,
        prompt: &str,
        model: &str,
        provider: &str,
        temperature: f64,
        max_tokens: usize,
        response: &InferenceResponse,
    ) -> Result<()> {
        if !self.policy.writes() {
            return Ok(());
        }
        let key = cache_key(prompt, model, provider, temperature, max_tokens);
        let entry = CacheEntry {
            prompt_hash: key.clone(),
            model_name: model.to_string(),
            provider: provider.to_string(),
            prompt_text: prompt.to_string(),
            response_text: response.text.clone(),
            input_tokens: response.input_tokens,
            output_tokens: response.output_tokens,
            latency_ms: response.latency_ms,
            created_at: crate::util::unix_ts(),
            ttl_days: self.ttl_days,
        };
        self.index.lock().unwrap().insert(key, entry.clone());
        let should_flush = {
            let mut pending = self.pending.lock().unwrap();
            pending.push(entry);
            pending.len() >= self.flush_every
        };
        self.stats.lock().unwrap().writes += 1;
        if should_flush {
            self.flush()?;
        }
        Ok(())
    }

    /// Persist buffered writes as one deltalite upsert.
    ///
    /// Commits are serialized through `commit_lock` so concurrent executor
    /// flushes from this process never race each other on a version, and
    /// commit conflicts from *other* processes sharing the table (deltalite
    /// now fails those hard instead of clobbering the log) are retried
    /// with a freshly recomputed version a few times before giving up.
    pub fn flush(&self) -> Result<()> {
        let _commit_guard = self.commit_lock.lock().unwrap();
        let pending: Vec<CacheEntry> = {
            let mut p = self.pending.lock().unwrap();
            std::mem::take(&mut *p)
        };
        if pending.is_empty() {
            return Ok(());
        }
        // Deduplicate within the batch (last write wins) — upsert requires
        // unique keys.
        let mut by_key: BTreeMap<String, Json> = BTreeMap::new();
        for e in &pending {
            by_key.insert(e.prompt_hash.clone(), e.to_json());
        }
        let rows: Vec<Json> = by_key.into_values().collect();
        let mut last_err = None;
        for _ in 0..4 {
            match self.table.upsert(&rows, "prompt_hash") {
                Ok(_) => return Ok(()),
                Err(e) if deltalite::is_commit_conflict(&e) => last_err = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap().context("flushing response cache"))
    }

    /// Storage footprint of live data (paper §5.3 accounting).
    pub fn storage_bytes(&self) -> Result<u64> {
        self.table.storage_bytes()
    }

    pub fn current_version(&self) -> Result<Option<u64>> {
        self.table.current_version()
    }

    /// Compact the underlying table.
    pub fn compact(&self) -> Result<()> {
        self.flush()?;
        self.table.compact()?;
        Ok(())
    }
}

impl Drop for ResponseCache {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-cache-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn resp(text: &str) -> InferenceResponse {
        InferenceResponse {
            text: text.into(),
            input_tokens: 10,
            output_tokens: 5,
            latency_ms: 100.0,
            cost_usd: 0.001,
        }
    }

    #[test]
    fn key_sensitivity() {
        let base = cache_key("p", "m", "prov", 0.0, 100);
        assert_ne!(base, cache_key("q", "m", "prov", 0.0, 100));
        assert_ne!(base, cache_key("p", "m2", "prov", 0.0, 100));
        assert_ne!(base, cache_key("p", "m", "prov2", 0.0, 100));
        assert_ne!(base, cache_key("p", "m", "prov", 0.5, 100));
        assert_ne!(base, cache_key("p", "m", "prov", 0.0, 200));
        assert_eq!(base, cache_key("p", "m", "prov", 0.0, 100));
        assert_eq!(base.len(), 64);
    }

    #[test]
    fn get_after_put() {
        let cache = ResponseCache::open(&tmp_dir("getput"), CachePolicy::Enabled).unwrap();
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        cache.put("p", "m", "prov", 0.0, 100, &resp("hello")).unwrap();
        let hit = cache.get("p", "m", "prov", 0.0, 100).unwrap().unwrap();
        assert_eq!(hit.response_text, "hello");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 1, 1));
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = tmp_dir("persist");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("p", "m", "prov", 0.0, 100, &resp("persisted")).unwrap();
            cache.flush().unwrap();
        }
        let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        assert_eq!(cache.len(), 1);
        let hit = cache.get("p", "m", "prov", 0.0, 100).unwrap().unwrap();
        assert_eq!(hit.response_text, "persisted");
    }

    #[test]
    fn replay_errors_on_miss() {
        let dir = tmp_dir("replay");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("known", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        }
        let cache = ResponseCache::open(&dir, CachePolicy::Replay).unwrap();
        assert!(cache.get("known", "m", "prov", 0.0, 100).unwrap().is_some());
        assert!(cache.get("unknown", "m", "prov", 0.0, 100).is_err());
        // Replay never writes.
        cache.put("new", "m", "prov", 0.0, 100, &resp("y")).unwrap();
        assert_eq!(cache.stats().writes, 0);
    }

    #[test]
    fn write_only_skips_lookup() {
        let cache = ResponseCache::open(&tmp_dir("writeonly"), CachePolicy::WriteOnly).unwrap();
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        // Lookup returns None even though the entry exists.
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        assert_eq!(cache.stats().writes, 1);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn disabled_does_nothing() {
        let cache = ResponseCache::open(&tmp_dir("disabled"), CachePolicy::Disabled).unwrap();
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes), (0, 0, 0));
    }

    #[test]
    fn ttl_expiry() {
        let dir = tmp_dir("ttl");
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.ttl_days = Some(1.0);
        cache.put("p", "m", "prov", 0.0, 100, &resp("x")).unwrap();
        // Manually age the entry in the index.
        {
            let mut idx = cache.index.lock().unwrap();
            for e in idx.values_mut() {
                e.created_at -= 2.0 * 86_400.0;
            }
        }
        assert!(cache.get("p", "m", "prov", 0.0, 100).unwrap().is_none());
        assert_eq!(cache.stats().expired, 1);
    }

    #[test]
    fn time_travel_reproduces_old_state() {
        let dir = tmp_dir("timetravel");
        {
            let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
            cache.put("p", "m", "prov", 0.0, 100, &resp("v0")).unwrap();
            cache.flush().unwrap(); // version 0
            cache.put("p", "m", "prov", 0.0, 100, &resp("v1")).unwrap();
            cache.flush().unwrap(); // version 1
        }
        let old = ResponseCache::open_at_version(&dir, 0).unwrap();
        assert_eq!(
            old.get("p", "m", "prov", 0.0, 100).unwrap().unwrap().response_text,
            "v0"
        );
        let new = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        assert_eq!(
            new.get("p", "m", "prov", 0.0, 100).unwrap().unwrap().response_text,
            "v1"
        );
    }

    #[test]
    fn entry_json_round_trip() {
        let e = CacheEntry {
            prompt_hash: "abc".into(),
            model_name: "m".into(),
            provider: "p".into(),
            prompt_text: "prompt \"quoted\"".into(),
            response_text: "line1\nline2".into(),
            input_tokens: 42,
            output_tokens: 7,
            latency_ms: 123.4,
            created_at: 1000.0,
            ttl_days: Some(30.0),
        };
        let back = CacheEntry::from_json(&e.to_json()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn batch_flush_threshold() {
        let dir = tmp_dir("flush");
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.flush_every = 10;
        for i in 0..25 {
            cache.put(&format!("p{i}"), "m", "prov", 0.0, 100, &resp("x")).unwrap();
        }
        // Two automatic flushes happened (at 10 and 20); version >= 1.
        assert!(cache.current_version().unwrap() >= Some(1));
        cache.flush().unwrap();
        let reopened = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
        assert_eq!(reopened.len(), 25);
    }
}
