//! Semantic (fuzzy) response cache — the paper's §6.1 limitation
//! ("exact-match caching does not handle semantic equivalence;
//! semantic caching could improve hit rates"), implemented as an optional
//! layer in the GPTCache style: prompts are embedded with the SimLM PJRT
//! encoder and a cache hit is the nearest stored prompt above a cosine
//! threshold *for the same (model, provider, temperature, max_tokens)*.
//!
//! Trade-offs preserved from the paper's discussion: fuzzy hits risk
//! serving a response to a subtly different prompt, so the threshold is
//! explicit and hits report their similarity for auditability.

use crate::cache::CacheEntry;
use crate::runtime::SemanticRuntime;
use anyhow::Result;

/// One stored prompt: embedding + the exact-match key scope.
struct SemEntry {
    scope: String,
    embedding: Vec<f32>,
    entry: CacheEntry,
}

/// In-memory semantic index over cache entries. Persistence rides on the
/// exact-match Delta-backed cache; [`SemanticCache::rebuild_from`]
/// repopulates the index from it at open.
pub struct SemanticCache<'rt> {
    runtime: &'rt SemanticRuntime,
    threshold: f32,
    entries: Vec<SemEntry>,
    pub hits: u64,
    pub misses: u64,
}

fn scope_key(model: &str, provider: &str, temperature: f64, max_tokens: usize) -> String {
    format!("{model}|{provider}|{temperature:.6}|{max_tokens}")
}

impl<'rt> SemanticCache<'rt> {
    pub fn new(runtime: &'rt SemanticRuntime, threshold: f32) -> Self {
        assert!((0.0..=1.0).contains(&threshold));
        Self { runtime, threshold, entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Index an entry (embeds the prompt once).
    pub fn insert(&mut self, entry: CacheEntry) -> Result<()> {
        let emb = self.runtime.embed_texts(&[entry.prompt_text.as_str()])?;
        self.entries.push(SemEntry {
            scope: scope_key(&entry.model_name, &entry.provider, 0.0, 0)
                .replace("|0.000000|0", ""), // scope on (model, provider)
            embedding: emb.into_iter().next().unwrap(),
            entry,
        });
        Ok(())
    }

    /// Rebuild the index for one (model, provider) scope from the
    /// exact-match cache. The scan consults the cache's per-file
    /// `model_name` stats, so a multi-model table only decompresses the
    /// requested model's data files.
    pub fn rebuild_from(
        &mut self,
        cache: &crate::cache::ResponseCache,
        model: &str,
        provider: &str,
    ) -> Result<usize> {
        let entries = cache.entries_for_model(model, provider)?;
        let n = entries.len();
        for entry in entries {
            self.insert(entry)?;
        }
        Ok(n)
    }

    /// Fuzzy lookup: nearest stored prompt in the same scope with cosine
    /// ≥ threshold. Returns (entry, similarity).
    pub fn get(
        &mut self,
        prompt: &str,
        model: &str,
        provider: &str,
    ) -> Result<Option<(CacheEntry, f32)>> {
        if self.entries.is_empty() {
            self.misses += 1;
            return Ok(None);
        }
        let scope = scope_key(model, provider, 0.0, 0).replace("|0.000000|0", "");
        let q = self
            .runtime
            .embed_texts(&[prompt])?
            .into_iter()
            .next()
            .unwrap();
        let mut best: Option<(usize, f32)> = None;
        for (i, e) in self.entries.iter().enumerate() {
            if e.scope != scope {
                continue;
            }
            let sim = SemanticRuntime::cosine(&q, &e.embedding);
            if best.map(|(_, b)| sim > b).unwrap_or(true) {
                best = Some((i, sim));
            }
        }
        match best {
            Some((i, sim)) if sim >= self.threshold => {
                self.hits += 1;
                Ok(Some((self.entries[i].entry.clone(), sim)))
            }
            _ => {
                self.misses += 1;
                Ok(None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn entry(prompt: &str, response: &str, model: &str) -> CacheEntry {
        CacheEntry {
            prompt_hash: crate::cache::cache_key(prompt, model, "openai", 0.0, 1024),
            model_name: model.into(),
            provider: "openai".into(),
            prompt_text: prompt.into(),
            response_text: response.into(),
            input_tokens: 10,
            output_tokens: 5,
            latency_ms: 100.0,
            created_at: 0.0,
            ttl_days: None,
        }
    }

    fn runtime() -> Option<SemanticRuntime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(SemanticRuntime::load(&dir).unwrap())
    }

    #[test]
    fn exact_prompt_hits() {
        let Some(rt) = runtime() else { return };
        let mut sc = SemanticCache::new(&rt, 0.9);
        sc.insert(entry("what is the capital of france", "paris", "gpt-4o")).unwrap();
        let hit = sc.get("what is the capital of france", "gpt-4o", "openai").unwrap();
        let (e, sim) = hit.expect("identical prompt must hit");
        assert_eq!(e.response_text, "paris");
        assert!(sim > 0.999);
    }

    #[test]
    fn paraphrase_hits_below_exact_cache() {
        let Some(rt) = runtime() else { return };
        let mut sc = SemanticCache::new(&rt, 0.80);
        sc.insert(entry(
            "what is the capital city of france",
            "paris",
            "gpt-4o",
        ))
        .unwrap();
        // The exact-match cache would miss this rephrasing; semantic hits.
        let hit = sc
            .get("tell me the capital city of france please", "gpt-4o", "openai")
            .unwrap();
        assert!(hit.is_some(), "paraphrase should hit at 0.80 threshold");
        let (_, sim) = hit.unwrap();
        assert!(sim < 0.9999, "paraphrase is not an exact embedding match");
    }

    #[test]
    fn unrelated_prompt_misses() {
        let Some(rt) = runtime() else { return };
        let mut sc = SemanticCache::new(&rt, 0.85);
        sc.insert(entry("what is the capital of france", "paris", "gpt-4o")).unwrap();
        let hit = sc
            .get("write a poem about gradient descent optimization", "gpt-4o", "openai")
            .unwrap();
        assert!(hit.is_none(), "unrelated prompt must miss");
        assert_eq!(sc.misses, 1);
    }

    #[test]
    fn scope_isolation_across_models() {
        let Some(rt) = runtime() else { return };
        let mut sc = SemanticCache::new(&rt, 0.8);
        sc.insert(entry("what is the capital of france", "paris", "gpt-4o")).unwrap();
        let hit = sc.get("what is the capital of france", "gpt-4o-mini", "openai").unwrap();
        assert!(hit.is_none(), "different model must not share fuzzy entries");
    }

    #[test]
    fn rebuild_from_exact_cache_scopes_by_model() {
        let Some(rt) = runtime() else { return };
        let dir = std::env::temp_dir()
            .join("slleval-semantic-test")
            .join(format!("rebuild-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = crate::cache::ResponseCache::open(
            &dir,
            crate::config::CachePolicy::Enabled,
        )
        .unwrap();
        let resp = |text: &str| crate::providers::InferenceResponse {
            text: text.into(),
            input_tokens: 10,
            output_tokens: 5,
            latency_ms: 100.0,
            cost_usd: 0.001,
        };
        cache
            .put("what is the capital of france", "gpt-4o", "openai", 0.0, 1024, &resp("paris"))
            .unwrap();
        cache
            .put("what is the capital of norway", "gpt-4o", "openai", 0.0, 1024, &resp("oslo"))
            .unwrap();
        let madrid = resp("madrid");
        cache
            .put("what is the capital of spain", "other-model", "openai", 0.0, 1024, &madrid)
            .unwrap();
        cache.flush().unwrap();

        let mut sc = SemanticCache::new(&rt, 0.8);
        let n = sc.rebuild_from(&cache, "gpt-4o", "openai").unwrap();
        assert_eq!(n, 2, "only the requested model's entries are indexed");
        assert_eq!(sc.len(), 2);
        let hit = sc.get("tell me the capital city of france", "gpt-4o", "openai").unwrap();
        assert!(hit.is_some());
        let miss = sc.get("what is the capital of spain", "other-model", "openai").unwrap();
        assert!(miss.is_none(), "other models' entries are not indexed");
    }

    #[test]
    fn threshold_controls_hit_rate() {
        let Some(rt) = runtime() else { return };
        let mut strict = SemanticCache::new(&rt, 0.995);
        let mut loose = SemanticCache::new(&rt, 0.5);
        let e = entry("name the capital of norway", "oslo", "gpt-4o");
        strict.insert(e.clone()).unwrap();
        loose.insert(e).unwrap();
        let q = "what city is the capital of norway";
        assert!(strict.get(q, "gpt-4o", "openai").unwrap().is_none());
        assert!(loose.get(q, "gpt-4o", "openai").unwrap().is_some());
    }
}
