//! "deltalite": a minimal Delta-Lake-style versioned table.
//!
//! The paper stores the response cache in Delta Lake for ACID appends,
//! upserts, and time travel (§3.2, Table 1). deltalite reproduces exactly
//! those properties on the local filesystem:
//!
//! ```text
//! <table>/
//!   _log/00000000.json     one commit per version: schema + actions
//!   _log/00000001.json
//!   data/<version>-<n>-<writer>.jsonl.gz   immutable row files (gzip JSONL)
//! ```
//!
//! Each commit lists `add` actions (new data files) and `remove` actions
//! (files superseded by an upsert/compaction). A snapshot at version V is
//! the union of rows in files added-but-not-removed by commits ≤ V — which
//! is precisely Delta's log-replay protocol, minus checkpointing (our logs
//! are small). Upserts deduplicate on a key column: the newest version of
//! a key wins.

use crate::util::fsx::{self, Publish};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

/// One commit's content.
#[derive(Debug, Clone)]
struct Commit {
    version: u64,
    adds: Vec<String>,
    removes: Vec<String>,
    /// Operation tag ("append" | "upsert" | "compact") for diagnostics.
    op: String,
    timestamp: f64,
}

/// A versioned table rooted at a directory.
pub struct DeltaTable {
    root: PathBuf,
}

/// Does `err` denote a commit conflict — an `append`/`upsert`/`compact`
/// losing the optimistic-concurrency race for its version? Callers retry
/// these (the next attempt re-reads the log and targets the next free
/// version); any other error is a real failure. The vendored `anyhow`
/// shim has no `downcast`, so conflicts travel as a message marker —
/// this helper is the one place allowed to know that.
pub fn is_commit_conflict(err: &anyhow::Error) -> bool {
    err.chain().any(|m| m.contains("commit conflict"))
}

impl DeltaTable {
    /// Open or create the table.
    pub fn open(root: &Path) -> Result<DeltaTable> {
        std::fs::create_dir_all(root.join("_log"))?;
        std::fs::create_dir_all(root.join("data"))?;
        Ok(DeltaTable { root: root.to_path_buf() })
    }

    /// The table's root directory (cache relocation, worker handoff).
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn log_dir(&self) -> PathBuf {
        self.root.join("_log")
    }

    fn data_dir(&self) -> PathBuf {
        self.root.join("data")
    }

    /// Latest committed version, or None for an empty table.
    pub fn current_version(&self) -> Result<Option<u64>> {
        let mut max: Option<u64> = None;
        for entry in std::fs::read_dir(self.log_dir())? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(stem) = name.strip_suffix(".json") {
                if let Ok(v) = stem.parse::<u64>() {
                    max = Some(max.map_or(v, |m| m.max(v)));
                }
            }
        }
        Ok(max)
    }

    fn read_commit(&self, version: u64) -> Result<Commit> {
        let path = self.log_dir().join(format!("{version:08}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading commit {path:?}"))?;
        let v = Json::parse(&text)?;
        Ok(Commit {
            version,
            adds: v
                .get("add")?
                .as_arr()?
                .iter()
                .map(|a| Ok(a.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            removes: v
                .get("remove")?
                .as_arr()?
                .iter()
                .map(|a| Ok(a.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            op: v.str_or("op", "append").to_string(),
            timestamp: v.f64_or("timestamp", 0.0),
        })
    }

    fn commits_up_to(&self, version: Option<u64>) -> Result<Vec<Commit>> {
        let Some(latest) = self.current_version()? else {
            return Ok(vec![]);
        };
        let upper = match version {
            Some(v) if v > latest => bail!("version {v} does not exist (latest {latest})"),
            Some(v) => v,
            None => latest,
        };
        (0..=upper).map(|v| self.read_commit(v)).collect()
    }

    /// Live data files at a version (log replay).
    fn live_files(&self, version: Option<u64>) -> Result<Vec<String>> {
        let mut live: BTreeSet<String> = BTreeSet::new();
        for c in self.commits_up_to(version)? {
            for r in &c.removes {
                live.remove(r);
            }
            for a in &c.adds {
                live.insert(a.clone());
            }
        }
        Ok(live.into_iter().collect())
    }

    fn write_data_file(&self, version: u64, part: usize, rows: &[Json]) -> Result<String> {
        // The name carries a per-writer discriminator so two writers racing
        // on the same version can never clobber each other's data file:
        // the losing commit leaves an orphaned (never referenced, harmless)
        // file behind, exactly like Delta's GUID-named parquet parts.
        let name = format!("{version:08}-{part:04}-{}.jsonl.gz", fsx::unique_suffix());
        let path = self.data_dir().join(&name);
        let file = std::fs::File::create(&path)?;
        let mut enc = GzEncoder::new(file, Compression::fast());
        for row in rows {
            writeln!(enc, "{row}")?;
        }
        enc.finish()?;
        Ok(name)
    }

    fn read_data_file(&self, name: &str) -> Result<Vec<Json>> {
        let path = self.data_dir().join(name);
        let file = std::fs::File::open(&path).with_context(|| format!("reading {path:?}"))?;
        let reader = BufReader::new(GzDecoder::new(file));
        let mut rows = Vec::new();
        for line in reader.lines() {
            let line = line?;
            if !line.trim().is_empty() {
                rows.push(Json::parse(&line)?);
            }
        }
        Ok(rows)
    }

    /// Next unclaimed version number.
    fn next_version(&self) -> Result<u64> {
        Ok(self.current_version()?.map_or(0, |v| v + 1))
    }

    /// Commit `adds`/`removes` at exactly `version`. The log entry is
    /// published with an exclusive first-writer-wins claim (O_EXCL
    /// semantics via `link(2)`; see [`crate::util::fsx`]): a plain
    /// check-then-rename would race — on Linux `rename(2)` silently
    /// replaces an existing destination, so two writers committing the
    /// same version would clobber a committed log entry. Here exactly one
    /// racing writer wins the version and every loser gets a hard
    /// "commit conflict" error. The version is computed once by the
    /// calling operation (never recomputed between naming the data file
    /// and claiming the log slot), so a commit can only ever reference
    /// data files written for that same version.
    fn commit(&self, version: u64, adds: Vec<String>, removes: Vec<String>, op: &str) -> Result<u64> {
        let entry = Json::obj(vec![
            ("version", Json::num(version as f64)),
            ("op", Json::str(op)),
            ("timestamp", Json::num(crate::util::unix_ts())),
            ("add", Json::arr(adds.into_iter().map(Json::Str).collect())),
            ("remove", Json::arr(removes.into_iter().map(Json::Str).collect())),
        ]);
        let final_path = self.log_dir().join(format!("{version:08}.json"));
        match fsx::publish_exclusive(&final_path, entry.to_pretty().as_bytes())? {
            Publish::Committed => Ok(version),
            Publish::Conflict => bail!("commit conflict at version {version}"),
        }
    }

    /// Append rows as a new version. Returns the version. A concurrent
    /// writer claiming the same version first surfaces as a
    /// "commit conflict" error; retrying the append re-reads the log and
    /// targets the next free version.
    pub fn append(&self, rows: &[Json]) -> Result<u64> {
        let version = self.next_version()?;
        let file = self.write_data_file(version, 0, rows)?;
        self.commit(version, vec![file], vec![], "append")
    }

    /// Upsert rows keyed on `key_col`: rows with existing keys replace the
    /// old rows (old files containing them are rewritten), new keys append.
    pub fn upsert(&self, rows: &[Json], key_col: &str) -> Result<u64> {
        // Claim the target version *before* scanning live files: any commit
        // that lands while we rewrite makes our claim conflict (instead of
        // us committing a rewrite based on a stale snapshot).
        let version = self.next_version()?;
        let new_keys: BTreeSet<String> = rows
            .iter()
            .filter_map(|r| r.opt(key_col).and_then(|k| k.as_str().ok()).map(String::from))
            .collect();
        if new_keys.len() != rows.len() {
            bail!("upsert rows must all carry a unique string '{key_col}'");
        }

        // Find live files containing clobbered keys; rewrite them minus
        // those rows.
        let mut removes = Vec::new();
        let mut rewritten: Vec<Json> = Vec::new();
        for file in self.live_files(None)? {
            let file_rows = self.read_data_file(&file)?;
            let has_conflict = file_rows.iter().any(|r| {
                r.opt(key_col)
                    .and_then(|k| k.as_str().ok())
                    .map(|k| new_keys.contains(k))
                    .unwrap_or(false)
            });
            if has_conflict {
                removes.push(file.clone());
                rewritten.extend(file_rows.into_iter().filter(|r| {
                    r.opt(key_col)
                        .and_then(|k| k.as_str().ok())
                        .map(|k| !new_keys.contains(k))
                        .unwrap_or(true)
                }));
            }
        }

        let mut adds = Vec::new();
        if !rewritten.is_empty() {
            adds.push(self.write_data_file(version, 1, &rewritten)?);
        }
        adds.push(self.write_data_file(version, 0, rows)?);
        self.commit(version, adds, removes, "upsert")
    }

    /// Read the full snapshot at `version` (None = latest). Rows from all
    /// live files, in file order.
    pub fn snapshot(&self, version: Option<u64>) -> Result<Vec<Json>> {
        let mut rows = Vec::new();
        for file in self.live_files(version)? {
            rows.extend(self.read_data_file(&file)?);
        }
        Ok(rows)
    }

    /// Snapshot as a key → row map (last write wins within a file list).
    pub fn snapshot_by_key(&self, key_col: &str, version: Option<u64>) -> Result<BTreeMap<String, Json>> {
        let mut map = BTreeMap::new();
        for row in self.snapshot(version)? {
            if let Some(k) = row.opt(key_col).and_then(|k| k.as_str().ok()) {
                map.insert(k.to_string(), row.clone());
            }
        }
        Ok(map)
    }

    /// Rewrite all live rows into a single file (log stays, data shrinks).
    pub fn compact(&self) -> Result<u64> {
        let version = self.next_version()?;
        let live = self.live_files(None)?;
        let rows = self.snapshot(None)?;
        let file = self.write_data_file(version, 0, &rows)?;
        self.commit(version, vec![file], live, "compact")
    }

    /// Total bytes of live data files (storage-overhead accounting, §5.3).
    pub fn storage_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for file in self.live_files(None)? {
            total += std::fs::metadata(self.data_dir().join(&file))?.len();
        }
        Ok(total)
    }

    /// History of (version, op, timestamp) for diagnostics.
    pub fn history(&self) -> Result<Vec<(u64, String, f64)>> {
        Ok(self
            .commits_up_to(None)?
            .into_iter()
            .map(|c| (c.version, c.op, c.timestamp))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_table(name: &str) -> DeltaTable {
        let dir = std::env::temp_dir().join("slleval-delta-test").join(format!(
            "{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        DeltaTable::open(&dir).unwrap()
    }

    fn row(k: &str, v: f64) -> Json {
        Json::obj(vec![("key", Json::str(k)), ("value", Json::num(v))])
    }

    #[test]
    fn append_and_snapshot() {
        let t = tmp_table("append");
        assert_eq!(t.current_version().unwrap(), None);
        t.append(&[row("a", 1.0), row("b", 2.0)]).unwrap();
        t.append(&[row("c", 3.0)]).unwrap();
        assert_eq!(t.current_version().unwrap(), Some(1));
        assert_eq!(t.snapshot(None).unwrap().len(), 3);
    }

    #[test]
    fn time_travel() {
        let t = tmp_table("timetravel");
        t.append(&[row("a", 1.0)]).unwrap(); // v0
        t.append(&[row("b", 2.0)]).unwrap(); // v1
        t.upsert(&[row("a", 99.0)], "key").unwrap(); // v2
        assert_eq!(t.snapshot(Some(0)).unwrap().len(), 1);
        assert_eq!(t.snapshot(Some(1)).unwrap().len(), 2);
        let v1 = t.snapshot_by_key("key", Some(1)).unwrap();
        assert_eq!(v1["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
        let v2 = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(v2["a"].get("value").unwrap().as_f64().unwrap(), 99.0);
        assert!(t.snapshot(Some(99)).is_err());
    }

    #[test]
    fn upsert_replaces_and_appends() {
        let t = tmp_table("upsert");
        t.append(&[row("a", 1.0), row("b", 2.0)]).unwrap();
        t.upsert(&[row("b", 20.0), row("c", 3.0)], "key").unwrap();
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap["b"].get("value").unwrap().as_f64().unwrap(), 20.0);
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn upsert_requires_unique_keys() {
        let t = tmp_table("upsert-dup");
        assert!(t.upsert(&[row("a", 1.0), row("a", 2.0)], "key").is_err());
    }

    #[test]
    fn compact_preserves_content() {
        let t = tmp_table("compact");
        for i in 0..5 {
            t.append(&[row(&format!("k{i}"), i as f64)]).unwrap();
        }
        let before = t.snapshot_by_key("key", None).unwrap();
        t.compact().unwrap();
        let after = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(before, after);
        // Old snapshots still readable after compaction (time travel).
        assert_eq!(t.snapshot(Some(2)).unwrap().len(), 3);
    }

    #[test]
    fn history_records_ops() {
        let t = tmp_table("history");
        t.append(&[row("a", 1.0)]).unwrap();
        t.upsert(&[row("a", 2.0)], "key").unwrap();
        t.compact().unwrap();
        let ops: Vec<String> = t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert_eq!(ops, vec!["append", "upsert", "compact"]);
    }

    #[test]
    fn storage_bytes_positive_and_shrinks_on_compact() {
        let t = tmp_table("storage");
        for i in 0..10 {
            let rows: Vec<Json> = (0..20).map(|j| row(&format!("k{i}-{j}"), j as f64)).collect();
            t.append(&rows).unwrap();
        }
        let before = t.storage_bytes().unwrap();
        assert!(before > 0);
        t.compact().unwrap();
        let after = t.storage_bytes().unwrap();
        assert!(after <= before, "compaction must not grow live storage");
    }

    #[test]
    fn same_version_commit_conflicts_hard() {
        let t = tmp_table("conflict");
        t.append(&[row("a", 1.0)]).unwrap(); // claims version 0
        // A stale writer that still believes version 0 is free must get a
        // hard conflict, not silently clobber the committed entry.
        let file = t.write_data_file(0, 0, &[row("stale", 9.0)]).unwrap();
        let err = t.commit(0, vec![file], vec![], "append").unwrap_err();
        assert!(is_commit_conflict(&err), "{err:#}");
        // The original commit is untouched.
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap["a"].get("value").unwrap().as_f64().unwrap(), 1.0);
        assert!(!snap.contains_key("stale"));
    }

    #[test]
    fn two_racing_writers_exactly_one_wins_each_version() {
        let dir = std::env::temp_dir()
            .join("slleval-delta-test")
            .join(format!("race-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DeltaTable::open(&dir).unwrap();

        const PER_WRITER: usize = 12;
        let committed: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|w| {
                    let dir = dir.clone();
                    scope.spawn(move || {
                        // Each writer has its own table handle (two
                        // processes in miniature) and retries conflicts.
                        let t = DeltaTable::open(&dir).unwrap();
                        let mut versions = Vec::new();
                        for i in 0..PER_WRITER {
                            let r = [row(&format!("w{w}-{i}"), i as f64)];
                            loop {
                                match t.append(&r) {
                                    Ok(v) => {
                                        versions.push(v);
                                        break;
                                    }
                                    Err(e) => {
                                        assert!(
                                            is_commit_conflict(&e),
                                            "only conflicts are expected: {e:#}"
                                        );
                                    }
                                }
                            }
                        }
                        versions
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

        // Every version committed exactly once, contiguously.
        let mut versions = committed;
        versions.sort_unstable();
        let expected: Vec<u64> = (0..2 * PER_WRITER as u64).collect();
        assert_eq!(versions, expected, "each version must have exactly one winner");

        // The table replays cleanly and holds every row exactly once.
        let t = DeltaTable::open(&dir).unwrap();
        assert_eq!(t.current_version().unwrap(), Some(2 * PER_WRITER as u64 - 1));
        let snap = t.snapshot_by_key("key", None).unwrap();
        assert_eq!(snap.len(), 2 * PER_WRITER);
        let ops: Vec<String> =
            t.history().unwrap().into_iter().map(|(_, op, _)| op).collect();
        assert!(ops.iter().all(|op| op == "append"));
    }

    #[test]
    fn reopen_sees_committed_state() {
        let dir = std::env::temp_dir()
            .join("slleval-delta-test")
            .join(format!("reopen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let t = DeltaTable::open(&dir).unwrap();
            t.append(&[row("a", 1.0)]).unwrap();
        }
        let t2 = DeltaTable::open(&dir).unwrap();
        assert_eq!(t2.snapshot(None).unwrap().len(), 1);
    }
}
