//! Adaptive early stopping (Cer-Eval-style certifiable cost-efficient
//! evaluation): the wave-gate decision logic the runner plugs into the
//! scheduler's [`crate::sched::WaveGate`].
//!
//! The [`StoppingDriver`] holds the task's pure metrics plus a
//! response-less "skeleton" of every example. At each wave boundary the
//! scheduler hands it the completed in-order row prefix; the driver fills
//! the skeleton's responses, rescores every not-yet-certified metric over
//! the prefix, and computes each metric's CI at the sequential-correction
//! level `1 - look_alpha(wave)` (geometric alpha spending, so the union
//! bound over every look stays within the total `alpha` budget). A metric
//! is *certified* once its CI half-width meets `stopping.ci_half_width`
//! with at least `min_rows` rows covered; certified metrics are never
//! rescored at later looks ("stop a metric"). Once every metric is
//! certified the driver returns [`WaveDecision::Stop`] and the scheduler
//! settles the job — rows past the boundary are never issued.
//!
//! Determinism: each (wave, metric) look seeds its own bootstrap rng
//! stream from the task seed, so a `--resume` replaying decisions over
//! restored rows reproduces the live run's certifications bit for bit.
//! Only the software CI paths are used here (never the device bootstrap):
//! the driver is consulted from scheduler threads and must stay `Sync`,
//! which the PJRT runtime is not.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::runner::{RowInference, RunObserver};
use crate::config::{CiMethod, EvalTask, StoppingConfig};
use crate::metrics::{Example, MetricContext, MetricRequirements, ResolvedMetric};
use crate::sched::WaveDecision;
use crate::stats::{self, MetricScale};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One metric's certification state, updated at every wave look and
/// surfaced in results, the human summary, and `GET /runs/{id}/partial`.
#[derive(Debug, Clone)]
pub struct MetricStopState {
    pub name: String,
    /// The 0-based wave at which the metric certified (`None` = still
    /// open, or the run finished the whole frame first).
    pub stopped_at_wave: Option<usize>,
    /// Whether the CI half-width met the target under the sequential
    /// correction.
    pub certified: bool,
    /// The half-width at the metric's most recent look (NaN before the
    /// first look).
    pub half_width: f64,
    /// The target half-width (`stopping.ci_half_width`).
    pub target: f64,
}

impl MetricStopState {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "stopped_at_wave",
                self.stopped_at_wave.map(|w| Json::num(w as f64)).unwrap_or(Json::Null),
            ),
            ("certified", Json::Bool(self.certified)),
            ("half_width", Json::num(self.half_width)),
            ("target", Json::num(self.target)),
        ])
    }
}

/// The stopping rule behind the runner's wave loop (see module docs).
/// Built once per gated run; shared by reference with the scheduler's
/// gate closure, so it must be (and is) `Sync`.
pub struct StoppingDriver {
    cfg: StoppingConfig,
    seed: u64,
    ci_method: CiMethod,
    bootstrap_iterations: usize,
    metrics: Vec<ResolvedMetric>,
    /// Full-length example skeleton with empty responses: wave looks
    /// clone the prefix and fill responses in, so prompt/reference
    /// assembly happens exactly once.
    skeleton: Vec<Example>,
    state: Mutex<Vec<MetricStopState>>,
    observer: Option<Arc<dyn RunObserver>>,
}

impl StoppingDriver {
    /// Build the driver for a gated run. Fails when the task has no
    /// `stopping` block or any metric is not [`MetricRequirements::Pure`]
    /// — runtime/judge metrics score on the driver *after* inference, so
    /// a wave-time CI for them would require the very calls stopping is
    /// meant to save.
    pub fn new(
        task: &EvalTask,
        resolved: &[ResolvedMetric],
        skeleton: Vec<Example>,
        observer: Option<Arc<dyn RunObserver>>,
    ) -> Result<StoppingDriver> {
        let Some(cfg) = task.stopping.clone() else {
            bail!("stopping driver built for a task without a `stopping` block");
        };
        cfg.validate()?;
        for m in resolved {
            if m.requirements() != MetricRequirements::Pure {
                bail!(
                    "adaptive stopping supports pure metrics only, but '{}' needs {:?} \
                     scoring; remove the `stopping` block or drop the metric",
                    m.name(),
                    m.requirements()
                );
            }
        }
        let state = resolved
            .iter()
            .map(|m| MetricStopState {
                name: m.name().to_string(),
                stopped_at_wave: None,
                certified: false,
                half_width: f64::NAN,
                target: cfg.ci_half_width,
            })
            .collect();
        Ok(StoppingDriver {
            seed: task.statistics.seed,
            ci_method: task.statistics.ci_method,
            bootstrap_iterations: task.statistics.bootstrap_iterations,
            cfg,
            metrics: resolved.to_vec(),
            skeleton,
            state: Mutex::new(state),
            observer,
        })
    }

    /// First wave boundary: at least `min_rows`, so the first look never
    /// certifies on a degenerate tiny-n CI.
    pub fn first_wave_rows(&self) -> usize {
        self.cfg.wave_size.max(self.cfg.min_rows)
    }

    /// Rows released per wave after the first.
    pub fn wave_step(&self) -> usize {
        self.cfg.wave_size
    }

    /// Snapshot of every metric's certification state (result stamping,
    /// the serve daemon's partial feed).
    pub fn states(&self) -> Vec<MetricStopState> {
        self.state.lock().unwrap().clone()
    }

    /// The wave decision over the completed `[0, b)` row prefix — the
    /// thread backend's gate closure (`T = RowInference`).
    pub fn decide_rows(&self, wave: usize, prefix: &[&RowInference]) -> Result<WaveDecision> {
        let b = prefix.len();
        let level = 1.0 - self.cfg.look_alpha(wave);
        let mut examples: Vec<Example> = self.skeleton[..b.min(self.skeleton.len())].to_vec();
        anyhow::ensure!(
            examples.len() == b,
            "wave {wave}: {b}-row prefix exceeds the {}-example skeleton",
            self.skeleton.len()
        );
        let mut failed = vec![false; b];
        for (i, row) in prefix.iter().enumerate() {
            match &row.response {
                Some(r) => examples[i].response = r.clone(),
                None => failed[i] = true,
            }
        }

        let mut state = self.state.lock().unwrap();
        let mut all_certified = true;
        for (mi, metric) in self.metrics.iter().enumerate() {
            if state[mi].certified {
                continue;
            }
            let batch = metric
                .score_batch(&MetricContext::detached(), &examples)
                .with_context(|| {
                    format!("wave {wave}: scoring metric '{}' over {b} rows", metric.name())
                })?;
            anyhow::ensure!(
                batch.values.len() == b,
                "wave {wave}: metric '{}' returned {} values for {b} rows",
                metric.name(),
                batch.values.len()
            );
            let scored: Vec<f64> = batch
                .values
                .iter()
                .zip(&failed)
                .filter_map(|(v, &f)| if f { None } else { *v })
                .collect();
            // One deterministic rng stream per (wave, metric) look:
            // resume replays reproduce the live decisions exactly.
            let mut rng = Rng::with_stream(
                self.seed,
                0x5AEE ^ ((wave as u64) << 16) ^ mi as u64,
            );
            let ci = wave_ci(
                &scored,
                metric.scale(),
                self.ci_method,
                level,
                self.bootstrap_iterations,
                &mut rng,
            );
            let hw = ci.half_width();
            state[mi].half_width = hw;
            if scored.len() >= 2
                && b >= self.cfg.min_rows
                && hw.is_finite()
                && hw <= self.cfg.ci_half_width
            {
                state[mi].certified = true;
                state[mi].stopped_at_wave = Some(wave);
            } else {
                all_certified = false;
            }
        }
        if let Some(obs) = &self.observer {
            obs.wave_done(wave, b, &state);
        }
        Ok(if all_certified { WaveDecision::Stop } else { WaveDecision::Continue })
    }

    /// [`StoppingDriver::decide_rows`] for the process/remote backends,
    /// whose scheduler rows are raw checkpoint-encoded JSON
    /// (`T = Json`).
    pub fn decide_json(&self, wave: usize, prefix: &[&Json]) -> Result<WaveDecision> {
        let rows = prefix
            .iter()
            .map(|v| RowInference::from_json(v))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("wave {wave}: decoding backend rows"))?;
        self.decide_rows(wave, &rows.iter().collect::<Vec<_>>())
    }
}

/// Wave-time CI: the same method dispatch as the runner's final
/// `aggregate` (Wilson for binary analytic, t otherwise, software
/// percentile/BCa bootstrap), minus the device-bootstrap offload — see
/// module docs for why.
fn wave_ci(
    scored: &[f64],
    scale: MetricScale,
    method: CiMethod,
    level: f64,
    iterations: usize,
    rng: &mut Rng,
) -> stats::ConfidenceInterval {
    if scored.is_empty() {
        return stats::ConfidenceInterval {
            point: f64::NAN,
            lo: f64::NAN,
            hi: f64::NAN,
            level,
            method: "none",
        };
    }
    match method {
        CiMethod::Analytic => {
            if scale == MetricScale::Binary {
                let successes = scored.iter().filter(|&&v| v >= 0.5).count() as u64;
                stats::wilson_interval(successes, scored.len() as u64, level)
            } else {
                stats::t_interval(scored, level)
            }
        }
        CiMethod::Percentile => stats::percentile_bootstrap(
            scored,
            stats::describe::mean,
            level,
            iterations,
            rng,
        ),
        CiMethod::Bca => {
            stats::bca_bootstrap(scored, stats::describe::mean, level, iterations, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MetricConfig;
    use crate::metrics::MetricRegistry;

    fn task_with_stopping(metrics: Vec<(&str, &str)>) -> EvalTask {
        let mut task = EvalTask::default();
        task.metrics =
            metrics.into_iter().map(|(name, family)| MetricConfig::new(name, family)).collect();
        task.stopping = Some(StoppingConfig {
            ci_half_width: 0.1,
            alpha: 0.05,
            wave_size: 20,
            min_rows: 20,
            spend_alpha: true,
        });
        task.statistics.ci_method = CiMethod::Analytic;
        task
    }

    fn skeleton(n: usize, reference: &str) -> Vec<Example> {
        (0..n)
            .map(|i| Example {
                prompt: format!("p{i}"),
                response: String::new(),
                reference: reference.to_string(),
                question: String::new(),
                context: Vec::new(),
                gold_position: -1,
            })
            .collect()
    }

    fn rows(responses: Vec<Option<&str>>) -> Vec<RowInference> {
        responses
            .into_iter()
            .map(|r| RowInference {
                response: r.map(String::from),
                from_cache: false,
                latency_ms: 0.0,
                cost_usd: 0.0,
                attempts: 1,
                error: None,
            })
            .collect()
    }

    fn driver(task: &EvalTask, n: usize) -> StoppingDriver {
        let resolved = MetricRegistry::with_builtins().resolve_task(task).unwrap();
        StoppingDriver::new(task, &resolved, skeleton(n, "yes"), None).unwrap()
    }

    #[test]
    fn certifies_a_degenerate_binary_metric_and_stops() {
        // All responses match the reference: the Wilson half-width at
        // n=40 is well under the 0.1 target, so the first look certifies
        // and the whole run stops.
        let task = task_with_stopping(vec![("exact_match", "lexical")]);
        let d = driver(&task, 200);
        let prefix = rows(vec![Some("yes"); 40]);
        let refs: Vec<&RowInference> = prefix.iter().collect();
        assert!(matches!(d.decide_rows(0, &refs).unwrap(), WaveDecision::Stop));
        let states = d.states();
        assert_eq!(states.len(), 1);
        assert!(states[0].certified);
        assert_eq!(states[0].stopped_at_wave, Some(0));
        assert!(states[0].half_width < 0.1, "hw {}", states[0].half_width);
        assert_eq!(states[0].target, 0.1);
    }

    #[test]
    fn continues_while_uncertain_then_certifies_later_wave() {
        // A 50/50 split at n=20 has Wilson half-width ~0.21 (worse at
        // the spent level) — far above 0.05 — so wave 0 continues; a
        // much larger all-match prefix certifies at wave 1.
        let mut task = task_with_stopping(vec![("exact_match", "lexical")]);
        task.stopping.as_mut().unwrap().ci_half_width = 0.05;
        let d = driver(&task, 2000);
        let mixed: Vec<Option<&str>> =
            (0..20).map(|i| if i % 2 == 0 { Some("yes") } else { Some("no") }).collect();
        let w0 = rows(mixed);
        let refs: Vec<&RowInference> = w0.iter().collect();
        assert!(matches!(d.decide_rows(0, &refs).unwrap(), WaveDecision::Continue));
        assert!(!d.states()[0].certified);
        assert!(d.states()[0].half_width > 0.05);

        let w1 = rows(vec![Some("yes"); 1500]);
        let refs: Vec<&RowInference> = w1.iter().collect();
        assert!(matches!(d.decide_rows(1, &refs).unwrap(), WaveDecision::Stop));
        assert_eq!(d.states()[0].stopped_at_wave, Some(1));
    }

    #[test]
    fn failed_rows_are_masked_not_scored() {
        // Half the prefix failed inference: the CI runs over the scored
        // half only (20 matches → certifies), never over empty responses.
        let task = task_with_stopping(vec![("exact_match", "lexical")]);
        let d = driver(&task, 200);
        let mut resp: Vec<Option<&str>> = vec![Some("yes"); 20];
        resp.extend(vec![None; 20]);
        let prefix = rows(resp);
        let refs: Vec<&RowInference> = prefix.iter().collect();
        assert!(matches!(d.decide_rows(0, &refs).unwrap(), WaveDecision::Stop));
        assert!(d.states()[0].certified);
    }

    #[test]
    fn min_rows_gate_blocks_early_certification() {
        // A perfect 10-row prefix would certify on half-width alone, but
        // min_rows = 20 holds the decision open.
        let task = task_with_stopping(vec![("exact_match", "lexical")]);
        let d = driver(&task, 200);
        let prefix = rows(vec![Some("yes"); 10]);
        let refs: Vec<&RowInference> = prefix.iter().collect();
        assert!(matches!(d.decide_rows(0, &refs).unwrap(), WaveDecision::Continue));
        assert!(!d.states()[0].certified);
    }

    #[test]
    fn non_pure_metric_is_rejected_at_construction() {
        let task = task_with_stopping(vec![("exact_match", "lexical"), ("faithfulness", "rag")]);
        let resolved = MetricRegistry::with_builtins().resolve_task(&task).unwrap();
        let err = StoppingDriver::new(&task, &resolved, skeleton(10, "yes"), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("faithfulness"), "{err}");
        assert!(err.contains("pure metrics only"), "{err}");
    }

    #[test]
    fn decisions_replay_deterministically() {
        // Bootstrap CIs draw from a per-(wave, metric) stream of the task
        // seed: two drivers fed the same prefixes agree exactly (this is
        // what makes --resume's decision replay bit-identical).
        let mut task = task_with_stopping(vec![("token_f1", "lexical")]);
        task.statistics.ci_method = CiMethod::Percentile;
        let a = driver(&task, 200);
        let b = driver(&task, 200);
        let prefix = rows(
            (0..30).map(|i| if i % 3 == 0 { Some("yes") } else { Some("yes no") }).collect(),
        );
        let refs: Vec<&RowInference> = prefix.iter().collect();
        let da = a.decide_rows(0, &refs).unwrap();
        let db = b.decide_rows(0, &refs).unwrap();
        assert_eq!(format!("{da:?}"), format!("{db:?}"));
        let (sa, sb) = (a.states(), b.states());
        assert_eq!(sa[0].half_width.to_bits(), sb[0].half_width.to_bits());
        assert_eq!(sa[0].certified, sb[0].certified);
    }

    #[test]
    fn json_rows_decode_to_the_same_decision() {
        let task = task_with_stopping(vec![("exact_match", "lexical")]);
        let d = driver(&task, 200);
        let prefix = rows(vec![Some("yes"); 40]);
        let encoded: Vec<Json> = prefix.iter().map(|r| r.to_json()).collect();
        let refs: Vec<&Json> = encoded.iter().collect();
        assert!(matches!(d.decide_json(0, &refs).unwrap(), WaveDecision::Stop));
        assert!(d.states()[0].certified);
    }

    #[test]
    fn stop_state_json_shape() {
        let s = MetricStopState {
            name: "exact_match".into(),
            stopped_at_wave: Some(2),
            certified: true,
            half_width: 0.04,
            target: 0.05,
        };
        let j = s.to_json();
        assert_eq!(j.opt("name").unwrap().as_str().unwrap(), "exact_match");
        assert_eq!(j.opt("stopped_at_wave").unwrap().as_usize().unwrap(), 2);
        assert!(j.bool_or("certified", false));
        let open = MetricStopState {
            name: "x".into(),
            stopped_at_wave: None,
            certified: false,
            half_width: f64::NAN,
            target: 0.05,
        };
        assert!(open.to_json().opt("stopped_at_wave").is_none());
    }
}
