//! Streaming evaluation (paper §6.2 future work, implemented here):
//! results stream back as partition chunks complete rather than waiting
//! for the whole dataset, each chunk carrying a *running* aggregate with
//! an any-time confidence interval.
//!
//! The inference stage runs chunk-by-chunk (each chunk is a mini
//! distributed job); after each chunk the runner emits a
//! [`StreamUpdate`] with the cumulative metric estimate. Useful for very
//! large datasets where an early stop ("the CI is already tight enough /
//! the regression is already significant") saves real money.
//!
//! Executor backends compose transparently: each chunk's inference goes
//! through [`EvalRunner::run_inference`], so `executor.backend =
//! "process"` streams over crash-isolated worker processes, and any
//! executor deaths accumulate in the update's merged
//! [`SchedulerStats::executor_deaths`].

use super::cached_engine::{CallMeter, CallStats};
use super::runner::EvalRunner;
use crate::config::EvalTask;
use crate::data::DataFrame;
use crate::metrics::MetricReport;
use crate::sched::SchedulerStats;
use crate::stats::{wilson_interval, t_interval, ConfidenceInterval, MetricScale};
use anyhow::Result;

/// One streamed progress update.
#[derive(Debug, Clone)]
pub struct StreamUpdate {
    /// Examples processed so far.
    pub processed: usize,
    pub total: usize,
    /// Running metric aggregates (one per configured metric), with
    /// analytic any-time CIs (cheap; bootstrap runs once at the end).
    pub running: Vec<(String, ConfidenceInterval)>,
    /// Cumulative inference accounting.
    pub api_calls: u64,
    pub cache_hits: u64,
    pub cost_usd: f64,
    pub failed: u64,
    /// Cumulative metric-stage call traffic (judge / RAG verification
    /// calls) over the chunks processed so far.
    pub judge_calls: CallStats,
    /// Cumulative scheduler telemetry (stealing / speculation / retries)
    /// across the chunks processed so far.
    pub sched: SchedulerStats,
}

impl StreamUpdate {
    pub fn metric(&self, name: &str) -> Option<&ConfidenceInterval> {
        self.running.iter().find(|(n, _)| n == name).map(|(_, ci)| ci)
    }
}

/// Early-stop decision callback result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamControl {
    Continue,
    /// Stop after this chunk; partial results are returned.
    Stop,
}

impl EvalRunner {
    /// Evaluate in chunks of `chunk_size`, invoking `on_update` after each
    /// chunk. Returns the final per-metric reports over the processed
    /// prefix (full dataset unless the callback stopped early).
    ///
    /// For intra-chunk progress, attach a [`crate::engine::Progress`] via
    /// [`EvalRunner::with_progress`] (sized to `df.len()`): the scheduler
    /// advances it as individual inference tasks complete, so another
    /// thread can observe real driver-side progress between updates.
    pub fn evaluate_streaming<F>(
        &self,
        df: &DataFrame,
        task: &EvalTask,
        chunk_size: usize,
        mut on_update: F,
    ) -> Result<(Vec<MetricReport>, StreamUpdate)>
    where
        F: FnMut(&StreamUpdate) -> StreamControl,
    {
        task.validate()?;
        // Load-time metric resolution (names, scales, requirements all
        // come from the registry — no per-chunk name dispatch).
        let resolved = self.registry.resolve_task(task)?;
        let meter = std::sync::Arc::new(CallMeter::default());
        let chunk_size = chunk_size.max(1);
        let total = df.len();
        let prompts = self.prepare_prompts(df, task)?;

        let mut all_values: Vec<Vec<Option<f64>>> =
            task.metrics.iter().map(|_| Vec::new()).collect();
        let mut unparseable = vec![0usize; task.metrics.len()];
        let mut update = StreamUpdate {
            processed: 0,
            total,
            running: Vec::new(),
            api_calls: 0,
            cache_hits: 0,
            cost_usd: 0.0,
            failed: 0,
            judge_calls: CallStats::default(),
            sched: SchedulerStats::default(),
        };

        let mut start = 0usize;
        while start < total {
            let end = (start + chunk_size).min(total);
            let idx: Vec<usize> = (start..end).collect();
            let chunk_df = df.take(&idx)?;
            let chunk_prompts = prompts[start..end].to_vec();

            let (rows, stats) = self.run_inference(&chunk_prompts, task)?;
            let failed: Vec<bool> = rows.iter().map(|r| r.response.is_none()).collect();
            let examples = self.build_examples(&chunk_df, task, &chunk_prompts, &rows);
            for (mi, metric) in resolved.iter().enumerate() {
                let report = self.compute_resolved(metric, &examples, task, &failed, &meter)?;
                unparseable[mi] += report.unparseable;
                all_values[mi].extend(report.values);
            }

            update.processed = end;
            update.api_calls += stats.api_calls;
            update.cache_hits += stats.cache_hits;
            update.cost_usd += stats.total_cost_usd;
            update.failed += stats.failed;
            // The meter is shared across chunks, so its stats are already
            // cumulative.
            update.judge_calls = meter.stats();
            update.sched.merge(&stats.sched);
            update.running = task
                .metrics
                .iter()
                .enumerate()
                .map(|(mi, mc)| {
                    let scored: Vec<f64> = all_values[mi].iter().filter_map(|v| *v).collect();
                    let scale = resolved[mi].scale();
                    let ci = if scored.is_empty() {
                        ConfidenceInterval {
                            point: f64::NAN,
                            lo: f64::NAN,
                            hi: f64::NAN,
                            level: task.statistics.confidence_level,
                            method: "none",
                        }
                    } else if scale == MetricScale::Binary {
                        let successes = scored.iter().filter(|&&v| v >= 0.5).count() as u64;
                        wilson_interval(
                            successes,
                            scored.len() as u64,
                            task.statistics.confidence_level,
                        )
                    } else {
                        t_interval(&scored, task.statistics.confidence_level)
                    };
                    (mc.name.clone(), ci)
                })
                .collect();

            let control = on_update(&update);
            start = end;
            if control == StreamControl::Stop {
                break;
            }
        }

        let reports: Vec<MetricReport> = resolved
            .iter()
            .enumerate()
            .map(|(mi, metric)| MetricReport {
                name: metric.name().to_string(),
                values: all_values[mi].clone(),
                scale: metric.scale(),
                unparseable: unparseable[mi],
            })
            .collect();
        Ok((reports, update))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MetricConfig;
    use crate::data::synth;
    use crate::providers::simulated::SimServiceConfig;
    use crate::ratelimit::VirtualClock;

    fn fast_runner() -> EvalRunner {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        r
    }

    #[test]
    fn streams_all_chunks_and_matches_batch_eval() {
        let runner = fast_runner();
        let df = synth::generate_default(130, 91);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];

        let mut updates = 0;
        let (reports, last) = runner
            .evaluate_streaming(&df, &task, 40, |u| {
                updates += 1;
                assert!(u.processed <= u.total);
                assert!(u.metric("exact_match").is_some());
                StreamControl::Continue
            })
            .unwrap();
        assert_eq!(updates, 4); // 40+40+40+10
        assert_eq!(last.processed, 130);
        assert_eq!(reports[0].values.len(), 130);

        // Same values as the batch path.
        let batch = runner.evaluate(&df, &task).unwrap();
        let streamed_mean =
            reports[0].scored().iter().sum::<f64>() / reports[0].n_scored() as f64;
        assert!((streamed_mean - batch.metric("exact_match").unwrap().value).abs() < 1e-12);
    }

    #[test]
    fn judge_traffic_surfaces_in_updates() {
        let runner = fast_runner();
        let df = synth::generate_default(60, 96);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("helpfulness", "llm_judge")];
        let mut seen = Vec::new();
        let (reports, last) = runner
            .evaluate_streaming(&df, &task, 20, |u| {
                seen.push(u.judge_calls.total());
                StreamControl::Continue
            })
            .unwrap();
        // One judge call per processed example, cumulative across chunks.
        assert_eq!(seen, vec![20, 40, 60]);
        assert_eq!(last.judge_calls.api_calls, 60);
        assert!(last.judge_calls.cost_usd > 0.0);
        assert_eq!(reports[0].scale, MetricScale::Ordinal);
    }

    #[test]
    fn early_stop_truncates() {
        let runner = fast_runner();
        let df = synth::generate_default(200, 92);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
        let (reports, last) = runner
            .evaluate_streaming(&df, &task, 50, |u| {
                if u.processed >= 100 {
                    StreamControl::Stop
                } else {
                    StreamControl::Continue
                }
            })
            .unwrap();
        assert_eq!(last.processed, 100);
        assert_eq!(reports[0].values.len(), 100);
    }

    #[test]
    fn running_ci_tightens() {
        let runner = fast_runner();
        let df = synth::generate_default(300, 93);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
        let mut widths = Vec::new();
        runner
            .evaluate_streaming(&df, &task, 75, |u| {
                widths.push(u.metric("exact_match").unwrap().width());
                StreamControl::Continue
            })
            .unwrap();
        assert_eq!(widths.len(), 4);
        assert!(
            widths.last().unwrap() < widths.first().unwrap(),
            "CI should tighten: {widths:?}"
        );
    }

    #[test]
    fn progress_counter_tracks_streaming_inference() {
        let df = synth::generate_default(120, 98);
        let progress = std::sync::Arc::new(crate::engine::Progress::new(120));
        let runner = fast_runner().with_progress(progress.clone());
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
        let mut fractions = Vec::new();
        runner
            .evaluate_streaming(&df, &task, 40, |_| {
                fractions.push(progress.fraction());
                StreamControl::Continue
            })
            .unwrap();
        assert!((progress.fraction() - 1.0).abs() < 1e-12);
        assert_eq!(fractions.len(), 3);
        assert!(
            fractions.windows(2).all(|w| w[0] <= w[1]),
            "progress must be monotone: {fractions:?}"
        );
        assert!((fractions[0] - 1.0 / 3.0).abs() < 1e-9, "{fractions:?}");
    }

    #[test]
    fn early_stopped_stream_resumes_without_repaying_finished_chunks() {
        // Chunked inference checkpoints per chunk (each chunk is its own
        // content-addressed stage), so a stream stopped after chunk 1
        // resumes with chunk 1 restored and only the rest paid for.
        let n = 120;
        let chunk = 40;
        let df = synth::generate_default(n, 99);
        let mut task = EvalTask::default();
        task.inference.cache_policy = crate::config::CachePolicy::Disabled;
        task.scheduler.speculation = false;
        task.scheduler.adaptive_split = false;
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];

        let dir = std::env::temp_dir()
            .join("slleval-coord-test")
            .join(format!("stream-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        // Interrupted stream: stop after the first chunk completes.
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let (_, stopped) = runner
            .evaluate_streaming(&df, &task, chunk, |_| StreamControl::Stop)
            .unwrap();
        assert_eq!(stopped.processed, chunk);
        assert_eq!(stopped.api_calls, chunk as u64);

        // Resumed stream over the full dataset: chunk 1 restores, the
        // remaining chunks execute fresh.
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let mut first_update_calls = None;
        let (reports, last) = runner
            .evaluate_streaming(&df, &task, chunk, |u| {
                if first_update_calls.is_none() {
                    first_update_calls = Some((u.api_calls, u.sched.restored_rows));
                }
                StreamControl::Continue
            })
            .unwrap();
        assert_eq!(first_update_calls, Some((0, chunk)), "chunk 1 must be free");
        assert_eq!(last.processed, n);
        assert_eq!(last.api_calls, (n - chunk) as u64);
        assert_eq!(last.sched.restored_rows, chunk);
        assert_eq!(reports[0].values.len(), n);

        // Same values as an uninterrupted batch evaluation.
        let batch = fast_runner().evaluate(&df, &task).unwrap();
        assert_eq!(reports[0].values, batch.reports[0].values);
    }

    #[test]
    fn early_stop_on_significance_workflow() {
        // The motivating use: stop once the metric CI upper bound falls
        // below a regression threshold.
        let runner = fast_runner();
        let df = synth::generate_default(400, 94);
        let mut task = EvalTask::default();
        task.model.model_name = "gpt-3.5-turbo".into(); // weak model
        task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
        let threshold = 0.95; // "model must score >= 95%"
        let mut stopped_at = None;
        runner
            .evaluate_streaming(&df, &task, 50, |u| {
                let ci = u.metric("exact_match").unwrap();
                if u.processed >= 100 && ci.hi < threshold {
                    stopped_at = Some(u.processed);
                    StreamControl::Stop
                } else {
                    StreamControl::Continue
                }
            })
            .unwrap();
        let at = stopped_at.expect("weak model should fail the bar early");
        assert!(at < 400, "stopped at {at}");
    }
}
